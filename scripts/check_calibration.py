#!/usr/bin/env python
"""Calibration drift gate (CI `bench` job, DESIGN.md §13).

Reads the ``CalibrationReport`` JSON that ``benchmarks.run --profile
--calibration-out`` wrote, prints the fitted cost-model parameters and
the per-mode predicted-vs-measured relative error, and fails when the
worst divergence exceeds the threshold.

The threshold is deliberately GENEROUS (default 10.0 = 1000% relative
error): the tiny CI model on a shared CPU runner is nothing like the
TPU the roofline constants describe, and the fixed overhead term
absorbs most of the wall time — the gate exists to catch the cost model
going structurally wrong (predictions orders of magnitude off, a mode
missing, an unparseable report), not to enforce TPU-grade accuracy.
Locally the same report is informational; tighten ``--max-drift`` when
profiling on real accelerators.

    python scripts/check_calibration.py BENCH_calibration.json \
        [--max-drift 10.0]
"""
from __future__ import annotations

import argparse
import json
import sys

REQUIRED = ("mfu_cap", "ici", "overhead", "per_mode_rel_err",
            "worst_rel_err", "buckets", "n_samples")


def check_report(rep: dict, max_drift: float) -> list[str]:
    failures = []
    missing = sorted(k for k in REQUIRED if k not in rep)
    if missing:
        return [f"malformed calibration report: missing field(s) "
                + ", ".join(missing)]
    if not rep["buckets"]:
        failures.append("calibration report has zero buckets — the "
                        "profile smoke produced no steady samples")
    if not rep["per_mode_rel_err"]:
        failures.append("no per-mode divergence recorded")
    for mode in sorted(rep.get("per_mode_rel_err", {})):
        err = float(rep["per_mode_rel_err"][mode])
        ok = err <= max_drift
        print(f"{'ok  ' if ok else 'FAIL'}  predicted_vs_measured"
              f"{{mode={mode}}}: rel_err={err:.3f} (max {max_drift:g})")
        if not ok:
            failures.append(f"mode {mode}: predicted-vs-measured relative "
                            f"error {err:.3f} exceeds --max-drift "
                            f"{max_drift:g}")
    worst = float(rep["worst_rel_err"])
    if worst > max_drift:
        failures.append(f"worst bucket {rep.get('worst_bucket', '?')}: "
                        f"rel_err={worst:.3f} exceeds --max-drift "
                        f"{max_drift:g}")
    return failures


def main() -> None:
    p = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog="Report schema + calibration math: DESIGN.md §13 and "
               "src/repro/analysis/calibration.py.")
    p.add_argument("report", help="CalibrationReport JSON from "
                                  "benchmarks.run --calibration-out")
    p.add_argument("--max-drift", type=float, default=10.0,
                   help="max allowed predicted-vs-measured relative error "
                        "per mode and per bucket (default 10.0; generous "
                        "on purpose for CPU CI runners)")
    args = p.parse_args()
    try:
        with open(args.report) as f:
            rep = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"cannot read calibration report {args.report!r}: {e}",
              file=sys.stderr)
        sys.exit(1)

    print(f"calibration: model={rep.get('model', '?')} "
          f"tp={rep.get('tp', '?')} tile={rep.get('tile', '?')} "
          f"n_samples={rep.get('n_samples', '?')}")
    if all(k in rep for k in ("mfu_cap", "ici", "overhead")):
        print(f"fitted: mfu_cap={rep['mfu_cap']:.4g} "
              f"ici={rep['ici'] / 1e9:.4g} GB/s "
              f"overhead={rep['overhead'] * 1e6:.4g} us "
              f"step_base={rep.get('step_base', 0):.4g} s "
              f"step_per_token={rep.get('step_per_token', 0):.3e} s/tok")
    failures = check_report(rep, args.max_drift)
    if failures:
        print(f"\n{len(failures)} calibration check(s) failed:",
              file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        sys.exit(1)
    print(f"\ncalibration drift within ±{args.max_drift:g} across "
          f"{len(rep['buckets'])} bucket(s), "
          f"{len(rep['per_mode_rel_err'])} mode(s)")


if __name__ == "__main__":
    main()
