#!/usr/bin/env python
"""DESIGN.md §-reference gate (CI `lint` job).

Source docstrings cite the design doc as ``DESIGN.md §N`` (see the module
map in the top-level README).  This script keeps those citations honest:

1. **Resolution** — every ``DESIGN.md §N`` citation in a Python file under
   the scanned roots must resolve to a real ``## §N`` heading in
   DESIGN.md.  (Bare ``§N`` without the ``DESIGN.md`` qualifier is NOT
   checked: the code also cites *paper* sections, e.g. "paper §3.1".)
2. **Coverage** — every module under the ``COVERED_PACKAGES`` roots
   (runtime, core, obs, analysis) must have a module-level docstring
   containing at least one ``DESIGN.md §N`` citation, so the module map
   stays complete as the runtime grows.

    python scripts/check_design_refs.py [--root .]

Exit 0 when clean; exit 1 listing every violation as ``path:line: msg``.
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path

HEADING_RE = re.compile(r"^##\s+§(\d+)\b", re.MULTILINE)
CITE_RE = re.compile(r"DESIGN(?:\.md)?\s+§(\d+)\b")

# roots scanned for citation *resolution* (anything citing DESIGN.md)
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "scripts")
# packages whose every module must *carry* a citation (coverage rule)
COVERED_PACKAGES = ("src/repro/runtime", "src/repro/core",
                    "src/repro/obs", "src/repro/analysis")


def parse_headings(design_text: str) -> set:
    """Section numbers with a real ``## §N`` heading in DESIGN.md."""
    return {int(m) for m in HEADING_RE.findall(design_text)}


def find_citations(text: str):
    """All ``DESIGN.md §N`` citations as (line_number, section) pairs.

    Scans the WHOLE text, not line by line: ``\\s+`` in the pattern spans
    newlines, so a citation wrapped across a line break (normal docstring
    wrapping) is still found — and therefore still resolution-checked,
    with the same regex semantics the coverage rule uses."""
    return [(text.count("\n", 0, m.start()) + 1, int(m.group(1)))
            for m in CITE_RE.finditer(text)]


def module_docstring_cites(text: str) -> bool:
    """True when the module-level docstring carries a DESIGN.md §N cite."""
    try:
        doc = ast.get_docstring(ast.parse(text))
    except SyntaxError:
        return False
    return bool(doc and CITE_RE.search(doc))


def check(root: Path) -> list:
    """All violations under ``root`` as ``path:line: message`` strings."""
    failures = []
    design = root / "DESIGN.md"
    if not design.is_file():
        return [f"{design}: DESIGN.md not found"]
    sections = parse_headings(design.read_text())
    if not sections:
        return [f"{design}:1: no '## §N' headings found"]

    for d in SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root)
            text = path.read_text()
            for line, n in find_citations(text):
                if n not in sections:
                    failures.append(
                        f"{rel}:{line}: cites DESIGN.md §{n}, but DESIGN.md "
                        f"has no '## §{n}' heading (sections: "
                        f"{', '.join(str(s) for s in sorted(sections))})")

    for pkg in COVERED_PACKAGES:
        base = root / pkg
        if not base.is_dir():
            failures.append(f"{pkg}: covered package missing")
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root)
            if not module_docstring_cites(path.read_text()):
                failures.append(
                    f"{rel}:1: module docstring must cite its design "
                    f"section ('DESIGN.md §N') — see the README module map")
    return failures


def main() -> None:
    p = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        epilog="Module <-> section map: README.md; design doc: DESIGN.md.")
    p.add_argument("--root", default=".", help="repo root (default: cwd)")
    args = p.parse_args()
    failures = check(Path(args.root))
    if failures:
        print(f"{len(failures)} design-reference violation(s):",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print("all DESIGN.md § citations resolve; every covered-package "
          "module carries one")


if __name__ == "__main__":
    main()
