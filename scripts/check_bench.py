#!/usr/bin/env python
"""Benchmark-regression gate (CI `bench` job).

Compares the deterministic serving metrics a benchmark run wrote with
``python -m benchmarks.run --json BENCH_serve.json`` against the committed
``benchmarks/baseline.json``:

* the KEY SETS must agree exactly — a metric missing from the run means a
  benchmark silently stopped emitting it (a gate going vacuous), and a
  metric missing from the baseline means new coverage nobody is tracking
  yet; both fail with the full list of missing/extra names so the fix
  (extend the baseline, or restore the benchmark) is obvious.  Pass
  ``--allow-extra`` to downgrade extra-only disagreements to a note (local
  iteration on a new benchmark before its baseline lands).
* every shared metric must be within a relative tolerance (default ±15%);
  a zero baseline must stay zero (these are counters — preemptions
  appearing out of nowhere IS a regression).
* when the run carries a ``__provenance__`` map (metric -> source,
  written by benchmarks/run.py), every gated key must originate from a
  metrics-registry ``snapshot()`` (source ``registry`` or ``derived``,
  DESIGN.md §12) — an ``adhoc`` metric is an orphan the observability
  layer cannot vouch for, and fails with its name listed.
* ``measured:*`` keys (wall-clock profiling + calibration, written by
  ``benchmarks.run --profile``, DESIGN.md §13) are machine-dependent and
  therefore EXEMPT from the key-set and ±tolerance gates — but they are
  still provenance-REQUIRED: every measured key must be registry-sourced
  (no provenance map at all fails when measured keys are present).

    python scripts/check_bench.py BENCH_serve.json \
        [--baseline benchmarks/baseline.json] [--tol 0.15] [--allow-extra]
"""
from __future__ import annotations

import argparse
import json
import sys

# reserved key in the metrics JSON: {metric: source} map, never a metric
PROVENANCE_KEY = "__provenance__"

# wall-clock metrics namespace (DESIGN.md §13): informational, never
# compared against the committed baseline
MEASURED_PREFIX = "measured:"

# sources the registry can vouch for: a snapshot key copied verbatim, or
# a value computed from snapshot keys (recorded as derived:<expr>)
_REGISTRY_SOURCES = ("registry", "derived")


def split_measured(cur: dict) -> tuple[dict, dict]:
    """Partition a metrics dict into (deterministic, measured)."""
    det = {k: v for k, v in cur.items()
           if not k.startswith(MEASURED_PREFIX)}
    meas = {k: v for k, v in cur.items() if k.startswith(MEASURED_PREFIX)}
    return det, meas


def measured_failures(measured: dict, prov: dict | None) -> list[str]:
    """measured:* keys skip the determinism gates but MUST be sourced
    from a metrics-registry snapshot — unlike the baseline-keyed check,
    a missing provenance map is itself a failure here, because measured
    keys have no baseline entry vouching for them."""
    if not measured:
        return []
    if prov is None:
        return [f"{len(measured)} measured metric(s) present but the run "
                f"has no {PROVENANCE_KEY} map: " + ", ".join(sorted(measured))]
    orphans = sorted(
        k for k in measured
        if not str(prov.get(k, "adhoc")).startswith(_REGISTRY_SOURCES))
    if not orphans:
        for k in sorted(measured):
            print(f"meas  {k}: {measured[k]:g} (informational, not gated)")
        return []
    return [f"{len(orphans)} measured metric(s) not sourced from a "
            f"metrics-registry snapshot (orphans): " + ", ".join(
                f"{k} [{prov.get(k, 'missing')}]" for k in orphans)]


def provenance_failures(prov: dict | None, base: dict) -> list[str]:
    """Every baseline-gated key must come from a registry snapshot.

    ``prov`` is the run's ``__provenance__`` map; None (a pre-provenance
    metrics file) skips the check for backward compatibility."""
    if prov is None:
        return []
    orphans = sorted(
        k for k in base
        if not str(prov.get(k, "adhoc")).startswith(_REGISTRY_SOURCES))
    if not orphans:
        return []
    return [f"{len(orphans)} gated metric(s) not sourced from a metrics-"
            f"registry snapshot (orphans): " + ", ".join(
                f"{k} [{prov.get(k, 'missing')}]" for k in orphans)]


def keyset_failures(cur: dict, base: dict,
                    allow_extra: bool = False) -> list[str]:
    """Key-set disagreement as failure strings (empty = sets agree)."""
    missing = sorted(set(base) - set(cur))
    extra = sorted(set(cur) - set(base))
    failures = []
    if missing:
        failures.append(
            f"{len(missing)} baseline metric(s) MISSING from the current "
            f"run (a benchmark stopped emitting them): "
            + ", ".join(missing))
    if extra and not allow_extra:
        failures.append(
            f"{len(extra)} metric(s) in the current run but NOT in the "
            f"baseline (extend the baseline to start tracking them): "
            + ", ".join(extra))
    elif extra:
        for k in extra:
            print(f"note  {k}: not in baseline (current={cur[k]:g})")
    return failures


def compare(cur: dict, base: dict, tol: float) -> list[str]:
    """Per-metric tolerance check over the SHARED keys."""
    failures = []
    for key in sorted(set(base) & set(cur)):
        b = float(base[key])
        c = float(cur[key])
        if b == 0.0:
            ok = c == 0.0
            detail = f"current={c:g} baseline=0"
        else:
            rel = abs(c - b) / abs(b)
            ok = rel <= tol
            detail = f"current={c:g} baseline={b:g} rel_diff={rel:.1%}"
        print(f"{'ok  ' if ok else 'FAIL'}  {key}: {detail}")
        if not ok:
            failures.append(f"{key}: {detail}")
    return failures


def run_checks(cur: dict, base: dict, tol: float,
               allow_extra: bool = False,
               provenance: dict | None = None) -> list[str]:
    det, measured = split_measured(cur)
    base_det, _ = split_measured(base)
    return (keyset_failures(det, base_det, allow_extra=allow_extra)
            + compare(det, base_det, tol)
            + provenance_failures(provenance, base_det)
            + measured_failures(measured, provenance))


def main() -> None:
    p = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog="Metric semantics (deterministic counters vs ±tol values) "
               "and scenario docs: benchmarks/README.md.  Baseline update "
               "workflow: README.md (top level).")
    p.add_argument("current", help="metrics JSON from benchmarks.run --json")
    p.add_argument("--baseline", default="benchmarks/baseline.json")
    p.add_argument("--tol", type=float, default=0.15,
                   help="relative tolerance (default 0.15 = ±15%%)")
    p.add_argument("--allow-extra", action="store_true",
                   help="don't fail on metrics absent from the baseline "
                        "(local runs before a new baseline lands)")
    args = p.parse_args()
    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    prov = cur.pop(PROVENANCE_KEY, None)
    base.pop(PROVENANCE_KEY, None)

    failures = run_checks(cur, base, args.tol, allow_extra=args.allow_extra,
                          provenance=prov)
    if failures:
        print(f"\n{len(failures)} check(s) failed:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        sys.exit(1)
    print(f"\nall {len(base)} baseline metrics present and within "
          f"±{args.tol:.0%}")


if __name__ == "__main__":
    main()
