#!/usr/bin/env python
"""Benchmark-regression gate (CI `bench` job).

Compares the deterministic serving metrics a benchmark run wrote with
``python -m benchmarks.run --json BENCH_serve.json`` against the committed
``benchmarks/baseline.json`` within a relative tolerance (default ±15%).
Every baseline key must be present and in range; a zero baseline must stay
zero (these are counters — preemptions appearing out of nowhere IS a
regression).  Metrics present in the current run but absent from the
baseline are reported as a reminder to extend the baseline, not a failure
— new coverage must never be punished.

    python scripts/check_bench.py BENCH_serve.json \
        [--baseline benchmarks/baseline.json] [--tol 0.15]
"""
from __future__ import annotations

import argparse
import json
import sys


def compare(cur: dict, base: dict, tol: float) -> list[str]:
    failures = []
    for key in sorted(base):
        b = float(base[key])
        if key not in cur:
            failures.append(f"{key}: missing from current run "
                            f"(baseline {b:g})")
            continue
        c = float(cur[key])
        if b == 0.0:
            ok = c == 0.0
            detail = f"current={c:g} baseline=0"
        else:
            rel = abs(c - b) / abs(b)
            ok = rel <= tol
            detail = f"current={c:g} baseline={b:g} rel_diff={rel:.1%}"
        print(f"{'ok  ' if ok else 'FAIL'}  {key}: {detail}")
        if not ok:
            failures.append(f"{key}: {detail}")
    return failures


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("current", help="metrics JSON from benchmarks.run --json")
    p.add_argument("--baseline", default="benchmarks/baseline.json")
    p.add_argument("--tol", type=float, default=0.15,
                   help="relative tolerance (default 0.15 = ±15%%)")
    args = p.parse_args()
    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    failures = compare(cur, base, args.tol)
    extra = sorted(set(cur) - set(base))
    for key in extra:
        print(f"note  {key}: not in baseline (current={cur[key]:g}) — "
              f"extend {args.baseline} to start tracking it")
    if failures:
        print(f"\n{len(failures)} metric(s) out of tolerance:",
              file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        sys.exit(1)
    print(f"\nall {len(base)} baseline metrics within ±{args.tol:.0%}")


if __name__ == "__main__":
    main()
