#!/usr/bin/env python
"""Skip-count gate (CI tier1 job).

The test suite carries a KNOWN set of capability skips (jax-0.4.37 Pallas
interpreter, old shard_map scalar-residual staging, optional hypothesis —
see CHANGES.md / the verify skill).  Skips must not silently grow: a new
`pytest.importorskip` or capability guard that starts skipping real
coverage should fail CI until the ceiling here is consciously raised.

    python scripts/check_skips.py pytest-results.xml --max-skips 6
"""
from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("junit_xml", help="pytest --junitxml output")
    p.add_argument("--max-skips", type=int, default=6)
    args = p.parse_args()

    root = ET.parse(args.junit_xml).getroot()
    suites = [root] if root.tag == "testsuite" else list(
        root.iter("testsuite"))
    skipped = sum(int(s.get("skipped", 0)) for s in suites)

    for case in root.iter("testcase"):
        sk = case.find("skipped")
        if sk is not None:
            name = f"{case.get('classname', '?')}::{case.get('name', '?')}"
            print(f"skipped  {name}: {sk.get('message', '')[:120]}")

    if skipped > args.max_skips:
        print(f"\n{skipped} tests skipped, ceiling is {args.max_skips} — "
              f"a capability skip crept in; fix it or consciously raise "
              f"the ceiling in .github/workflows/ci.yml", file=sys.stderr)
        sys.exit(1)
    print(f"\n{skipped} skip(s) <= ceiling {args.max_skips}")


if __name__ == "__main__":
    main()
