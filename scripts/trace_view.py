#!/usr/bin/env python
"""Inspect / validate an exported Chrome-trace JSON (DESIGN.md §12).

    python scripts/trace_view.py BENCH_trace.json              # summary
    python scripts/trace_view.py BENCH_trace.json --validate   # CI gate
    python scripts/trace_view.py BENCH_trace.json --request online7
    python scripts/trace_view.py BENCH_trace.json --measured   # §13

Summary mode prints, per engine track: step/forward span counts, the
trace-derived weave rate (weave forwards / forwards, recomputed from the
per-forward attribution records — the same number `EngineStats.weave_rate`
reports), the per-forward decision reasons with the OVERLAP-POLICY plan
ids that made them (plan id 0 = the degenerate global threshold, a
nonzero id = a tuned plan cache from ``analysis/autotune.py``,
DESIGN.md §14), and the estimated compute / comm / overlapped
virtual-time totals from the §9 sim roofline.  ``--request`` walks one request's
lifecycle thread event by event (arrival → ... → terminal) including
every forward step that touched it.  ``--validate`` runs the full schema
check (``repro.obs.validate_chrome_trace``) and exits non-zero on any
failure — the CI bench job runs this on the quick-sweep trace.
``--measured`` summarizes the ``[measured]`` wall-clock track a
``WallClockProfiler`` recorded (DESIGN.md §13): per (track, phase),
measured seconds next to the §9-roofline virtual-second estimates and
their ratio.

The trace itself loads in the Perfetto UI: https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.trace import TERMINAL_PHASES, validate_chrome_trace  # noqa: E402


def _tracks(doc: dict):
    """pid -> process name, (pid, tid) -> thread name."""
    procs, threads = {}, {}
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "M":
            continue
        if ev["name"] == "process_name":
            procs[ev["pid"]] = ev["args"]["name"]
        elif ev["name"] == "thread_name":
            threads[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    return procs, threads


def summarize(doc: dict) -> None:
    procs, _ = _tracks(doc)
    per = defaultdict(lambda: {"steps": 0, "forwards": 0, "weave": 0,
                               "compute": 0.0, "comm": 0.0,
                               "overlapped": 0.0,
                               "by_reason": defaultdict(int),
                               "by_plan": defaultdict(int),
                               "by_method": defaultdict(int)})
    requests = defaultdict(list)
    for ev in doc["traceEvents"]:
        ph, cat = ev.get("ph"), ev.get("cat")
        if ph == "X" and cat == "step":
            per[procs.get(ev["pid"], ev["pid"])]["steps"] += 1
        elif ph == "X" and cat == "forward":
            t = per[procs.get(ev["pid"], ev["pid"])]
            a = ev.get("args", {})
            t["forwards"] += 1
            t["weave"] += int(bool(a.get("weave")))
            t["compute"] += a.get("est_compute", 0.0)
            t["comm"] += a.get("est_comm", 0.0)
            t["overlapped"] += a.get("est_overlapped", 0.0)
            t["by_reason"][a.get("reason", "?")] += 1
            t["by_plan"][a.get("plan_id", 0)] += 1
            t["by_method"][a.get("method", "?")] += 1
        elif ph == "i" and cat == "request":
            requests[(ev["pid"], ev["tid"])].append(ev["name"])

    for name in sorted(per):
        t = per[name]
        rate = t["weave"] / t["forwards"] if t["forwards"] else 0.0
        print(f"track {name}: {t['steps']} steps, {t['forwards']} forwards, "
              f"weave_rate={rate:.4f}")
        reasons = ", ".join(f"{k}={v}" for k, v in
                            sorted(t["by_reason"].items()))
        print(f"  decisions: {reasons}")
        # method=ring/ringweave rows are forwards the plan routed onto the
        # REAL fused ring AllReduce-RMSNorm kernel (DESIGN.md §14)
        methods = ", ".join(
            f"{k}{' [fused-kernel]' if k in ('ring', 'ringweave') else ''}"
            f"={v}" for k, v in sorted(t["by_method"].items()))
        print(f"  methods: {methods}")
        plans = ", ".join(
            f"{'global-threshold' if pid == 0 else f'plan {pid}'}={v}"
            for pid, v in sorted(t["by_plan"].items()))
        print(f"  decided by: {plans}")
        print(f"  est virtual time: compute={t['compute']:.6g} "
              f"comm={t['comm']:.6g} overlapped={t['overlapped']:.6g}")
    n_term = sum(1 for phases in requests.values()
                 if any(p in TERMINAL_PHASES for p in phases))
    print(f"requests: {len(requests)} lifecycle threads, "
          f"{n_term} reached a terminal phase")


def summarize_measured(doc: dict) -> int:
    """Virtual-vs-measured per phase from the ``[measured]`` track(s)."""
    procs, _ = _tracks(doc)
    per = defaultdict(lambda: {"n": 0, "measured_s": 0.0, "virtual_s": 0.0})
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "X" or ev.get("cat") != "measured":
            continue
        a = ev.get("args", {})
        track = procs.get(ev["pid"], ev["pid"])
        kind = a.get("kind", ev.get("name", "?"))
        t = per[(track, kind)]
        t["n"] += 1
        # the exporter scales 1 virtual tick (= 1 wall second on the
        # measured track) to 1e6 trace units
        t["measured_s"] += ev.get("dur", 0.0) / 1e6
        t["virtual_s"] += a.get("est_makespan", 0.0)
    if not per:
        print("no measured spans in this trace — record one with a "
              "WallClockProfiler attached to the engine "
              "(benchmarks.run --profile, DESIGN.md §13)", file=sys.stderr)
        return 1
    print(f"{'track':<22} {'phase':<9} {'n':>5} {'measured_s':>12} "
          f"{'virtual_s':>12} {'meas/virt':>10}")
    for (track, kind) in sorted(per):
        t = per[(track, kind)]
        ratio = (t["measured_s"] / t["virtual_s"] if t["virtual_s"]
                 else float("inf"))
        print(f"{str(track):<22} {kind:<9} {t['n']:>5} "
              f"{t['measured_s']:>12.6f} {t['virtual_s']:>12.6f} "
              f"{ratio:>10.3g}")
    tot_m = sum(t["measured_s"] for t in per.values())
    tot_v = sum(t["virtual_s"] for t in per.values())
    print(f"total: measured={tot_m:.6f}s virtual={tot_v:.6g}s "
          f"ratio={tot_m / tot_v if tot_v else float('inf'):.3g}")
    return 0


def show_request(doc: dict, rid: str) -> int:
    procs, threads = _tracks(doc)
    want = f"req {rid}"
    key = next((k for k, v in threads.items() if v == want), None)
    if key is None:
        names = sorted(v[4:] for v in threads.values())
        print(f"no request {rid!r}; known rids: {', '.join(names)}",
              file=sys.stderr)
        return 1
    pid, tid = key
    print(f"request {rid} lifecycle:")
    for ev in doc["traceEvents"]:
        if (ev.get("pid"), ev.get("tid")) != (pid, tid):
            continue
        if ev.get("ph") == "i" and ev.get("cat") == "request":
            extra = {k: v for k, v in ev.get("args", {}).items()
                     if v is not None}
            print(f"  t={ev['ts'] / 1e6:10.4f}  {ev['name']:<15} {extra}")
    # every forward span whose step committed tokens for this rid is not
    # tagged per-rid (packed forwards are shared); show the overlap-policy
    # decision log of all forwards instead, time-interleaved with the
    # lifecycle — each row names the plan that decided it (plan 0 = the
    # degenerate global threshold, DESIGN.md §14)
    print(f"\noverlap-policy decisions while {rid} was live (all tracks):")
    first = min((ev["ts"] for ev in doc["traceEvents"]
                 if (ev.get("pid"), ev.get("tid")) == (pid, tid)
                 and "ts" in ev), default=0.0)
    last = max((ev["ts"] for ev in doc["traceEvents"]
                if (ev.get("pid"), ev.get("tid")) == (pid, tid)
                and "ts" in ev), default=0.0)
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "X" or ev.get("cat") != "forward":
            continue
        if not (first <= ev["ts"] <= last):
            continue
        a = ev.get("args", {})
        track = procs.get(ev["pid"], ev["pid"])
        plan = a.get("plan_id", 0)
        print(f"  t={ev['ts'] / 1e6:10.4f}  {track:<10} {ev['name']:<16} "
              f"weave={str(bool(a.get('weave'))):<5} "
              f"reason={a.get('reason', '?'):<16} tokens={a.get('tokens')} "
              f"plan={'threshold' if plan == 0 else plan} "
              f"bucket={a.get('bucket', '?')} "
              f"ovl={a.get('est_overlapped', 0.0):.3g}")
    return 0


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("trace", help="Chrome-trace JSON from export_chrome_trace")
    p.add_argument("--validate", action="store_true",
                   help="schema + invariant check; non-zero exit on failure")
    p.add_argument("--request", default=None, metavar="RID",
                   help="walk one request's lifecycle thread")
    p.add_argument("--measured", action="store_true",
                   help="virtual-vs-measured wall-clock summary per phase "
                        "(needs a trace recorded with a WallClockProfiler)")
    args = p.parse_args()
    with open(args.trace) as f:
        doc = json.load(f)

    if args.validate:
        fails = validate_chrome_trace(doc)
        if fails:
            print(f"{len(fails)} validation failure(s):", file=sys.stderr)
            for msg in fails:
                print(f"  {msg}", file=sys.stderr)
            return 1
        n = len(doc.get("traceEvents", []))
        print(f"trace valid: {n} events")
        return 0
    if args.measured:
        return summarize_measured(doc)
    if args.request is not None:
        return show_request(doc, args.request)
    summarize(doc)
    return 0


if __name__ == "__main__":
    sys.exit(main())
