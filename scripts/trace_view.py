#!/usr/bin/env python
"""Inspect / validate an exported Chrome-trace JSON (DESIGN.md §12).

    python scripts/trace_view.py BENCH_trace.json              # summary
    python scripts/trace_view.py BENCH_trace.json --validate   # CI gate
    python scripts/trace_view.py BENCH_trace.json --request online7

Summary mode prints, per engine track: step/forward span counts, the
trace-derived weave rate (weave forwards / forwards, recomputed from the
per-forward attribution records — the same number `EngineStats.weave_rate`
reports), and the estimated compute / comm / overlapped virtual-time
totals from the §10 sim roofline.  ``--request`` walks one request's
lifecycle thread event by event (arrival → ... → terminal) including
every forward step that touched it.  ``--validate`` runs the full schema
check (``repro.obs.validate_chrome_trace``) and exits non-zero on any
failure — the CI bench job runs this on the quick-sweep trace.

The trace itself loads in the Perfetto UI: https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.trace import TERMINAL_PHASES, validate_chrome_trace  # noqa: E402


def _tracks(doc: dict):
    """pid -> process name, (pid, tid) -> thread name."""
    procs, threads = {}, {}
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "M":
            continue
        if ev["name"] == "process_name":
            procs[ev["pid"]] = ev["args"]["name"]
        elif ev["name"] == "thread_name":
            threads[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    return procs, threads


def summarize(doc: dict) -> None:
    procs, _ = _tracks(doc)
    per = defaultdict(lambda: {"steps": 0, "forwards": 0, "weave": 0,
                               "compute": 0.0, "comm": 0.0,
                               "overlapped": 0.0, "by_reason": defaultdict(int)})
    requests = defaultdict(list)
    for ev in doc["traceEvents"]:
        ph, cat = ev.get("ph"), ev.get("cat")
        if ph == "X" and cat == "step":
            per[procs.get(ev["pid"], ev["pid"])]["steps"] += 1
        elif ph == "X" and cat == "forward":
            t = per[procs.get(ev["pid"], ev["pid"])]
            a = ev.get("args", {})
            t["forwards"] += 1
            t["weave"] += int(bool(a.get("weave")))
            t["compute"] += a.get("est_compute", 0.0)
            t["comm"] += a.get("est_comm", 0.0)
            t["overlapped"] += a.get("est_overlapped", 0.0)
            t["by_reason"][a.get("reason", "?")] += 1
        elif ph == "i" and cat == "request":
            requests[(ev["pid"], ev["tid"])].append(ev["name"])

    for name in sorted(per):
        t = per[name]
        rate = t["weave"] / t["forwards"] if t["forwards"] else 0.0
        print(f"track {name}: {t['steps']} steps, {t['forwards']} forwards, "
              f"weave_rate={rate:.4f}")
        reasons = ", ".join(f"{k}={v}" for k, v in
                            sorted(t["by_reason"].items()))
        print(f"  decisions: {reasons}")
        print(f"  est virtual time: compute={t['compute']:.6g} "
              f"comm={t['comm']:.6g} overlapped={t['overlapped']:.6g}")
    n_term = sum(1 for phases in requests.values()
                 if any(p in TERMINAL_PHASES for p in phases))
    print(f"requests: {len(requests)} lifecycle threads, "
          f"{n_term} reached a terminal phase")


def show_request(doc: dict, rid: str) -> int:
    procs, threads = _tracks(doc)
    want = f"req {rid}"
    key = next((k for k, v in threads.items() if v == want), None)
    if key is None:
        names = sorted(v[4:] for v in threads.values())
        print(f"no request {rid!r}; known rids: {', '.join(names)}",
              file=sys.stderr)
        return 1
    pid, tid = key
    print(f"request {rid} lifecycle:")
    for ev in doc["traceEvents"]:
        if (ev.get("pid"), ev.get("tid")) != (pid, tid):
            continue
        if ev.get("ph") == "i" and ev.get("cat") == "request":
            extra = {k: v for k, v in ev.get("args", {}).items()
                     if v is not None}
            print(f"  t={ev['ts'] / 1e6:10.4f}  {ev['name']:<15} {extra}")
    # every forward span whose step committed tokens for this rid is not
    # tagged per-rid (packed forwards are shared); show the weave decision
    # log of all forwards instead, time-interleaved with the lifecycle
    print(f"\nweave decisions while {rid} was live (all tracks):")
    first = min((ev["ts"] for ev in doc["traceEvents"]
                 if (ev.get("pid"), ev.get("tid")) == (pid, tid)
                 and "ts" in ev), default=0.0)
    last = max((ev["ts"] for ev in doc["traceEvents"]
                if (ev.get("pid"), ev.get("tid")) == (pid, tid)
                and "ts" in ev), default=0.0)
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "X" or ev.get("cat") != "forward":
            continue
        if not (first <= ev["ts"] <= last):
            continue
        a = ev.get("args", {})
        track = procs.get(ev["pid"], ev["pid"])
        print(f"  t={ev['ts'] / 1e6:10.4f}  {track:<10} {ev['name']:<16} "
              f"weave={str(bool(a.get('weave'))):<5} "
              f"reason={a.get('reason', '?'):<16} tokens={a.get('tokens')} "
              f"ovl={a.get('est_overlapped', 0.0):.3g}")
    return 0


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("trace", help="Chrome-trace JSON from export_chrome_trace")
    p.add_argument("--validate", action="store_true",
                   help="schema + invariant check; non-zero exit on failure")
    p.add_argument("--request", default=None, metavar="RID",
                   help="walk one request's lifecycle thread")
    args = p.parse_args()
    with open(args.trace) as f:
        doc = json.load(f)

    if args.validate:
        fails = validate_chrome_trace(doc)
        if fails:
            print(f"{len(fails)} validation failure(s):", file=sys.stderr)
            for msg in fails:
                print(f"  {msg}", file=sys.stderr)
            return 1
        n = len(doc.get("traceEvents", []))
        print(f"trace valid: {n} events")
        return 0
    if args.request is not None:
        return show_request(doc, args.request)
    summarize(doc)
    return 0


if __name__ == "__main__":
    sys.exit(main())
