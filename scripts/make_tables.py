"""Regenerate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run JSON. Usage: python scripts/make_tables.py runs/dryrun_final.json"""
import json
import sys


def fmt(path):
    d = json.load(open(path))
    out = []
    for mesh_tag, title in (("|16x16|", "single-pod 16x16 (256 chips)"),
                            ("|2x16x16|", "multi-pod 2x16x16 (512 chips)")):
        rows, skips = [], []
        for k, v in sorted(d.items()):
            if mesh_tag not in k:
                continue
            if not (k.endswith("|baseline") or k.endswith("|final")):
                continue
            arch, shape = k.split("|")[0], k.split("|")[1]
            if v["status"] == "skipped":
                skips.append((arch, shape, v.get("skip", "")))
                continue
            if v["status"] != "ok":
                rows.append((arch, shape, v["status"], "", "", "", "", "",
                             ""))
                continue
            r = v["roofline"]
            m = v["memory"]
            dom = r["dominant"]
            rows.append((
                arch, shape, f'{r["compute_s"]:.3f}', f'{r["memory_s"]:.3f}',
                f'{r["collective_s"]:.4f}', dom,
                f'{r["useful_ratio"]:.2f}',
                f'{(m["args"] + m["temp"]) / 2**30:.1f}',
                f'{v.get("compile_s", "")}'))
        out.append(f"\n### {title}\n")
        out.append("| arch | shape | compute_s | memory_s | collective_s |"
                   " dominant | useful | GiB/dev | compile_s |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        for row in rows:
            out.append("| " + " | ".join(str(x) for x in row) + " |")
        if skips:
            out.append("\nskipped cells (documented, DESIGN.md §4): "
                       + ", ".join(f"{a}/{s}" for a, s, _ in skips))
    return "\n".join(out)


if __name__ == "__main__":
    print(fmt(sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun_final.json"))
