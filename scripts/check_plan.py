#!/usr/bin/env python
"""Validate a tuned overlap-plan cache and gate it against drift
(DESIGN.md §14) — the plan-cache analogue of check_bench.py.

Two modes, composable:

* schema: the plan JSON must load as a ``core/policy.TunedPolicy`` —
  supported version, every entry keyed by a known site/method with
  split_frac in (0, 1) and budget in (0, 1] — plus structural checks the
  loader is lenient about (nonzero plan id, no duplicate entry keys,
  bucket labels consistent with the declared edges).
* drift (``--expect``): the plan must be ENTRY-IDENTICAL to a reference
  (the committed ``benchmarks/plans/default.json``).  CI regenerates the
  plan with ``python -m repro.analysis.autotune`` on the default sim HW
  and diffs it against the committed cache, so a cost-model or search
  change can never silently invalidate the plan every engine loads.

Exit 0 = pass, 1 = failures (printed one per line).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.policy import PLAN_VERSION, TunedPolicy, token_bucket  # noqa: E402
from repro.core.splitting import MAX_RING_CHANNELS, ring_channels  # noqa: E402

REGEN_HINT = ("regenerate with: PYTHONPATH=src python -m "
              "repro.analysis.autotune --out benchmarks/plans/default.json")


def check_plan(doc: dict) -> List[str]:
    """Schema + structural failures for one plan-cache document."""
    failures: List[str] = []
    try:
        plan = TunedPolicy.from_doc(doc)
    except (ValueError, TypeError, KeyError) as e:
        return [f"plan does not load: {e}"]
    if plan.version != PLAN_VERSION:
        failures.append(f"version {plan.version} != supported "
                        f"{PLAN_VERSION}")
    if plan.plan_id <= 0:
        failures.append(f"plan_id {plan.plan_id} must be positive "
                        f"(0 is reserved for the degenerate threshold "
                        f"policy)")
    if len(plan.bucket_edges) < 2:
        failures.append(f"bucket_edges needs >= 2 edges, got "
                        f"{list(plan.bucket_edges)}")
    if list(plan.bucket_edges) != sorted(set(plan.bucket_edges)):
        failures.append(f"bucket_edges not strictly increasing: "
                        f"{list(plan.bucket_edges)}")
    if not plan.entries:
        failures.append("plan has no entries")
    valid_buckets = {token_bucket(lo, plan.bucket_edges)
                     for lo in plan.bucket_edges}
    seen = set()
    for e in plan.entries:
        key = (e.site, e.bucket, e.tp, e.family)
        if key in seen:
            failures.append(f"duplicate entry key {key}")
        seen.add(key)
        if e.bucket not in valid_buckets:
            failures.append(f"entry {key}: bucket {e.bucket!r} does not "
                            f"match the declared bucket_edges")
        if e.method in ("fused", "fused-unsplit"):
            # fused entries grant the ring kernel its lane count through
            # the budget; a budget that rounds to zero lanes (or claims
            # more than the kernel can drive) would over/under-commit the
            # comm resource at runtime — reject it here, not in the engine
            lanes = ring_channels(e.budget)
            if not (1 <= lanes <= MAX_RING_CHANNELS):
                failures.append(
                    f"entry {key}: method {e.method!r} budget {e.budget} "
                    f"maps to {lanes} ring lanes (want 1..."
                    f"{MAX_RING_CHANNELS})")
    return failures


def check_drift(doc: dict, expect: dict) -> List[str]:
    """Entry-level diff of a plan against the committed reference."""
    failures: List[str] = []
    for field in ("version", "plan_id", "bucket_edges"):
        if doc.get(field) != expect.get(field):
            failures.append(f"{field}: {doc.get(field)!r} != committed "
                            f"{expect.get(field)!r}")

    def index(d):
        return {(e["site"], e["bucket"], e["tp"], e["family"]): e
                for e in d.get("entries", [])}

    cur, ref = index(doc), index(expect)
    for key in sorted(set(ref) - set(cur)):
        failures.append(f"missing committed entry {key}")
    for key in sorted(set(cur) - set(ref)):
        failures.append(f"extra entry {key} not in committed plan")
    for key in sorted(set(cur) & set(ref)):
        if cur[key] != ref[key]:
            failures.append(f"entry {key} drifted: {cur[key]} != "
                            f"committed {ref[key]}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate a tuned overlap-plan cache (DESIGN.md §14)",
        epilog=f"On drift failures: {REGEN_HINT} — then commit the "
               f"regenerated plan alongside the change that moved it.")
    ap.add_argument("plan", help="plan-cache JSON to validate")
    ap.add_argument("--expect", default=None,
                    help="committed reference plan; any entry difference "
                         "fails (CI drift gate)")
    args = ap.parse_args(argv)

    with open(args.plan) as f:
        doc = json.load(f)
    failures = check_plan(doc)
    if args.expect:
        with open(args.expect) as f:
            expect = json.load(f)
        failures += check_drift(doc, expect)

    if failures:
        print(f"FAIL: {len(failures)} plan-cache failure(s) in "
              f"{args.plan}:")
        for f_ in failures:
            print(f"  - {f_}")
        if args.expect:
            print(f"hint: {REGEN_HINT}")
        return 1
    n = len(doc.get("entries", []))
    print(f"OK: {args.plan} valid (plan id {doc.get('plan_id')}, "
          f"{n} entries"
          + (", matches committed plan)" if args.expect else ")"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
