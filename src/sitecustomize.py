"""Auto-loaded by the interpreter when src/ is on PYTHONPATH (site.py
imports ``sitecustomize`` from the first path entry that has one).  Installs
the jax version-compat shims before any user code runs, so test subprocess
snippets can call ``jax.make_mesh(..., axis_types=...)`` / ``jax.shard_map``
without importing repro first."""
try:
    import repro.compat  # noqa: F401  (import side effect: compat.install())
except Exception:  # pragma: no cover - never block interpreter startup
    pass
