"""Train-step builders.

``make_train_step`` (default): gradients via jax.grad OUTSIDE shard_map —
the shard_map transpose (VMA-tracked) inserts exactly the right psums for
replicated params, including the subtle token-sharded-norm-weight case; the
optimizer runs as plain jit under GSPMD with ZeRO-1 state sharding.

``make_manual_sync_train_step``: full-manual variant where gradients are
synced explicitly inside shard_map — VMA-aware psum over `model` (only the
grads that actually vary, e.g. token-sharded norm weights), psum over
`data` (fast ICI), then an int8+error-feedback *compressed* psum over `pod`
(the slow DCN hop). tests/test_training.py pins manual == automatic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.build import ModelApi
from repro.training import compression as C
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state, opt_state_specs)


def _batch_specs(batch_like, dp_axes):
    dp = tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]
    return jax.tree.map(lambda _: P(dp), batch_like)


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def make_loss_fn(api: ModelApi, mesh, batch_like):
    """shard_map-wrapped global-mean loss (dp psums inside)."""
    pspec = api.specs()
    bspec = _batch_specs(batch_like, api.pcfg.dp_axes)

    def local_loss(params, batch):
        ls, dn, aux = api.train_loss(params, batch)
        for ax in api.pcfg.dp_axes:
            ls = lax.psum(ls, ax)
            dn = lax.psum(dn, ax)
            aux = lax.pmean(aux, ax)
        return ls / jnp.maximum(dn, 1.0) + aux

    # check_vma=False: the VMA-checked transpose of scan+checkpoint bodies
    # trips a jax error-formatting bug; the unchecked transpose inserts the
    # conservative (correct) psums — tests pin fused==vanilla gradients.
    return jax.shard_map(local_loss, mesh=mesh, in_specs=(pspec, bspec),
                         out_specs=P(), check_vma=False), pspec, bspec


def make_train_step(api: ModelApi, mesh, batch_like, ocfg: AdamWConfig,
                    dp_size: int):
    """Returns (jitted step, init_fn). step(params, opt, batch) ->
    (params, opt, metrics)."""
    loss_sm, pspec, bspec = make_loss_fn(api, mesh, batch_like)

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_sm)(params, batch)
        new_params, new_opt, gnorm = adamw_update(params, grads, opt, ocfg)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    params_like = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    ospec = opt_state_specs(params_like, pspec, api.pcfg.dp_axes, dp_size)
    jstep = jax.jit(step,
                    in_shardings=(_ns(mesh, pspec), _ns(mesh, ospec),
                                  _ns(mesh, bspec)),
                    out_shardings=(_ns(mesh, pspec), _ns(mesh, ospec), None),
                    donate_argnums=(0, 1))

    def init_fn(key):
        params = jax.jit(api.init, out_shardings=_ns(mesh, pspec))(key)
        opt = jax.jit(init_opt_state, out_shardings=_ns(mesh, ospec))(params)
        return params, opt

    return jstep, init_fn


# --------------------------------------------------------------------------
# manual-sync variant (explicit collectives + cross-pod grad compression)
# --------------------------------------------------------------------------

def _vma_psum(g, axis):
    """psum over `axis` iff the value actually varies over it."""
    if axis in jax.typeof(g).vma:
        return lax.psum(g, axis)
    return g


def _spec_has_axis(spec, axis) -> bool:
    return any(e == axis or (isinstance(e, tuple) and axis in e)
               for e in spec)


def _sync_model_axis(grads, pspec, tp_axis):
    """Replicated params used token-/head-sharded (norm weights etc.) need
    their grads psum'd over the model axis; tp-SHARDED param grads are
    per-slice values that must NOT be summed. Spec + VMA decide exactly."""
    def leaf(g, s):
        if _spec_has_axis(s, tp_axis):
            return g
        return _vma_psum(g, tp_axis)
    return jax.tree.map(leaf, grads, pspec)


def _manual_global_norm(grads, pspec, tp_axis):
    """Global grad L2 norm inside shard_map: sharded-leaf sums-of-squares
    psum over model; replicated leaves counted once."""
    ss_sharded = jnp.zeros((), jnp.float32)
    ss_repl = jnp.zeros((), jnp.float32)
    flat_g = jax.tree.leaves(grads)
    flat_s = jax.tree.leaves(pspec, is_leaf=lambda s: isinstance(s, P))
    for g, s in zip(flat_g, flat_s):
        ss = jnp.sum(jnp.square(g.astype(jnp.float32)))
        if _spec_has_axis(s, tp_axis):
            ss_sharded = ss_sharded + ss
        else:
            ss_repl = ss_repl + ss
    # ss_repl is numerically identical on every model shard but formally
    # varying (post-all_gather values); pmean restores VMA invariance
    ss_repl = lax.psum(ss_repl, tp_axis) / lax.axis_size(tp_axis)
    return jnp.sqrt(lax.psum(ss_sharded, tp_axis) + ss_repl)


def make_manual_sync_train_step(api: ModelApi, mesh, batch_like,
                                ocfg: AdamWConfig, *,
                                compress_pod: bool | None = None):
    pspec = api.specs()
    bspec = _batch_specs(batch_like, api.pcfg.dp_axes)
    pcfg = api.pcfg
    has_pod = "pod" in mesh.axis_names
    if compress_pod is None:
        compress_pod = pcfg.grad_compression == "int8" and has_pod
    ospec = {"m": pspec, "v": pspec, "step": P()}
    # error-feedback residuals are PER-POD state: leading pod axis
    efspec = jax.tree.map(lambda s: P("pod", *s) if has_pod else s, pspec,
                          is_leaf=lambda s: isinstance(s, P)) \
        if compress_pod else None

    def step_body(params, opt, ef, batch):
        def body_loss(p):
            ls, dn, aux = api.train_loss(p, batch)
            loss = ls / jnp.maximum(dn, 1.0) + aux
            # The per-shard loss is numerically identical across the model
            # axis but formally *varying* (it flows through all_gather'd
            # activations). Under VMA semantics jax.grad seeds a cotangent
            # on every shard's copy, scaling grads by tp; pmean over the
            # model axis expresses the loss once and fixes the seed.
            return lax.pmean(loss, pcfg.tp_axis)

        loss, grads = jax.value_and_grad(body_loss)(params)
        for ax in pcfg.dp_axes:
            loss = lax.pmean(loss, ax)
        # 1. model axis: only replicated params whose grads vary
        #    (token-sharded norm-weight use); sharded slices stay local
        grads = _sync_model_axis(grads, pspec, pcfg.tp_axis)
        # 2. fast intra-pod data reduce
        grads = jax.tree.map(lambda g: _vma_psum(g, "data"), grads)
        # 3. slow cross-pod hop, optionally int8-compressed w/ error feedback
        if has_pod:
            if compress_pod:
                ef_in = jax.tree.map(lambda e: jnp.squeeze(e, 0), ef)
                grads, ef_out = C.compress_grads(grads, "pod", ef_in)
                ef = jax.tree.map(lambda e: e[None], ef_out)
            else:
                grads = jax.tree.map(lambda g: _vma_psum(g, "pod"), grads)
        # grads divide by the global token denominator already (body_loss is
        # a per-shard mean); rescale to the global mean: each dp shard's
        # loss averaged its own tokens, so the psum'd grad is dp_size times
        # the global-mean grad
        n_dp = 1
        for ax in pcfg.dp_axes:
            n_dp *= lax.axis_size(ax)
        # pre-VMA jax transposes the body_loss pmean by broadcasting the
        # full cotangent to every model shard (instead of the VMA 1/tp
        # seed), so every grad leaf comes out exactly tp x too large
        from repro import compat
        norm = n_dp if compat.HAS_VMA else n_dp * lax.axis_size(pcfg.tp_axis)
        grads = jax.tree.map(lambda g: g / norm, grads)
        gnorm = _manual_global_norm(grads, pspec, pcfg.tp_axis)
        new_params, new_opt, _ = adamw_update(params, grads, opt, ocfg,
                                              gnorm=gnorm)
        out = (new_params, new_opt, {"loss": loss, "grad_norm": gnorm})
        if compress_pod:
            return out + (ef,)
        return out

    in_specs = [pspec, ospec, efspec, bspec] if compress_pod else \
        [pspec, ospec, None, bspec]
    out_specs = (pspec, ospec, P())
    if compress_pod:
        out_specs = out_specs + (efspec,)

    if compress_pod:
        sm = jax.shard_map(step_body, mesh=mesh,
                           in_specs=(pspec, ospec, efspec, bspec),
                           out_specs=out_specs)
        jstep = jax.jit(sm, donate_argnums=(0, 1, 2))
    else:
        def step_noef(params, opt, batch):
            return step_body(params, opt, None, batch)
        sm = jax.shard_map(step_noef, mesh=mesh,
                           in_specs=(pspec, ospec, bspec),
                           out_specs=out_specs)
        jstep = jax.jit(sm, donate_argnums=(0, 1))

    def init_fn(key):
        params = jax.jit(api.init, out_shardings=_ns(mesh, pspec))(key)
        opt = jax.jit(init_opt_state, out_shardings=_ns(mesh, ospec))(params)
        if compress_pod:
            pod = mesh.shape["pod"]
            ef = jax.jit(
                lambda p: jax.tree.map(
                    lambda x: jnp.zeros((pod,) + x.shape, jnp.float32), p),
                out_shardings=_ns(mesh, efspec))(params)
            return params, opt, ef
        return params, opt

    return jstep, init_fn
