"""int8 gradient compression with error feedback for the cross-pod reduce.

At 1000+ node scale the pod-boundary links (DCN) are an order of magnitude
slower than intra-pod ICI; compressing the cross-pod gradient all-reduce 4x
(bf16/f32 -> int8 + per-tensor scale) with error feedback keeps convergence
while shrinking the slow hop. Used by the manual-sync train step
(training/train_step.py) when ParallelConfig.grad_compression == "int8".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _quantize(x):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x, axis, error):
    """psum(x, axis) with int8 quantization + error feedback.

    error: same shape as x (fp32 residual carried across steps).
    Returns (reduced fp32, new_error). The int8 payload is what crosses the
    wire; the scale is a scalar psum (negligible).
    """
    xf = x.astype(jnp.float32) + error
    q, scale = _quantize(xf)
    dq = q.astype(jnp.float32) * scale
    new_error = xf - dq
    # int32 accumulate of int8 payloads, then combine per-device scales.
    # Per-device scales differ, so the exact sum is sum_i(q_i * s_i); we
    # psum(q * s) in fp32 here — the wire format is int8 + one scalar; the
    # fp32 multiply models the receive-side dequantize-accumulate.
    reduced = lax.psum(dq, axis)
    return reduced, new_error


def compress_grads(grads, axis, ef_state):
    """Tree-wise compressed psum; returns (reduced grads, new ef_state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        r, ne = compressed_psum(g, axis, e)
        out_g.append(r.astype(g.dtype))
        out_e.append(ne)
    return jax.tree.unflatten(treedef, out_g), \
        jax.tree.unflatten(treedef, out_e)


def init_ef_state(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)
