"""Sharded AdamW with ZeRO-1-style optimizer-state partitioning.

The optimizer update is elementwise, so it runs as plain jit under GSPMD:
``zero_specs`` extends each parameter's PartitionSpec with the data-parallel
axes on the largest unsharded, divisible dimension. Gradients arrive
dp-replicated (the shard_map transpose already reduced them), XLA
dynamic-slices them against the dp-sharded m/v states, and the updated
params are all-gathered back to replicated — i.e. ZeRO-1 dataflow for free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params):
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def zero_specs(params, pspecs, dp_axes, dp_size: int):
    """Extend each param spec with the (unused) dp axes on a divisible free
    dim. Params already sharded over a dp axis (ep2d MoE experts) only get
    the remaining axes."""
    n_dp = max(len(dp_axes), 1)
    per_axis = max(int(round(dp_size ** (1.0 / n_dp))), 1)

    def extend(p, spec):
        entries = list(spec) + [None] * (p.ndim - len(spec))
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a is not None:
                    used.add(a)
        avail = [a for a in dp_axes if a not in used]
        if not avail:
            return P(*entries)
        div = per_axis ** len(avail) if len(avail) < n_dp else dp_size
        best, best_size = -1, 0
        for i, (dim, s) in enumerate(zip(p.shape, entries)):
            if s is None and dim % div == 0 and dim > best_size:
                best, best_size = i, dim
        if best < 0:
            return P(*entries)
        entries[best] = tuple(avail) if len(avail) > 1 else avail[0]
        return P(*entries)

    return jax.tree.map(extend, params, pspecs,
                        is_leaf=lambda s: isinstance(s, P))


def opt_state_specs(params, pspecs, dp_axes, dp_size: int):
    zs = zero_specs(params, pspecs, dp_axes, dp_size)
    return {"m": zs, "v": zs, "step": P()}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, cfg: AdamWConfig, gnorm=None):
    step = opt_state["step"] + 1
    if gnorm is None:
        gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** step)
        vhat = v2 / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        p2 = p.astype(jnp.float32) * (1 - lr * decay) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in flat])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in flat])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
