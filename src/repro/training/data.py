"""Synthetic LM data pipeline: deterministic, shardable, host-side.

Generates a stationary Markov-chain token stream (learnable structure, so
tiny-model training loss visibly decreases) with per-host sharding by batch
index — the pattern a real pipeline (e.g. grain) would follow.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, order: int = 2):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        rng = np.random.RandomState(seed)
        # sparse-ish transition table -> learnable bigram structure
        self._table = rng.randint(0, vocab, size=(vocab, 4))
        self._seed = seed

    def batch(self, step: int, host_index: int = 0, host_count: int = 1):
        b_local = self.global_batch // host_count
        rng = np.random.RandomState((self._seed, step, host_index))
        toks = np.empty((b_local, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.randint(0, self.vocab, size=b_local)
        choices = rng.randint(0, 4, size=(b_local, self.seq_len))
        noise = rng.random(size=(b_local, self.seq_len)) < 0.05
        rand_tok = rng.randint(0, self.vocab, size=(b_local, self.seq_len))
        for t in range(self.seq_len):
            nxt = self._table[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
