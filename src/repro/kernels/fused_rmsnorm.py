"""Pallas TPU kernel: single-HBM-pass fused residual-add + RMSNorm.

TPU-native adaptation of the local-compute portion of the paper's fused
AllReduce-RMSNorm kernel (Listing 1). The multimem ld_reduce/st become the
surrounding `psum_scatter`/`all_gather` (see core/fused_collectives.py and
kernels/ring_ar_rmsnorm.py for the fully-fused ring form); what this kernel
preserves is the *memory traffic* property:

    unfused:  write r = x+res; read r (variance); read r (scale); write out
              -> 3 reads + 2 writes of the token slice
    fused:    read x, read res; keep t = x+res in VMEM; write res' and out
              -> 2 reads + 2 writes, no HBM round-trip for the intermediate

Token tiles are processed per grid step with the full hidden dim resident in
VMEM (hidden <= 8192 fits a (256, 8192) f32 tile in ~8 MiB; ops.py shrinks the
token tile for wider models).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_rmsnorm_kernel(x_ref, res_ref, w_ref, out_ref, res_out_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    r = res_ref[...].astype(jnp.float32)
    t = x + r
    var = jnp.mean(t * t, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    res_out_ref[...] = t.astype(res_out_ref.dtype)
    w = w_ref[...].astype(jnp.float32)
    out_ref[...] = (t * inv * w[None, :]).astype(out_ref.dtype)


def fused_residual_rmsnorm_pallas(x, residual, weight, *, eps: float = 1e-6,
                                  block_tokens: int = 256,
                                  interpret: bool = False):
    """(out, new_residual) = fused add+norm, tiled over tokens.

    x, residual: (T, d); weight: (d,). T must be a multiple of 8 (sublane
    tile); callers pad. ``interpret=True`` runs the kernel body in Python on
    CPU for validation.
    """
    t_tokens, d = x.shape
    bt = min(block_tokens, t_tokens)
    # keep the fp32 working set (x, t, out ~ 3 tiles) under ~12 MiB of VMEM
    while bt > 8 and 3 * bt * d * 4 > 12 * 2**20:
        bt //= 2
    if t_tokens % bt != 0:
        # fall back to the largest divisor <= bt that is a multiple of 8
        for cand in range(bt, 0, -8):
            if t_tokens % cand == 0:
                bt = cand
                break
        else:
            bt = t_tokens
    grid = (t_tokens // bt,)
    kernel = functools.partial(_fused_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_tokens, d), x.dtype),
            jax.ShapeDtypeStruct((t_tokens, d), residual.dtype),
        ],
        interpret=interpret,
    )(x, residual, weight)
