"""Pallas TPU flash attention (forward) for the overlapped compute path.

Blockwise softmax attention with GQA grouping, causal + sliding-window
masking via position arrays (so chunked prefill / TokenWeave suffix splits
with arbitrary offsets work unchanged). The kernel keeps the running
(m, l, acc) statistics in VMEM scratch across the kv-block grid dimension —
the logits tile never touches HBM, which is exactly the traffic the pure-jnp
chunked path pays (see EXPERIMENTS.md §Perf iteration on the memory term).

Grid: (num_q_blocks, num_kv_blocks), kv minor (sequential on TPU, so the
scratch carries across kv steps for a fixed q block). Batch and KV-head
dims are vmapped over the kernel.

Validated against kernels/ref.flash_attention_ref in interpret mode across
shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, causal, window, sm_scale,
                  num_kv_blocks):
    kv_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)        # (bq, g, dh)
    k = k_ref[...].astype(jnp.float32)        # (bk, dh)
    v = v_ref[...].astype(jnp.float32)        # (bk, dh)
    qp = qpos_ref[...]                        # (bq,)
    kp = kpos_ref[...]                        # (bk,)

    logits = jnp.einsum("qgd,kd->qgk", q, k) * sm_scale
    mask = kp[None, :] >= 0
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window > 0:
        mask &= (qp[:, None] - kp[None, :]) < window
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)

    m_prev = m_ref[...]                       # (bq, g)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "qgk,kd->qgd", p, v)
    m_ref[...] = m_new

    @pl.when(kv_idx == num_kv_blocks - 1)
    def _finish():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)[..., None]
                      ).astype(o_ref.dtype)


def _flash_single(q, k, v, qpos, kpos, *, causal, window, sm_scale,
                  block_q, block_kv, interpret):
    """q: (Sq, G, dh); k/v: (Sk, dh); qpos (Sq,), kpos (Sk,)."""
    sq, g, dh = q.shape
    sk = k.shape[0]
    bq = min(block_q, sq)
    bk = min(block_kv, sk)
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, pq), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, (0, pq), constant_values=0)
    if pk:
        k = jnp.pad(k, ((0, pk), (0, 0)))
        v = jnp.pad(v, ((0, pk), (0, 0)))
        kpos = jnp.pad(kpos, (0, pk), constant_values=-1)
    nq, nk = (sq + pq) // bq, (sk + pk) // bk

    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               sm_scale=sm_scale, num_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bq, g, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bk, dh), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, dh), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, g, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((sq + pq, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, g), jnp.float32),       # running max
            pltpu.VMEM((bq, g), jnp.float32),       # running denom
            pltpu.VMEM((bq, g, dh), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(qpos, kpos, q, k, v)
    return out[:sq]


def flash_attention(q, k, v, qpos, kpos, *, causal: bool, window: int = 0,
                    sm_scale: float | None = None, block_q: int = 512,
                    block_kv: int = 1024, interpret: bool = False):
    """q: (B, Sq, KVH, G, dh); k/v: (B, Sk, KVH, dh); positions (B, S*)."""
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    fn = functools.partial(_flash_single, causal=causal, window=window,
                           sm_scale=sm_scale, block_q=block_q,
                           block_kv=block_kv, interpret=interpret)
    fn_h = jax.vmap(fn, in_axes=(0, 0, 0, None, None))   # over KV heads
    fn_b = jax.vmap(fn_h, in_axes=(0, 0, 0, 0, 0))       # over batch
    qr = jnp.moveaxis(q, 2, 1)      # (B, KVH, Sq, G, dh)
    kr = jnp.moveaxis(k, 2, 1)      # (B, KVH, Sk, dh)
    vr = jnp.moveaxis(v, 2, 1)
    out = fn_b(qr, kr, vr, qpos, kpos)   # (B, KVH, Sq, G, dh)
    return jnp.moveaxis(out, 1, 2)       # (B, Sq, KVH, G, dh)
