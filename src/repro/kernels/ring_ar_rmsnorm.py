"""TPU-native ring fused AllReduce-RMSNorm kernel (paper Listing 1 analogue).

The paper's H100 kernel rides NVSwitch multimem: ld_reduce pulls the
reduced value, the norm happens in registers, multimem.st broadcasts the
result — one kernel, minimal HBM traffic, 2-8 SMs. TPU has no switch
multicast; the native analogue is a *ring* schedule on ICI driven by async
remote DMAs, which likewise leaves the compute units almost entirely free:

  phase 1  ring reduce-scatter: N-1 hops; the hop arriving at its owner is
           accumulated IN VMEM and never round-trips to HBM
  phase 2  fused residual-add + RMSNorm on the owned 1/N token chunk,
           still in VMEM (the paper's lines 23-37)
  phase 3  ring all-gather of the normed chunks

Chunk ownership matches `lax.psum_scatter(..., tiled=True)`: device r ends
up owning rows [r*C, (r+1)*C), so this kernel is a drop-in for the
psum_scatter/all_gather pair in core.fused_collectives — and IS dispatched
there on the serving hot path (``comm_norm`` mode="ring", DESIGN.md §2)
whenever the backend supports it, falling back to that composition
otherwise.

The ``channels`` knob is the TPU analogue of the paper's 2-8 SM resource
grant: it sizes the in-flight comm-slot ring lanes (HBM staging slots +
their semaphores), mapped from a plan entry's SM-equivalent ``budget`` by
``core.splitting.ring_channels`` (DESIGN.md §14).

Numerics are pinned against kernels/ref.ring_ar_rmsnorm_ref and the
unfused vanilla composition by tests/test_fused_path.py (in-process and
subprocess-distributed); on backends whose Pallas interpreter cannot
emulate remote DMAs (jax < 0.5 CPU) the ring mode gates to the fallback
composition instead of running this kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_hbm, res_ref, w_ref, out_hbm, res_out_ref, comm_hbm,
            acc_vmem, send_vmem, chunk_vmem, send_sem, recv_sem, free_sem,
            *, n_dev: int, chunk: int, eps: float, axis_name: str,
            channels: int):
    me = jax.lax.axis_index(axis_name)
    right = jax.lax.rem(me + 1, n_dev)
    left = jax.lax.rem(me - 1 + n_dev, n_dev)

    def dma_in(idx, dst):
        """x_hbm[idx*chunk : (idx+1)*chunk] -> dst (VMEM)."""
        cp = pltpu.make_async_copy(x_hbm.at[pl.ds(idx * chunk, chunk)], dst,
                                   send_sem.at[2])
        cp.start()
        cp.wait()

    # ---- phase 1: ring reduce-scatter -----------------------------------
    # chunk c starts at device (c+1)%N and travels right, ending at c.
    first = jax.lax.rem(me - 1 + n_dev, n_dev)
    dma_in(first, send_vmem)
    for s in range(n_dev - 1):
        slot = s % channels
        # wait until the receiver freed this comm slot (steps >= channels)
        if s >= channels:
            pltpu.semaphore_wait(free_sem.at[slot], 1)
        rcp = pltpu.make_async_remote_copy(
            src_ref=send_vmem,
            dst_ref=comm_hbm.at[slot],
            send_sem=send_sem.at[0], recv_sem=recv_sem.at[slot],
            device_id=(right,), device_id_type=pltpu.DeviceIdType.MESH)
        rcp.start()
        rcp.wait()
        # arrival of chunk (me - s - 2) from the left neighbor
        cp = pltpu.make_async_copy(comm_hbm.at[slot], acc_vmem,
                                   send_sem.at[1])
        cp.start()
        cp.wait()
        # slot consumed: free it for the left neighbor (phase-1 tail signals
        # are drained by phase-3's first two sends — see pairing note below)
        pltpu.semaphore_signal(free_sem.at[slot], 1, device_id=(left,),
                               device_id_type=pltpu.DeviceIdType.MESH)
        idx = jax.lax.rem(me - s - 2 + 2 * n_dev, n_dev)
        dma_in(idx, chunk_vmem)
        if s < n_dev - 2:
            send_vmem[...] = acc_vmem[...] + chunk_vmem[...]
    # after the loop: acc + own contribution = fully reduced chunk `me`
    t = (acc_vmem[...] + chunk_vmem[...]).astype(jnp.float32) \
        if n_dev > 1 else 0.0

    # ---- phase 2: fused residual add + RMSNorm (VMEM, paper lines 23-37) -
    if n_dev == 1:
        dma_in(0, chunk_vmem)
        t = chunk_vmem[...].astype(jnp.float32)
    t = t + res_ref[...].astype(jnp.float32)
    var = jnp.mean(t * t, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    res_out_ref[...] = t.astype(res_out_ref.dtype)
    normed = (t * inv * w_ref[...].astype(jnp.float32)[None, :])
    send_vmem[...] = normed.astype(send_vmem.dtype)

    # write own chunk to the output
    wcp = pltpu.make_async_copy(send_vmem, out_hbm.at[pl.ds(me * chunk,
                                                            chunk)],
                                send_sem.at[2])
    wcp.start()
    wcp.wait()

    # ---- phase 3: ring all-gather of normed chunks ----------------------
    # semaphore pairing (k = channels): each device emits N-1 phase-1 free
    # signals; N-1-k are consumed by phase-1 sends (s >= k) and the final k
    # by phase-3's first k sends, which guarantees the receiver has drained
    # its phase-1 slots before phase-3 data lands (no cross-phase race).
    # Phase-3 emits its own signals only while a later sender still waits
    # (s + k < N-1), so all semaphores end at zero for ANY k in [1, N-1].
    for s in range(n_dev - 1):
        slot = s % channels
        pltpu.semaphore_wait(free_sem.at[slot], 1)
        rcp = pltpu.make_async_remote_copy(
            src_ref=send_vmem,
            dst_ref=comm_hbm.at[slot],
            send_sem=send_sem.at[0], recv_sem=recv_sem.at[slot],
            device_id=(right,), device_id_type=pltpu.DeviceIdType.MESH)
        rcp.start()
        rcp.wait()
        cp = pltpu.make_async_copy(comm_hbm.at[slot], chunk_vmem,
                                   send_sem.at[1])
        cp.start()
        cp.wait()
        if s + channels < n_dev - 1:
            pltpu.semaphore_signal(free_sem.at[slot], 1, device_id=(left,),
                                   device_id_type=pltpu.DeviceIdType.MESH)
        idx = jax.lax.rem(me - s - 1 + 2 * n_dev, n_dev)
        ocp = pltpu.make_async_copy(chunk_vmem,
                                    out_hbm.at[pl.ds(idx * chunk, chunk)],
                                    send_sem.at[2])
        ocp.start()
        ocp.wait()
        send_vmem[...] = chunk_vmem[...]


def ring_fused_ar_rmsnorm(x, residual, weight, *, axis_name: str,
                          n_dev: int, eps: float = 1e-6,
                          interpret: bool = False, channels: int = 2):
    """Inside shard_map over `axis_name` (size n_dev).

    x: (T, d) per-device partial sums; residual: (T//n_dev, d) own token
    slice; weight: (d,). Returns (normed_full (T, d), new_residual).

    ``channels`` = in-flight ring comm lanes (the SM-equivalent resource
    grant; see module docstring). Clamped to [1, n_dev-1] — more lanes
    than ring hops buys nothing.
    """
    t_tokens, d = x.shape
    assert t_tokens % n_dev == 0
    chunk = t_tokens // n_dev
    channels = max(1, min(int(channels), max(n_dev - 1, 1)))
    kernel = functools.partial(_kernel, n_dev=n_dev, chunk=chunk, eps=eps,
                               axis_name=axis_name, channels=channels)
    out, new_res, _ = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),           # x (HBM)
            pl.BlockSpec((chunk, d), lambda: (0, 0)),    # residual (VMEM)
            pl.BlockSpec((d,), lambda: (0,)),            # weight
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pl.ANY),           # normed out (HBM)
            pl.BlockSpec((chunk, d), lambda: (0, 0)),    # new residual
            pl.BlockSpec(memory_space=pl.ANY),           # comm buffer (HBM)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_tokens, d), x.dtype),
            jax.ShapeDtypeStruct((chunk, d), residual.dtype),
            jax.ShapeDtypeStruct((channels, chunk, d), x.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((chunk, d), x.dtype),             # acc
            pltpu.VMEM((chunk, d), x.dtype),             # send
            pltpu.VMEM((chunk, d), x.dtype),             # chunk in
            pltpu.SemaphoreType.DMA((3,)),
            pltpu.SemaphoreType.DMA((channels,)),
            pltpu.SemaphoreType.REGULAR((channels,)),
        ],
        compiler_params=getattr(pltpu, "CompilerParams",
                                getattr(pltpu, "TPUCompilerParams", None)
                                )(collective_id=7),
        # older pallas has no InterpretParams dataclass; plain True selects
        # the same interpreter
        interpret=(pltpu.InterpretParams()
                   if hasattr(pltpu, "InterpretParams") else True)
        if interpret else False,
    )(x, residual, weight)
    return out, new_res
