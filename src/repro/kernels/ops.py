"""jit'd dispatchers that select Pallas kernels (TPU target) or the pure-jnp
fallback (CPU container / dry-run lowering, mathematically identical)."""
from __future__ import annotations

from repro.kernels import ref as kref
from repro.kernels.fused_rmsnorm import fused_residual_rmsnorm_pallas


def fused_residual_rmsnorm(x, residual, weight, *, eps: float = 1e-6,
                           use_pallas: bool = False, interpret: bool = False):
    """Single-pass residual+RMSNorm. Returns (normed, new_residual).

    The jnp fallback expresses the same single-pass dataflow (t stays live,
    both outputs derived from it) so XLA fusion on any backend keeps the
    memory-traffic property the kernel encodes explicitly.
    """
    if use_pallas:
        return fused_residual_rmsnorm_pallas(
            x, residual, weight, eps=eps, interpret=interpret)
    return kref.fused_residual_rmsnorm_ref(x, residual, weight, eps)
