# Pallas TPU kernels for the paper's compute hot-spots:
#   fused_rmsnorm      - single-HBM-pass residual-add + RMSNorm (paper Listing 1,
#                        local compute portion)
#   flash_attention    - blockwise attention used by the overlapped compute path
#   ring_ar_rmsnorm    - TPU-native ring ReduceScatter+RMSNorm+AllGather
# Each kernel has a pure-jnp oracle in ref.py and a jit'd dispatcher in ops.py.
