"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_residual_rmsnorm_ref(x, residual, weight, eps: float = 1e-6):
    """Oracle for kernels/fused_rmsnorm.py.

    Matches paper Listing 1 lines 23-26 + 34-37 (minus the multimem ld/st):
        t = x + residual            (x = arriving reduced partial)
        var = mean(t^2)             (fp32)
        out = t * rsqrt(var+eps) * weight
        new_residual = t
    """
    xf = x.astype(jnp.float32)
    rf = residual.astype(jnp.float32)
    t = xf + rf
    var = jnp.mean(t * t, axis=-1, keepdims=True)
    out = t * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype), t.astype(residual.dtype)


def flash_attention_ref(q, k, v, *, causal: bool, window: int = 0,
                        q_offset: int = 0, sm_scale: float | None = None):
    """Oracle for kernels/flash_attention.py.

    q: (Tq, Hq, dh); k, v: (Tk, Hkv, dh). GQA via head repetition.
    ``q_offset`` is the absolute position of q[0] within the kv context
    (chunked attention: the suffix split passes offset = len(prefix)).
    ``window`` > 0 masks keys older than ``window`` positions (sliding).
    """
    tq, hq, dh = q.shape
    tk, hkv, _ = k.shape
    if sm_scale is None:
        sm_scale = dh ** -0.5
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * sm_scale
    qpos = q_offset + jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("hqk,khd->qhd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def _chunk_owner(rank: int, n_dev: int) -> int:
    """Ring chunk-ownership contract: which token chunk device ``rank``
    norms.  MUST match ``lax.psum_scatter(..., tiled=True)`` — device r
    owns rows [r*C, (r+1)*C) — or the ring kernel's output disagrees with
    the psum_scatter/all_gather composition it substitutes for.  Kept as
    a named function so the fused-path fault-injection test can plant a
    wrong-ownership schedule and prove the numerics pin catches it."""
    return rank % n_dev


def ring_ar_rmsnorm_ref(shards, residual_shards, weight, eps: float = 1e-6):
    """Oracle for kernels/ring_ar_rmsnorm.py.

    ``shards``: list of N per-device partial-sum arrays (T, d) (identical
    shapes); ``residual_shards``: list of N arrays (T//N, d) — each device's
    private token slice of the residual stream. Returns (list of N identical
    normed (T, d) outputs, list of N updated residual shards), i.e. the
    semantics of AllReduce followed by residual+RMSNorm, computed the
    TokenWeave way (RS -> norm on 1/N tokens -> AG).
    """
    n = len(shards)
    total = sum(s.astype(jnp.float32) for s in shards)
    t_tokens = total.shape[0]
    shard_len = t_tokens // n
    new_residuals, normed = [], [None] * n
    for i in range(n):
        own = _chunk_owner(i, n)
        sl = total[own * shard_len:(own + 1) * shard_len]
        out, new_r = fused_residual_rmsnorm_ref(
            sl.astype(shards[0].dtype), residual_shards[i], weight, eps)
        normed[own] = out
        new_residuals.append(new_r)
    full = jnp.concatenate(normed, axis=0)
    return [full for _ in range(n)], new_residuals
