"""Axis helpers used inside ``jax.shard_map`` bodies.

All model code runs fully-manual inside shard_map; these helpers make the
axis arithmetic uniform (and degrade to identities on 1-sized axes, which is
how single-device CPU tests exercise the exact same code path).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
from jax import lax


def axis_size(name) -> int:
    return lax.axis_size(name)


def axis_index(name):
    return lax.axis_index(name)


@dataclasses.dataclass(frozen=True)
class CommCtx:
    """Everything the fused collective ops need to know about the layout."""
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("data",)
    mode: str = "fused"            # vanilla | reordered | fused | ring | nocomm
    eps: float = 1e-6
    use_pallas: bool = False
    interpret: bool = False        # pallas interpret mode (CPU validation)
    bf16_wire: bool = False        # pin collective dtype (see ParallelConfig)
    comm_budget: float = 1.0       # SM-equivalent fraction -> ring channels

    @property
    def sharded_residual(self) -> bool:
        """fused/reordered/ring keep the residual stream token-sharded
        over TP."""
        return self.mode in ("fused", "reordered", "ring")

    def tp_size(self) -> int:
        return lax.axis_size(self.tp_axis)

    def tp_index(self):
        return lax.axis_index(self.tp_axis)


def token_shard_slice(x: jnp.ndarray, ctx: CommCtx) -> jnp.ndarray:
    """Slice this TP shard's token range out of a token-replicated array."""
    tp = ctx.tp_size()
    if tp == 1:
        return x
    shard = x.shape[0] // tp
    return lax.dynamic_slice_in_dim(x, ctx.tp_index() * shard, shard, axis=0)


def psum_dp(x, ctx: CommCtx):
    """All-reduce over every data-parallel axis (grad sync)."""
    for ax in ctx.dp_axes:
        x = lax.psum(x, ax)
    return x
