"""Uniform model API over the four family implementations."""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import encdec, hybrid, mamba_model, transformer


_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba_model,
    "hybrid": hybrid,
    "encdec": encdec,
}


@dataclasses.dataclass(frozen=True)
class ModelApi:
    """Bound functional API; every method is meant to run inside shard_map
    (except init/specs helpers which are pure host-side)."""
    cfg: ModelConfig
    pcfg: ParallelConfig
    mod: Any
    tp: int
    ep: int = 1

    # ---- host-side ------------------------------------------------------
    def init(self, key):
        return self.mod.init_params(key, self.cfg, self.pcfg, self.tp,
                                    self.ep)

    def specs(self):
        return self.mod.param_specs(self.cfg, self.pcfg)

    def init_cache(self, batch: int, max_len: int, **kw):
        if self.mod is transformer:
            return transformer.init_cache(batch, max_len, self.cfg, self.tp,
                                          self.pcfg)
        return self.mod.init_cache(batch, max_len, self.cfg, self.tp, **kw)

    def cache_specs(self, batch1: bool = False):
        if self.mod is transformer:
            return transformer.cache_specs(self.cfg, self.pcfg, batch1)
        return self.mod.cache_specs(self.cfg, self.pcfg, batch1)

    # ---- inside shard_map -----------------------------------------------
    def train_loss(self, params, batch):
        return self.mod.train_loss(params, batch, cfg=self.cfg,
                                   pcfg=self.pcfg)

    def prefill(self, params, batch, cache, **kw):
        if self.mod is encdec:
            return encdec.prefill(params, batch, cache, cfg=self.cfg,
                                  pcfg=self.pcfg, **kw)
        return self.mod.prefill(params, batch["tokens"], cache, cfg=self.cfg,
                                pcfg=self.pcfg,
                                positions=batch.get("positions"),
                                **({k: v for k, v in batch.items()
                                    if k in ("mrope_positions",
                                             "extra_embeds")}
                                   if self.mod is transformer else {}), **kw)

    def decode_step(self, params, tokens, cache, positions, **kw):
        return self.mod.decode_step(params, tokens, cache, cfg=self.cfg,
                                    pcfg=self.pcfg, positions=positions, **kw)

    def verify_step(self, params, tokens, cache, positions, **kw):
        """Speculative multi-token verify (transformer families only)."""
        return self.mod.verify_step(params, tokens, cache, cfg=self.cfg,
                                    pcfg=self.pcfg, positions=positions, **kw)

    def packed_step(self, params, tokens, cache, positions, **kw):
        """Packed mixed-segment hybrid step (transformer families only,
        DESIGN.md §6)."""
        return self.mod.packed_step(params, tokens, cache, cfg=self.cfg,
                                    pcfg=self.pcfg, positions=positions, **kw)


def build_model(cfg: ModelConfig, pcfg: ParallelConfig, tp: int,
                ep: int = 1) -> ModelApi:
    if cfg.family not in _FAMILY:
        raise KeyError(f"unknown family {cfg.family!r}")
    return ModelApi(cfg=cfg, pcfg=pcfg, mod=_FAMILY[cfg.family], tp=tp, ep=ep)
