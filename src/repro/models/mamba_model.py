"""Falcon-Mamba: attention-free Mamba-1 stack with TokenWeave weaving.

TokenWeave transfers directly (DESIGN.md §4): every block is token-level
except the recurrence, whose split dependency is the prefix's final
(conv, ssm) state — the suffix split starts its scan there, exactly like the
KV-prefix in chunked attention. Each block ends in a row-parallel out_proj,
so the fused AllReduce-RMSNorm slot appears once per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import fused_collectives as fc
from repro.layers import embedding as E
from repro.layers import ssm as S
from repro.models.transformer import _comm_ctx, _decide_split, _entry_norm


def init_params(key, cfg: ModelConfig, pcfg: ParallelConfig, tp: int,
                ep: int = 1):
    ke, kl = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    layers = []
    for k in jax.random.split(kl, cfg.num_layers):
        layers.append({
            "mamba": S.init_mamba1_params(k, cfg, tp),
            "norm_out": jnp.ones((1, cfg.d_model), dtype),  # next block's norm
        })
    layers = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "embedding": E.init_embedding_params(ke, cfg, tp),
        "norm_first": jnp.ones((1, cfg.d_model), dtype),
        "layers": layers,
    }


def param_specs(cfg: ModelConfig, pcfg: ParallelConfig):
    from jax.sharding import PartitionSpec as P
    ls = {"mamba": S.mamba1_param_specs(cfg), "norm_out": P(None)}
    layers = jax.tree.map(lambda s: P(None, *s), ls,
                          is_leaf=lambda s: isinstance(s, P))
    return {"embedding": E.embedding_param_specs(cfg),
            "norm_first": P(None), "layers": layers}


def _block(lp, h, res, *, cfg, ctx, init_state, chunk):
    partial, state = S.mamba1_forward(lp["mamba"], h, cfg=cfg,
                                      tp_axis=ctx.tp_axis,
                                      init_state=init_state, chunk=chunk)
    b, s, d = h.shape
    h_flat, res = fc.comm_norm(partial.reshape(b * s, d), res,
                               lp["norm_out"][0], ctx=ctx)
    return h_flat.reshape(b, s, d), res, state


def forward(params, tokens, *, cfg: ModelConfig, pcfg: ParallelConfig,
            positions=None, cache=None, decode: bool = False,
            return_kv: bool = True, ssm_chunk: int = 256):
    """Returns (hidden (B,S,d), new_state_cache, aux=0).

    cache: (conv_state (L,B,K-1,dil), ssm_state (L,B,dil,n)) — both the
    decode state and the chunked-prefill carry.
    """
    tp = lax.axis_size(pcfg.tp_axis)
    b, s = tokens.shape
    ctx = _comm_ctx(pcfg, cfg, b * s, tp)
    emb = E.embed_tokens(params["embedding"], tokens, tp_axis=ctx.tp_axis,
                         scale=cfg.embed_scale)
    w_first = params["norm_first"][0]

    split = _decide_split(b, s, tp=tp, pcfg=pcfg, decode=decode)
    if split is not None and not decode:
        s1, _ = split
        embs = [emb[:, :s1], emb[:, s1:]]
    elif split is not None and decode:
        b1, _ = split
        embs = [emb[:b1], emb[b1:]]
        split_batch = b1
    else:
        embs = [emb]
    n = len(embs)

    hs, ress = [], []
    for e in embs:
        h_i, r_i = _entry_norm(e, w_first, ctx)
        hs.append(h_i)
        ress.append(r_i)

    def body(carry, xs):
        hs, ress = carry
        lp, st = xs
        new_h, new_r, out_states = list(hs), list(ress), []
        if decode and n == 2:
            sts = jax.tree.map(lambda c: c[:split_batch], st), \
                  jax.tree.map(lambda c: c[split_batch:], st)
        else:
            sts = [st] * n
        prev_final = None
        for i in range(n):
            if decode or (cache is not None):
                init_state = sts[i] if (decode or i == 0) else None
            else:
                init_state = None
            if not decode and i > 0:
                # suffix split resumes from the prefix's final state
                init_state = prev_final
            h_i, r_i, state_i = _block(lp, hs[i], ress[i], cfg=cfg, ctx=ctx,
                                       init_state=init_state,
                                       chunk=1 if decode else ssm_chunk)
            new_h[i], new_r[i] = h_i, r_i
            prev_final = state_i
            out_states.append(state_i)
        if n == 2:
            if decode:
                st_out = jax.tree.map(lambda a, b_: jnp.concatenate([a, b_], 0),
                                      out_states[0], out_states[1])
            else:
                st_out = out_states[-1]  # final state after both splits
        else:
            st_out = out_states[0]
        return (new_h, new_r), st_out

    if cache is not None:
        (hs, ress), states = lax.scan(body, (hs, ress),
                                      (params["layers"], cache))
    else:
        def body_nc(carry, lp):
            # fresh state: mamba1_forward builds zeros when init_state None
            return body(carry, (lp, None))
        bodyfn = body_nc
        if pcfg.remat and not decode:
            bodyfn = jax.checkpoint(
                bodyfn, policy=jax.checkpoint_policies.nothing_saveable)
        (hs, ress), states = lax.scan(bodyfn, (hs, ress), params["layers"])

    h_out = jnp.concatenate(hs, axis=0 if decode else 1) if n == 2 else hs[0]
    return h_out, states, jnp.zeros((), jnp.float32)


def train_loss(params, batch, *, cfg, pcfg, aux_weight: float = 0.0):
    h, _, aux = forward(params, batch["tokens"], cfg=cfg, pcfg=pcfg,
                        return_kv=False)
    logits = E.lm_head_logits(params["embedding"], h)
    loss_sum, denom = E.sharded_softmax_xent(
        logits, batch["labels"], vocab_size=cfg.vocab_size,
        tp_axis=pcfg.tp_axis)
    return loss_sum, denom, aux


def prefill(params, tokens, cache, *, cfg, pcfg, positions=None,
            last_idx=None, **_):
    h, states, aux = forward(params, tokens, cfg=cfg, pcfg=pcfg, cache=cache)
    if last_idx is None:
        h_last = h[:, -1:]
    else:
        h_last = jnp.take_along_axis(h, last_idx[:, None, None], axis=1)
    logits = E.lm_head_logits(params["embedding"], h_last)
    return logits, states, aux


def decode_step(params, tokens, cache, *, cfg, pcfg, positions=None, **_):
    h, states, _ = forward(params, tokens, cfg=cfg, pcfg=pcfg, cache=cache,
                           decode=True)
    logits = E.lm_head_logits(params["embedding"], h)
    return logits, states


def init_cache(batch: int, max_len: int, cfg: ModelConfig, tp: int):
    return S.init_mamba1_state(batch, cfg, tp, cfg.num_layers)


def cache_specs(cfg: ModelConfig, pcfg: ParallelConfig,
                batch1: bool = False):
    from jax.sharding import PartitionSpec as P
    b = None if batch1 else tuple(pcfg.dp_axes)
    return (P(None, b, None, "model"), P(None, b, "model", None))
