"""Decoder-only transformer stack (dense / MoE / VLM families) with the
TokenWeave two-split weave built into the layer execution.

Everything in this module is written to run INSIDE ``jax.shard_map``: weights
carry a leading per-shard axis (size tp or 1), collectives are explicit, and
the AllReduce+RMSNorm slots go through ``core.fused_collectives.comm_norm``.

The weave (paper Fig. 8): with two token-splits s0/s1, ops are emitted in the
order

    attn(s0) ; AR-norm(s0) ; attn(s1) ; AR-norm(s1) ;
    ffn(s0)  ; AR-norm(s0) ; ffn(s1)  ; AR-norm(s1)

so each collective is data-independent of the compute op that follows it —
XLA's latency-hiding scheduler turns the collectives into start/done pairs
that overlap with the adjacent split's compute. The suffix split's attention
takes the prefix split's KV as ``kv_prefix`` (chunked attention, §3.1), and
the residual stream stays token-sharded across TP throughout (§3.2).

Residual-ordering invariant: each split's residual is created *in that
split's own flattened token order* (the split happens before the first
comm_norm), so every psum_scatter/all_gather pair within a split is
self-consistent and no cross-shard re-distribution is ever needed.

Norm-weight convention (off-by-one, like vLLM's fused add+norm): layer i's
post-FFN comm_norm applies layer i+1's input norm; ``norm_ffn`` of the last
layer is the final norm; ``params['norm_first']`` is layer 0's input norm,
applied by the embedding-side comm_norm.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import fused_collectives as fc
from repro.core.splitting import split_decision, token_bucket  # noqa: F401
#   (split_decision re-exported: tests + obs treat this module as the
#    decision surface; the actual dispatch goes through the overlap
#    policy, DESIGN.md §14)
from repro.distributed.context import CommCtx
from repro.layers import attention as A
from repro.layers import embedding as E
from repro.layers import mlp as M
from repro.layers import moe as X


# --------------------------------------------------------------------------
# layer kinds (gemma3 local/global pattern etc.)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerKind:
    window: int          # 0 = full attention
    theta: float
    is_moe: bool = False


def layer_kinds(cfg: ModelConfig) -> List[LayerKind]:
    kinds = []
    for i in range(cfg.num_layers):
        if cfg.local_global_period:
            # gemma3: (period-1) local layers then 1 global, repeating
            is_global = (i % cfg.local_global_period) == cfg.local_global_period - 1
            kinds.append(LayerKind(
                window=0 if is_global else cfg.sliding_window,
                theta=cfg.rope_theta if is_global else
                (cfg.rope_theta_local or cfg.rope_theta),
                is_moe=cfg.is_moe))
        else:
            kinds.append(LayerKind(window=cfg.sliding_window,
                                   theta=cfg.rope_theta, is_moe=cfg.is_moe))
    return kinds


def uniform_kinds(cfg: ModelConfig) -> bool:
    ks = layer_kinds(cfg)
    return all(k == ks[0] for k in ks)


def use_scan(cfg: ModelConfig, pcfg: ParallelConfig) -> bool:
    return pcfg.scan_layers and uniform_kinds(cfg)


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init_layer_params(key, cfg: ModelConfig, tp: int, ep: int = 1):
    ka, kf = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    p = {
        "attn": A.init_attention_params(ka, cfg, tp),
        "norm_attn": jnp.ones((1, cfg.d_model), dtype),
        "norm_ffn": jnp.ones((1, cfg.d_model), dtype),
    }
    if cfg.sandwich_norms:
        p["norm_attn_post"] = jnp.ones((1, cfg.d_model), dtype)
        p["norm_ffn_post"] = jnp.ones((1, cfg.d_model), dtype)
    if cfg.is_moe:
        p["moe"] = X.init_moe_params(kf, cfg, tp, ep)
    else:
        p["mlp"] = M.init_mlp_params(kf, cfg, tp)
    return p


def layer_param_specs(cfg: ModelConfig):
    from jax.sharding import PartitionSpec as P
    specs = {
        "attn": A.attention_param_specs(cfg),
        "norm_attn": P(None),
        "norm_ffn": P(None),
    }
    if cfg.sandwich_norms:
        specs["norm_attn_post"] = P(None)
        specs["norm_ffn_post"] = P(None)
    if cfg.is_moe:
        specs["moe"] = X.moe_param_specs(cfg)
    else:
        specs["mlp"] = M.mlp_param_specs(cfg)
    return specs


def init_params(key, cfg: ModelConfig, pcfg: ParallelConfig, tp: int,
                ep: int = 1):
    ke, kl = jax.random.split(key)
    layers = [init_layer_params(k, cfg, tp, ep)
              for k in jax.random.split(kl, cfg.num_layers)]
    if use_scan(cfg, pcfg):
        layers = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    else:
        layers = {f"layer_{i}": lp for i, lp in enumerate(layers)}
    return {
        "embedding": E.init_embedding_params(ke, cfg, tp),
        "norm_first": jnp.ones((1, cfg.d_model), jnp.dtype(cfg.dtype)),
        "layers": layers,
    }


def param_specs(cfg: ModelConfig, pcfg: ParallelConfig):
    from jax.sharding import PartitionSpec as P
    ls = layer_param_specs(cfg)
    if use_scan(cfg, pcfg):
        layers = jax.tree.map(lambda s: P(None, *s), ls,
                              is_leaf=lambda s: isinstance(s, P))
    else:
        layers = {f"layer_{i}": ls for i in range(cfg.num_layers)}
    return {"embedding": E.embedding_param_specs(cfg),
            "norm_first": P(None), "layers": layers}


# --------------------------------------------------------------------------
# single-layer body (one split)
# --------------------------------------------------------------------------

def _layer_split(lp, h, res, *, positions, mrope_positions, kind: LayerKind,
                 cfg, pcfg, ctx: CommCtx, lay, kv_prefix, cache_layer,
                 decode: bool, block_tables=None, packed_slots=None):
    """One transformer layer on one token-split.

    Returns (h_next, res, new_kv or new_cache_layer, aux).
    """
    aux = jnp.zeros((), jnp.float32)
    if packed_slots is not None:
        # packed mixed-segment step (DESIGN.md §6): cache_layer is the FULL
        # slot cache (or one layer of the paged pool when block_tables is
        # given); every token scatters into its owning row, then attends it
        if block_tables is not None:
            a_part, kv_out = A.attn_packed_paged(
                lp["attn"], h, cache_layer, block_tables,
                positions=positions, seg_slots=packed_slots, cfg=cfg,
                lay=lay, theta=kind.theta, window=kind.window)
        else:
            a_part, kv_out = A.attn_packed(
                lp["attn"], h, cache_layer, positions=positions,
                seg_slots=packed_slots, cfg=cfg, lay=lay, theta=kind.theta,
                window=kind.window)
    elif decode and block_tables is not None:
        # paged decode: cache_layer is one layer of the shared block pool;
        # the block-table indirection replaces per-slot rows (no seq_axis —
        # the shared pool cannot shard over data, DESIGN.md §7).  S > 1 is
        # the speculative gamma+1 verify window (DESIGN.md §8).
        paged_attn = (A.attn_verify_paged if h.shape[1] > 1
                      else A.attn_decode_paged)
        a_part, kv_out = paged_attn(
            lp["attn"], h, cache_layer, block_tables, positions=positions,
            cfg=cfg, lay=lay, theta=kind.theta, window=kind.window,
            mrope_positions=mrope_positions)
    elif decode and h.shape[1] > 1:
        # legacy-slot speculative verify window (no seq_axis: the verify
        # scatter writes full rows locally; context-parallel KV keeps the
        # plain decode path)
        a_part, kv_out = A.attn_verify(
            lp["attn"], h, cache_layer, positions=positions, cfg=cfg,
            lay=lay, theta=kind.theta, window=kind.window,
            mrope_positions=mrope_positions)
    elif decode:
        seq_axis = (tuple(pcfg.dp_axes)
                    if pcfg.seq_shard_kv and kind.window == 0 else None)
        a_part, kv_out = A.attn_decode(
            lp["attn"], h, cache_layer, positions=positions, cfg=cfg, lay=lay,
            theta=kind.theta, window=kind.window,
            mrope_positions=mrope_positions, seq_axis=seq_axis)
    else:
        a_part, kv_out = A.attn_prefill(
            lp["attn"], h, positions=positions, cfg=cfg, lay=lay,
            theta=kind.theta, window=kind.window, kv_prefix=kv_prefix,
            mrope_positions=mrope_positions, impl=pcfg.attn_impl,
            block_q=pcfg.attn_block_q, block_kv=pcfg.attn_block_kv)

    b, s, d = h.shape
    h2_flat, res = fc.comm_norm(
        a_part.reshape(b * s, d), res, lp["norm_attn"][0], ctx=ctx,
        weight_post=(lp["norm_attn_post"][0]
                     if "norm_attn_post" in lp else None))
    h2 = h2_flat.reshape(b, s, d)

    if kind.is_moe:
        f_part, aux = X.moe_forward(lp["moe"], h2, cfg, tp_axis=ctx.tp_axis,
                                    ep_axis=pcfg.moe_ep_axis)
    else:
        f_part = M.mlp_forward(lp["mlp"], h2, tp_axis=ctx.tp_axis,
                               act=cfg.act)

    h3_flat, res = fc.comm_norm(
        f_part.reshape(b * s, d), res, lp["norm_ffn"][0], ctx=ctx,
        weight_post=(lp["norm_ffn_post"][0]
                     if "norm_ffn_post" in lp else None))
    return h3_flat.reshape(b, s, d), res, kv_out, aux


def _weave_layer(lp, state, cache_layer, *, kind, cfg, pcfg, ctx, lay,
                 decode: bool, block_tables=None):
    """Run one layer over one or two splits in paper-Fig.8 order.

    state: dict with lists h[i], res[i], positions[i], mrope[i].
    Returns (state, kv_out or new_cache_layer, aux).
    """
    n = len(state["h"])
    kv_outs, auxes = [], []
    new_h, new_res = list(state["h"]), list(state["res"])

    if state.get("pslots") is not None:
        # packed mixed-segment step: the splits run over the SAME cache in
        # sequence — the suffix split's attention reads the prefix split's
        # freshly scattered KV (a straddling segment's later tokens need
        # its earlier ones), the same §3.1 chunked-attention dependency the
        # prefill weave already carries, so the Fig.8 overlap is preserved.
        cl = cache_layer
        for i in range(n):
            h, res, cl, aux = _layer_split(
                lp, state["h"][i], state["res"][i],
                positions=state["positions"][i],
                mrope_positions=state["mrope"][i], kind=kind, cfg=cfg,
                pcfg=pcfg, ctx=ctx, lay=lay, kv_prefix=None, cache_layer=cl,
                decode=False, block_tables=block_tables,
                packed_slots=state["pslots"][i])
            new_h[i], new_res[i] = h, res
            auxes.append(aux)
        return dict(state, h=new_h, res=new_res), cl, sum(auxes)

    if decode and block_tables is not None:
        # paged decode runs unsplit (forward forces split=None): a batch
        # split would fork the shared block pool into two divergent copies
        assert n == 1, "paged decode cannot weave-split the shared pool"
        h, res, new_cache, aux = _layer_split(
            lp, state["h"][0], state["res"][0],
            positions=state["positions"][0], mrope_positions=state["mrope"][0],
            kind=kind, cfg=cfg, pcfg=pcfg, ctx=ctx, lay=lay, kv_prefix=None,
            cache_layer=cache_layer, decode=True, block_tables=block_tables)
        return dict(state, h=[h], res=[res]), new_cache, aux

    if decode:
        sizes = [h.shape[0] for h in state["h"]]
        offs = [0]
        for s_ in sizes[:-1]:
            offs.append(offs[-1] + s_)
        for i in range(n):
            cl = jax.tree.map(
                lambda c, o=offs[i], s_=sizes[i]:
                    lax.dynamic_slice_in_dim(c, o, s_, axis=0), cache_layer)
            h, res, kv, aux = _layer_split(
                lp, state["h"][i], state["res"][i],
                positions=state["positions"][i],
                mrope_positions=state["mrope"][i], kind=kind, cfg=cfg,
                pcfg=pcfg, ctx=ctx, lay=lay, kv_prefix=None, cache_layer=cl,
                decode=True)
            new_h[i], new_res[i] = h, res
            kv_outs.append(kv)
            auxes.append(aux)
        new_cache = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *kv_outs)
        return dict(state, h=new_h, res=new_res), new_cache, sum(auxes)

    kv_prev = _cache_prefix(cache_layer)
    for i in range(n):
        h, res, kv, aux = _layer_split(
            lp, state["h"][i], state["res"][i],
            positions=state["positions"][i],
            mrope_positions=state["mrope"][i], kind=kind, cfg=cfg, pcfg=pcfg,
            ctx=ctx, lay=lay, kv_prefix=kv_prev, cache_layer=None,
            decode=False)
        new_h[i], new_res[i] = h, res
        kv_outs.append(kv)
        auxes.append(aux)
        # later splits attend to cache-prefix + all earlier splits' kv
        kv_prev = kv if kv_prev is None else tuple(
            jnp.concatenate([a, b], axis=1) for a, b in zip(kv_prev, kv))
    kv_new = kv_outs[0] if n == 1 else tuple(
        jnp.concatenate([a, b], axis=1) for a, b in zip(*kv_outs))
    return dict(state, h=new_h, res=new_res), kv_new, sum(auxes)


def _cache_prefix(cache_layer):
    if cache_layer is None:
        return None
    return (cache_layer["k"], cache_layer["v"], cache_layer["pos"])


# --------------------------------------------------------------------------
# full forward
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WeaveInfo:
    """Full weave decision for one forward dispatch: the split (in the
    dispatch's native axis units), WHY it was or wasn't taken, and the
    parameters the decision saw — the host-side record the observability
    layer attaches to every forward span (DESIGN.md §12), stamped with
    the overlap plan that produced it (DESIGN.md §14)."""
    weave: bool
    split: Optional[Tuple[int, int]]
    reason: str   # split | weave_disabled | paged_pool_unsplit |
    #               below_min_tokens | below_wave_floor |
    #               plan_split | plan_unsplit
    axis: str     # packed | batch | seq
    threshold: int  # configured tokenweave_min_tokens (tokens)
    unit: int       # effective wave quantum the decision used
    site: str = ""      # policy decision site: prefill|decode|verify|packed
    plan_id: int = 0    # 0 = degenerate global-threshold policy
    bucket: str = ""    # tokens-bucket the decision was keyed on
    budget: float = 1.0   # comm resource-budget fraction the plan granted
    sim_method: str = ""  # plan-forced sim pricing mode; "" = legacy
    #                       comm-mode mapping (obs/attribution.py)
    comm_mode: str = ""   # plan-forced comm_norm mode ("ring" when the
    #                       plan dispatches the real fused kernel); "" =
    #                       pcfg.comm_mode


def _active_policy(pcfg: ParallelConfig):
    from repro.core.policy import DEFAULT_POLICY
    return pcfg.overlap_policy or DEFAULT_POLICY


def _plan_meta(policy, site: str, tokens: int, tp: int, family: str,
               has_split: bool = False) -> Tuple[float, str, str]:
    """(budget, sim_method, comm_mode) granted by the active plan.

    sim_method stays "" (= the legacy comm-mode mapping in
    obs/attribution.py) unless a plan entry forces a different pricing
    mode: ``none`` disables the fused collective entirely -> vanilla;
    the fused methods price as the ring kernel (``ring`` unsplit,
    ``ringweave`` when the split actually fired).  comm_mode is "ring"
    for the fused methods — ``forward`` threads it into ``_comm_ctx`` so
    ``comm_norm`` dispatches the real kernel (DESIGN.md §2)."""
    plan = policy.plan_for(site, tokens, tp=tp, family=family)
    if plan is None:
        return 1.0, "", ""
    if plan.method == "none":
        return plan.budget, "vanilla", ""
    if plan.method == "fused-unsplit":
        return plan.budget, "ring", "ring"
    if plan.method == "fused":
        return plan.budget, "ringweave" if has_split else "ring", "ring"
    return plan.budget, "", ""


def weave_decision_info(b: int, s: int, *, tp: int, pcfg: ParallelConfig,
                        decode: bool = False, packed: bool = False,
                        paged_pool: bool = False,
                        family: str = "dense") -> WeaveInfo:
    """Host-side mirror of the trace-time weave split decision (pure int
    math), with the refusal reason attached.

    The decision is delegated to the active ``OverlapPolicy``
    (``pcfg.overlap_policy``, DESIGN.md §14) at one of four sites —
    prefill/train: split along the sequence dim (all rows cut at the same
    position — rectangular shapes); decode: split along the batch dim;
    verify: decode with s = gamma+1 tokens per row; packed: split along
    the flat packed token axis (b == 1), so the threshold sees the true
    combined iteration size (DESIGN.md §6).  Without an installed policy
    the degenerate global-threshold ``ThresholdPolicy`` applies — token-
    identical to the historical ``split_decision`` path.
    ``paged_pool`` marks a non-packed paged decode/verify dispatch, which
    always runs unsplit (a batch split would fork the shared pool,
    DESIGN.md §7); packed paged steps thread the pool sequentially
    through the splits and CAN weave.
    """
    thr = pcfg.tokenweave_min_tokens
    policy = _active_policy(pcfg)
    pid = getattr(policy, "plan_id", 0)
    if not pcfg.tokenweave:
        return WeaveInfo(False, None, "weave_disabled", "packed" if packed
                         else ("batch" if decode else "seq"), thr, 0,
                         site="packed" if packed else (
                             "decode" if decode and s == 1 else
                             "verify" if decode else "prefill"),
                         plan_id=pid, bucket=token_bucket(b * s))
    if paged_pool and not packed:
        # the shared pool forbids a batch split, but the plan's METHOD
        # still applies: a fused entry dispatches the ring kernel unsplit
        site = ("decode" if decode and s == 1 else
                "verify" if decode else "prefill")
        budget, sim, cm = _plan_meta(policy, site, b * s, tp, family,
                                     has_split=False)
        return WeaveInfo(False, None, "paged_pool_unsplit",
                         "batch" if decode else "seq", thr, 0,
                         site=site, plan_id=pid, bucket=token_bucket(b * s),
                         budget=budget, sim_method=sim, comm_mode=cm)
    if packed:
        d = policy.decide("packed", b * s, unit=pcfg.split_unit_for(tp),
                          min_tokens=thr, tp=tp, family=family)
        budget, sim, cm = _plan_meta(policy, "packed", b * s, tp, family,
                                     has_split=d.split is not None)
        return WeaveInfo(d.split is not None, d.split, d.reason, "packed",
                         thr, d.unit, site="packed", plan_id=d.plan_id,
                         bucket=d.bucket, budget=budget, sim_method=sim,
                         comm_mode=cm)
    if decode:
        unit = max(tp, 8)
        if s > 1:
            # speculative verify: every batch row carries s = gamma+1
            # tokens, so the paper's token threshold converts to rows —
            # this is exactly how spec decoding pushes decode iterations
            # across tokenweave_min_tokens (DESIGN.md §8)
            min_rows = max(2 * unit, -(-thr // s))
            d = policy.decide("verify", b, unit=unit, min_tokens=min_rows,
                              tp=tp, family=family, bucket_tokens=b * s)
            site = "verify"
        else:
            d = policy.decide("decode", b, unit=unit, min_tokens=2 * unit,
                              tp=tp, family=family)
            site = "decode"
        budget, sim, cm = _plan_meta(policy, site, b * s, tp, family,
                                     has_split=d.split is not None)
        return WeaveInfo(d.split is not None, d.split, d.reason, "batch",
                         thr, d.unit, site=site, plan_id=d.plan_id,
                         bucket=d.bucket, budget=budget, sim_method=sim,
                         comm_mode=cm)
    d = policy.decide("prefill", b * s, unit=pcfg.split_unit_for(tp),
                      min_tokens=thr, row_multiple=b, tp=tp, family=family)
    split = None if d.split is None else (d.split[0] // b, d.split[1] // b)
    budget, sim, cm = _plan_meta(policy, "prefill", b * s, tp, family,
                                 has_split=split is not None)
    return WeaveInfo(split is not None, split, d.reason, "seq", thr, d.unit,
                     site="prefill", plan_id=d.plan_id, bucket=d.bucket,
                     budget=budget, sim_method=sim, comm_mode=cm)


def _decide_split(b: int, s: int, *, tp: int, pcfg: ParallelConfig,
                  decode: bool, packed: bool = False,
                  family: str = "dense") -> Optional[Tuple[int, int]]:
    """Static (trace-time) TokenWeave split decision (per-dim sizes or
    None) — thin view over ``weave_decision_info``."""
    return weave_decision_info(b, s, tp=tp, pcfg=pcfg, decode=decode,
                               packed=packed, family=family).split


def weave_decision(b: int, s: int, *, tp: int, pcfg: ParallelConfig,
                   decode: bool = False, packed: bool = False,
                   paged_pool: bool = False, family: str = "dense") -> bool:
    """Boolean view of ``weave_decision_info`` (the engine's legacy
    weave-activation predicate)."""
    return weave_decision_info(b, s, tp=tp, pcfg=pcfg, decode=decode,
                               packed=packed, paged_pool=paged_pool,
                               family=family).weave


def _comm_ctx(pcfg: ParallelConfig, cfg: ModelConfig, t_local: int,
              tp: int, *, mode: Optional[str] = None,
              budget: float = 1.0) -> CommCtx:
    """Pick the effective comm mode: the token-sharded (fused/reordered/
    ring) layouts need t_local divisible by tp; otherwise fall back to
    vanilla (the paper's fallback for small decode batches).  ``mode``
    overrides ``pcfg.comm_mode`` when the overlap plan forces one
    ("ring" = dispatch the real fused kernel, DESIGN.md §14); ``budget``
    is the plan's comm resource grant, sizing the ring kernel's lanes."""
    mode = mode or pcfg.comm_mode
    if (mode in ("fused", "reordered", "ring")
            and (t_local % tp != 0 or t_local < tp)):
        mode = "vanilla"
    return CommCtx(tp_axis=pcfg.tp_axis, dp_axes=pcfg.dp_axes, mode=mode,
                   eps=cfg.norm_eps, use_pallas=pcfg.use_pallas_norm,
                   bf16_wire=pcfg.bf16_wire, comm_budget=budget)


def _entry_norm(emb, w_first, ctx):
    """Split-local embedding -> residual birth + first input norm."""
    b, s, d = emb.shape
    res0 = fc.fresh_residual(b * s, d, emb.dtype, ctx=ctx)
    h_flat, res = fc.comm_norm(emb.reshape(b * s, d), res0, w_first, ctx=ctx)
    return h_flat.reshape(b, s, d), res


def forward(params, tokens, *, cfg: ModelConfig, pcfg: ParallelConfig,
            positions=None, mrope_positions=None, extra_embeds=None,
            cache=None, decode: bool = False, return_kv: bool = True,
            block_tables=None, packed_slots=None):
    """Shared forward. Returns (hidden_normed (B,S,d), kv_or_cache, aux).

    train: cache=None, decode=False (kv output suppressed via return_kv).
    prefill chunk: cache = existing KV cache (attended as prefix); the
        chunk's new kv is returned for the engine to insert.
    decode: cache required; S == 1, or S == gamma+1 for the speculative
        verify window (multi-token causal decode attention); returns the
        updated cache.
    block_tables: (B, max_blocks) int32 — switches decode to the paged
        block-pool cache layout (runtime/paging.py); prefill is unaffected
        (the engine pre-gathers the paged prefix into rectangular rows).
    packed_slots: (T,) int32 — switches to the packed mixed-segment mode
        (DESIGN.md §6): tokens is (1, T) with per-token cache-row /
        block-table-row owners (-1 = padding); cache is the FULL slot
        cache (or the paged pool with block_tables) and the updated cache
        is returned.  The weave split runs over the flat packed token
        axis, so the threshold sees the true combined iteration size.
    """
    tp = lax.axis_size(pcfg.tp_axis)
    b = tokens.shape[0]
    s_total = tokens.shape[1] + (extra_embeds.shape[1]
                                 if extra_embeds is not None else 0)
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(s_total, dtype=jnp.int32)[None], (b, s_total))

    packed = packed_slots is not None
    winfo = weave_decision_info(
        b, s_total, tp=tp, pcfg=pcfg, decode=decode, packed=packed,
        paged_pool=(decode and block_tables is not None and not packed),
        family=cfg.family)
    ctx = _comm_ctx(pcfg, cfg, b * s_total, tp,
                    mode=winfo.comm_mode or None, budget=winfo.budget)
    emb = E.embed_tokens(params["embedding"], tokens, tp_axis=ctx.tp_axis,
                         scale=cfg.embed_scale)
    if extra_embeds is not None:
        # VLM stub frontend: patch embeddings are complete values; divide so
        # the TP reduction reconstructs them alongside the partial text rows
        img = (extra_embeds / tp).astype(emb.dtype)
        emb = jnp.concatenate([img, emb], axis=1)
    d = cfg.d_model
    w_first = params["norm_first"][0]

    split = winfo.split
    if decode and block_tables is not None and not packed:
        split = None  # shared pool cannot be forked across a batch split
        #               (weave_decision_info already refused via paged_pool)
    pslots = None
    if split is not None and packed:
        s1, _ = split          # cut along the flat packed token axis
        embs = [emb[:, :s1], emb[:, s1:]]
        poss = [positions[:, :s1], positions[:, s1:]]
        pslots = [packed_slots[:s1], packed_slots[s1:]]
        mrs = [None, None]
    elif split is not None and not decode:
        s1, _ = split
        embs = [emb[:, :s1], emb[:, s1:]]
        poss = [positions[:, :s1], positions[:, s1:]]
        mrs = _split_mrope(mrope_positions, s1)
    elif split is not None and decode:
        b1, _ = split
        embs = [emb[:b1], emb[b1:]]
        poss = [positions[:b1], positions[b1:]]
        mrs = _split_mrope_batch(mrope_positions, b1)
    else:
        embs, poss, mrs = [emb], [positions], [mrope_positions]
        if packed:
            pslots = [packed_slots]

    hs, ress = [], []
    for e in embs:
        h_i, r_i = _entry_norm(e, w_first, ctx)
        hs.append(h_i)
        ress.append(r_i)
    state = {"h": hs, "res": ress, "positions": poss, "mrope": mrs,
             "pslots": pslots}

    kinds = layer_kinds(cfg)
    lay = A.attention_layout(tp, cfg.num_heads, cfg.num_kv_heads,
                             cfg.head_dim)
    aux_total = jnp.zeros((), jnp.float32)
    scan_mode = use_scan(cfg, pcfg) and "layer_0" not in params["layers"]

    if scan_mode:
        kind = kinds[0]

        def body(carry, xs):
            st, aux = carry
            lp, cache_layer = xs
            st, kv_new, aux_l = _weave_layer(
                lp, st, cache_layer, kind=kind, cfg=cfg, pcfg=pcfg, ctx=ctx,
                lay=lay, decode=decode, block_tables=block_tables)
            ys = kv_new if (return_kv or decode) else None
            return (st, aux + aux_l), ys

        if pcfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        if cache is None:
            def body_nocache(carry, lp):
                return body(carry, (lp, None))
            bodyfn, scan_xs = body_nocache, params["layers"]
        else:
            bodyfn, scan_xs = body, (params["layers"], cache)
        (state, aux_total), kv_all = lax.scan(
            bodyfn, (state, aux_total), scan_xs)
    else:
        kv_list = []
        for i, kind in enumerate(kinds):
            lp = params["layers"][f"layer_{i}"]
            cache_layer = None if cache is None else cache[f"layer_{i}"]
            fn = functools.partial(
                _weave_layer, kind=kind, cfg=cfg, pcfg=pcfg, ctx=ctx,
                lay=lay, decode=decode, block_tables=block_tables)
            if pcfg.remat:
                fn = jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.nothing_saveable)
            state, kv_new, aux_l = fn(lp, state, cache_layer)
            aux_total = aux_total + aux_l
            if return_kv or decode:
                kv_list.append(kv_new)
        kv_all = ({f"layer_{i}": kv for i, kv in enumerate(kv_list)}
                  if kv_list else None)

    if len(state["h"]) == 2:
        axis = 0 if decode else 1
        h_out = jnp.concatenate(state["h"], axis=axis)
    else:
        h_out = state["h"][0]
    return h_out, kv_all, aux_total


def _split_mrope(mrope, s1):
    if mrope is None:
        return [None, None]
    return [mrope[:, :, :s1], mrope[:, :, s1:]]


def _split_mrope_batch(mrope, b1):
    if mrope is None:
        return [None, None]
    return [mrope[:b1], mrope[b1:]]


# --------------------------------------------------------------------------
# task heads
# --------------------------------------------------------------------------

def train_loss(params, batch, *, cfg: ModelConfig, pcfg: ParallelConfig,
               aux_weight: float = 0.01):
    """batch: {tokens (B,S), labels (B,S)} -> (loss_sum, denom, aux)."""
    h, _, aux = forward(params, batch["tokens"], cfg=cfg, pcfg=pcfg,
                        mrope_positions=batch.get("mrope_positions"),
                        extra_embeds=batch.get("extra_embeds"),
                        return_kv=False)
    labels = batch["labels"]
    if h.shape[1] != labels.shape[1]:      # VLM: image positions carry no loss
        h = h[:, h.shape[1] - labels.shape[1]:]
    logits = E.lm_head_logits(params["embedding"], h)
    loss_sum, denom = E.sharded_softmax_xent(
        logits, labels, vocab_size=cfg.vocab_size, tp_axis=pcfg.tp_axis)
    return loss_sum, denom, aux * aux_weight


def prefill(params, tokens, cache, *, cfg, pcfg, positions,
            mrope_positions=None, extra_embeds=None, last_idx=None):
    """One (chunked-)prefill step. Returns (last-pos logits local shard,
    chunk kv to insert, aux). ``last_idx``: per-request index of the last
    valid (unpadded) token in the chunk."""
    h, kv, aux = forward(params, tokens, cfg=cfg, pcfg=pcfg,
                         positions=positions, mrope_positions=mrope_positions,
                         extra_embeds=extra_embeds, cache=cache,
                         return_kv=True)
    if last_idx is None:
        h_last = h[:, -1:]
    else:
        h_last = jnp.take_along_axis(h, last_idx[:, None, None], axis=1)
    logits = E.lm_head_logits(params["embedding"], h_last)
    return logits, kv, aux


def decode_step(params, tokens, cache, *, cfg, pcfg, positions,
                mrope_positions=None, block_tables=None):
    """Single-token decode. Returns (logits local shard (B,1,V_loc),
    updated cache). ``block_tables`` selects the paged block-pool layout
    (cache = pool from runtime/paging.init_paged_cache)."""
    h, new_cache, _ = forward(params, tokens, cfg=cfg, pcfg=pcfg,
                              positions=positions,
                              mrope_positions=mrope_positions, cache=cache,
                              decode=True, block_tables=block_tables)
    logits = E.lm_head_logits(params["embedding"], h)
    return logits, new_cache


def verify_step(params, tokens, cache, *, cfg, pcfg, positions,
                mrope_positions=None, block_tables=None):
    """Speculative multi-token verify: tokens (B, gamma+1) = the pending
    decode input followed by the draft proposal, positions -1 where a row
    has no (or a short) draft.  Returns (logits local shard
    (B, gamma+1, V_loc) — one target distribution per window position —
    and the updated cache with the whole window's KV written; the engine
    rolls back rejected positions host-side, DESIGN.md §8)."""
    h, new_cache, _ = forward(params, tokens, cfg=cfg, pcfg=pcfg,
                              positions=positions,
                              mrope_positions=mrope_positions, cache=cache,
                              decode=True, block_tables=block_tables)
    logits = E.lm_head_logits(params["embedding"], h)
    return logits, new_cache


def packed_step(params, tokens, cache, *, cfg, pcfg, positions, seg_slots,
                sample_idx, block_tables=None):
    """One packed hybrid forward (DESIGN.md §6): tokens (1, T) carries
    prefill-chunk segments, single-token decode slots, and speculative
    verify windows concatenated along one token axis; ``seg_slots`` (T,)
    maps each token to its owning cache row (legacy) or block-table row
    (paged), -1 = padding.  ``sample_idx`` (Nseg, W) indexes each
    segment's sampling window into the packed axis (row 0 = the position
    whose logits a plain sample would use; rows 1..γ the verify window;
    -1 = unused, clamped — the engine masks host-side).  Returns (logits
    local shard (Nseg, W, V_loc), updated cache)."""
    h, new_cache, _ = forward(params, tokens, cfg=cfg, pcfg=pcfg,
                              positions=positions, cache=cache,
                              return_kv=True, block_tables=block_tables,
                              packed_slots=seg_slots)
    h_sel = h[0][jnp.maximum(sample_idx, 0)]          # (Nseg, W, d)
    logits = E.lm_head_logits(params["embedding"], h_sel)
    return logits, new_cache


# --------------------------------------------------------------------------
# cache factory (dense / moe / vlm families)
# --------------------------------------------------------------------------

def init_cache(batch: int, max_len: int, cfg: ModelConfig, tp: int,
               pcfg: ParallelConfig | None = None):
    kinds = layer_kinds(cfg)
    scan = (pcfg is None or pcfg.scan_layers) and uniform_kinds(cfg)
    if scan:
        return A.init_kv_cache(batch, max_len, cfg, tp,
                               window=kinds[0].window)
    return {f"layer_{i}": A.init_kv_cache(batch, max_len, cfg, tp,
                                          window=k.window, layers=0)
            for i, k in enumerate(kinds)}


def cache_specs(cfg: ModelConfig, pcfg: ParallelConfig,
                batch1: bool = False):
    """KV-cache PartitionSpecs. ``batch1``: global batch of 1 cannot shard
    the batch axis (long_500k cell) — context-parallel seq sharding
    (pcfg.seq_shard_kv) carries the distribution instead; sliding-window
    ring caches stay replicated (they are tiny and their decode path is
    shard-local)."""
    from jax.sharding import PartitionSpec as P
    dp = tuple(pcfg.dp_axes)
    b = None if batch1 else dp

    def kv_spec(window: int):
        if pcfg.seq_shard_kv and window == 0:
            return {"k": P(None, None, dp, "model", None),
                    "v": P(None, None, dp, "model", None),
                    "pos": P(None, None, dp)}
        return {"k": P(None, b, None, "model", None),
                "v": P(None, b, None, "model", None),
                "pos": P(None, b, None)}

    kinds = layer_kinds(cfg)
    if use_scan(cfg, pcfg):
        return kv_spec(kinds[0].window)
    return {f"layer_{i}": {k: P(*s[1:]) for k, s in
                           kv_spec(kind.window).items()}
            for i, kind in enumerate(kinds)}
