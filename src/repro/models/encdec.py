"""Whisper-style encoder-decoder backbone (conv frontend stubbed: the
assignment provides precomputed frame embeddings via input_specs()).

Encoder: bidirectional self-attention -> the TokenWeave split runs along the
*batch* dim (a sequence split would create a two-way KV dependency).
Decoder: causal self-attn + cross-attn + GELU FFN -> three fused
AllReduce-RMSNorm slots per layer, woven like the dense stack.

Learned positions: tables are sized from config (`max_source_positions`,
decoder table grown to the serving max_len — documented deviation from the
real 448-position whisper decoder).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import fused_collectives as fc
from repro.layers import attention as A
from repro.layers import embedding as E
from repro.layers import mlp as M
from repro.models.transformer import _comm_ctx, _decide_split, _entry_norm

MAX_DECODER_POSITIONS = 1 << 20  # grown table; see module docstring


def _enc_layer_init(key, cfg, tp):
    ka, kf = jax.random.split(key)
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    return {
        "attn": A.init_attention_params(ka, cfg, tp),
        "mlp": M.init_mlp_params(kf, cfg, tp),
        "norm_attn": jnp.ones((1, d), dtype),
        "norm_ffn": jnp.ones((1, d), dtype),
    }


def _dec_layer_init(key, cfg, tp):
    ka, kc, kf = jax.random.split(key, 3)
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    return {
        "attn": A.init_attention_params(ka, cfg, tp),
        "cross": A.init_attention_params(kc, cfg, tp, cross=True),
        "mlp": M.init_mlp_params(kf, cfg, tp),
        "norm_attn": jnp.ones((1, d), dtype),
        "norm_cross": jnp.ones((1, d), dtype),
        "norm_ffn": jnp.ones((1, d), dtype),
    }


def init_params(key, cfg: ModelConfig, pcfg: ParallelConfig, tp: int,
                ep: int = 1, max_positions: int = 4096):
    ke, kenc, kdec, kp = jax.random.split(key, 4)
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    enc = [_enc_layer_init(k, cfg, tp)
           for k in jax.random.split(kenc, cfg.encoder_layers)]
    dec = [_dec_layer_init(k, cfg, tp)
           for k in jax.random.split(kdec, cfg.num_layers)]
    k1, k2 = jax.random.split(kp)
    return {
        "embedding": E.init_embedding_params(ke, cfg, tp),
        "pos_enc": (jax.random.normal(
            k1, (1, cfg.max_source_positions, d)) * 0.02).astype(dtype),
        "pos_dec": (jax.random.normal(
            k2, (1, max_positions, d)) * 0.02).astype(dtype),
        "norm_first_enc": jnp.ones((1, d), dtype),
        "norm_first": jnp.ones((1, d), dtype),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
    }


def param_specs(cfg: ModelConfig, pcfg: ParallelConfig):
    from jax.sharding import PartitionSpec as P
    enc = {"attn": A.attention_param_specs(cfg),
           "mlp": M.mlp_param_specs(cfg),
           "norm_attn": P(None), "norm_ffn": P(None)}
    dec = {"attn": A.attention_param_specs(cfg),
           "cross": A.attention_param_specs(cfg, cross=True),
           "mlp": M.mlp_param_specs(cfg),
           "norm_attn": P(None), "norm_cross": P(None), "norm_ffn": P(None)}
    def stack(t):
        return jax.tree.map(lambda s: P(None, *s), t,
                            is_leaf=lambda s: isinstance(s, P))
    return {
        "embedding": E.embedding_param_specs(cfg),
        "pos_enc": P(None), "pos_dec": P(None),
        "norm_first_enc": P(None), "norm_first": P(None),
        "enc_layers": stack(enc), "dec_layers": stack(dec),
    }


# --------------------------------------------------------------------------

def encode(params, frames, *, cfg, pcfg):
    """frames: (B, S_enc, d) stub embeddings -> encoder output (B, S_enc, d).

    Batch-dim TokenWeave split (bidirectional attention)."""
    tp = lax.axis_size(pcfg.tp_axis)
    b, s, d = frames.shape
    ctx = _comm_ctx(pcfg, cfg, b * s, tp)
    pos_tab = params["pos_enc"][0]
    x = frames + pos_tab[None, :s].astype(frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    # batch split (token-split along batch keeps bidirectional attn local)
    b1 = None
    if pcfg.tokenweave and b >= 2 and b * s >= pcfg.tokenweave_min_tokens:
        half = b // 2
        while half > 0 and ((half * s) % tp or ((b - half) * s) % tp):
            half -= 1
        b1 = half or None
    parts = [(x[:b1], positions[:b1]), (x[b1:], positions[b1:])] \
        if b1 else [(x, positions)]

    lay = A.attention_layout(tp, cfg.num_heads, cfg.num_kv_heads,
                             cfg.head_dim)
    hs, ress = [], []
    for e, _ in parts:
        # frame embeddings are complete values -> /tp so the reduce restores
        h_i, r_i = _entry_norm(e / tp, params["norm_first_enc"][0], ctx)
        hs.append(h_i)
        ress.append(r_i)

    def body(carry, lp):
        hs, ress = carry
        new_h, new_r = list(hs), list(ress)
        for i in range(len(hs)):
            bsz, s_, _ = hs[i].shape
            a_part, _ = A.attn_prefill(
                lp["attn"], hs[i], positions=parts[i][1], cfg=cfg, lay=lay,
                theta=cfg.rope_theta, causal=False, impl=pcfg.attn_impl,
                block_q=pcfg.attn_block_q, block_kv=pcfg.attn_block_kv)
            h2f, new_r[i] = fc.comm_norm(a_part.reshape(bsz * s_, d),
                                         ress[i], lp["norm_attn"][0], ctx=ctx)
            f_part = M.mlp_forward(lp["mlp"], h2f.reshape(bsz, s_, d),
                                   tp_axis=ctx.tp_axis)
            h3f, new_r[i] = fc.comm_norm(f_part.reshape(bsz * s_, d),
                                         new_r[i], lp["norm_ffn"][0], ctx=ctx)
            new_h[i] = h3f.reshape(bsz, s_, d)
        return (new_h, new_r), None

    bodyfn = body
    if pcfg.remat:
        bodyfn = jax.checkpoint(
            bodyfn, policy=jax.checkpoint_policies.nothing_saveable)
    (hs, ress), _ = lax.scan(bodyfn, (hs, ress), params["enc_layers"])
    return jnp.concatenate(hs, axis=0) if len(hs) == 2 else hs[0]


def project_cross_caches(params, enc_out, *, cfg, pcfg):
    """Precompute per-decoder-layer cross KV: (L, B, S_enc, kv, dh)."""
    tp = lax.axis_size(pcfg.tp_axis)
    lay = A.attention_layout(tp, cfg.num_heads, cfg.num_kv_heads,
                             cfg.head_dim)

    def body(_, lp):
        k, v, kpos = A.project_cross_kv(lp["cross"], enc_out, cfg=cfg,
                                        lay=lay)
        return None, {"k": k, "v": v, "pos": kpos}

    _, cross = lax.scan(body, None, params["dec_layers"])
    return cross


def decoder_forward(params, tokens, *, cfg, pcfg, cross_kv, positions=None,
                    cache=None, decode: bool = False):
    """Causal decoder over cross_kv. Mirrors transformer.forward weaving."""
    tp = lax.axis_size(pcfg.tp_axis)
    b, s = tokens.shape
    d = cfg.d_model
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
    ctx = _comm_ctx(pcfg, cfg, b * s, tp)
    emb = E.embed_tokens(params["embedding"], tokens, tp_axis=ctx.tp_axis)
    pos_emb = jnp.take(params["pos_dec"][0],
                       jnp.clip(positions, 0,
                                params["pos_dec"].shape[1] - 1), axis=0)
    emb = emb + pos_emb.astype(emb.dtype) / tp

    split = _decide_split(b, s, tp=tp, pcfg=pcfg, decode=decode)
    if split is not None and not decode:
        s1, _ = split
        embs = [emb[:, :s1], emb[:, s1:]]
        poss = [positions[:, :s1], positions[:, s1:]]
        crosses = [cross_kv, cross_kv]
        boffs = [0, 0]
    elif split is not None and decode:
        b1, _ = split
        embs, poss = [emb[:b1], emb[b1:]], [positions[:b1], positions[b1:]]
        crosses = [jax.tree.map(lambda c: c[:, :b1], cross_kv),
                   jax.tree.map(lambda c: c[:, b1:], cross_kv)]
        boffs = [0, b1]
    else:
        embs, poss, crosses, boffs = [emb], [positions], [cross_kv], [0]

    hs, ress = [], []
    for e in embs:
        h_i, r_i = _entry_norm(e, params["norm_first"][0], ctx)
        hs.append(h_i)
        ress.append(r_i)

    lay = A.attention_layout(tp, cfg.num_heads, cfg.num_kv_heads,
                             cfg.head_dim)

    def body(carry, xs):
        hs, ress = carry
        if cache is None:
            lp, cross_ls = xs
            cache_l = None
        else:
            lp, cross_ls, cache_l = xs
        new_h, new_r = list(hs), list(ress)
        kv_prev = None if (cache_l is None or decode) else \
            (cache_l["k"], cache_l["v"], cache_l["pos"])
        new_cache_parts = []
        for i in range(len(hs)):
            bsz, s_, _ = hs[i].shape
            if decode:
                cl = cache_l if len(hs) == 1 else jax.tree.map(
                    lambda c, o=boffs[i], l_=bsz:
                        lax.dynamic_slice_in_dim(c, o, l_, 0), cache_l)
                a_part, kv = A.attn_decode(lp["attn"], hs[i], cl,
                                           positions=poss[i], cfg=cfg,
                                           lay=lay, theta=cfg.rope_theta)
                new_cache_parts.append(kv)
            else:
                a_part, kv = A.attn_prefill(
                    lp["attn"], hs[i], positions=poss[i], cfg=cfg, lay=lay,
                    theta=cfg.rope_theta, kv_prefix=kv_prev,
                    impl=pcfg.attn_impl, block_q=pcfg.attn_block_q,
                    block_kv=pcfg.attn_block_kv)
                kv_prev = kv if kv_prev is None else tuple(
                    jnp.concatenate([a_, b_], axis=1)
                    for a_, b_ in zip(kv_prev, kv))
                new_cache_parts.append(kv)
            h2f, new_r[i] = fc.comm_norm(a_part.reshape(bsz * s_, d),
                                         ress[i], lp["norm_attn"][0], ctx=ctx)
            c_part = A.attn_cross(
                lp["cross"], h2f.reshape(bsz, s_, d),
                (cross_ls[i]["k"], cross_ls[i]["v"], cross_ls[i]["pos"]),
                cfg=cfg, lay=lay)
            h3f, new_r[i] = fc.comm_norm(c_part.reshape(bsz * s_, d),
                                         new_r[i], lp["norm_cross"][0],
                                         ctx=ctx)
            f_part = M.mlp_forward(lp["mlp"], h3f.reshape(bsz, s_, d),
                                   tp_axis=ctx.tp_axis)
            h4f, new_r[i] = fc.comm_norm(f_part.reshape(bsz * s_, d),
                                         new_r[i], lp["norm_ffn"][0], ctx=ctx)
            new_h[i] = h4f.reshape(bsz, s_, d)
        if decode:
            kv_new = (new_cache_parts[0] if len(hs) == 1 else jax.tree.map(
                lambda *xs_: jnp.concatenate(xs_, 0), *new_cache_parts))
        else:
            kv_new = (new_cache_parts[0] if len(hs) == 1 else tuple(
                jnp.concatenate([a_, b_], 1)
                for a_, b_ in zip(*new_cache_parts)))
        return (new_h, new_r), kv_new

    bodyfn = body
    if pcfg.remat and cache is None and not decode:
        bodyfn = jax.checkpoint(
            bodyfn, policy=jax.checkpoint_policies.nothing_saveable)
    # per-split stacked (L, ...) cross-kv views ride the scan as xs
    xs = (params["dec_layers"], tuple(crosses)) if cache is None else \
        (params["dec_layers"], tuple(crosses), cache)
    (hs, ress), kv_all = lax.scan(bodyfn, (hs, ress), xs)
    h_out = jnp.concatenate(hs, axis=0 if decode else 1) \
        if len(hs) == 2 else hs[0]
    return h_out, kv_all


def train_loss(params, batch, *, cfg, pcfg, aux_weight: float = 0.0):
    enc_out = encode(params, batch["frames"], cfg=cfg, pcfg=pcfg)
    cross = project_cross_caches(params, enc_out, cfg=cfg, pcfg=pcfg)
    h, _ = decoder_forward(params, batch["tokens"], cfg=cfg, pcfg=pcfg,
                           cross_kv=cross)
    logits = E.lm_head_logits(params["embedding"], h)
    loss_sum, denom = E.sharded_softmax_xent(
        logits, batch["labels"], vocab_size=cfg.vocab_size,
        tp_axis=pcfg.tp_axis)
    return loss_sum, denom, jnp.zeros((), jnp.float32)


def prefill(params, batch, cache, *, cfg, pcfg, positions=None, **_):
    """batch: {'frames': (B,S_enc,d), 'tokens': (B,S_dec)}. Encodes once,
    projects cross caches, runs the decoder prompt. Returns
    (last-pos logits, {'self': chunk kv, 'cross': cross caches}, aux)."""
    enc_out = encode(params, batch["frames"], cfg=cfg, pcfg=pcfg)
    cross = project_cross_caches(params, enc_out, cfg=cfg, pcfg=pcfg)
    h, kv = decoder_forward(params, batch["tokens"], cfg=cfg, pcfg=pcfg,
                            cross_kv=cross, positions=positions,
                            cache=None if cache is None else cache["self"])
    logits = E.lm_head_logits(params["embedding"], h[:, -1:])
    return logits, {"self": kv, "cross": cross}, jnp.zeros((), jnp.float32)


def decode_step(params, tokens, cache, *, cfg, pcfg, positions=None, **_):
    h, new_self = decoder_forward(params, tokens, cfg=cfg, pcfg=pcfg,
                                  cross_kv=cache["cross"], positions=positions,
                                  cache=cache["self"], decode=True)
    logits = E.lm_head_logits(params["embedding"], h)
    return logits, {"self": new_self, "cross": cache["cross"]}


def init_cache(batch: int, max_len: int, cfg: ModelConfig, tp: int,
               enc_len: int | None = None):
    lay_kv = A.init_kv_cache(batch, max_len, cfg, tp)
    s_enc = enc_len or cfg.max_source_positions
    lay = A.attention_layout(tp, cfg.num_heads, cfg.num_kv_heads,
                             cfg.head_dim)
    h_global = lay.kv_store * tp
    cross = {
        "k": jnp.zeros((cfg.num_layers, batch, s_enc, h_global,
                        cfg.head_dim), jnp.dtype(cfg.dtype)),
        "v": jnp.zeros((cfg.num_layers, batch, s_enc, h_global,
                        cfg.head_dim), jnp.dtype(cfg.dtype)),
        "pos": jnp.zeros((cfg.num_layers, batch, s_enc), jnp.int32),
    }
    return {"self": lay_kv, "cross": cross}


def cache_specs(cfg: ModelConfig, pcfg: ParallelConfig,
                batch1: bool = False):
    from jax.sharding import PartitionSpec as P
    b = None if batch1 else tuple(pcfg.dp_axes)
    kv = {"k": P(None, b, None, "model", None),
          "v": P(None, b, None, "model", None),
          "pos": P(None, b, None)}
    return {"self": dict(kv), "cross": dict(kv)}
