"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block
applied every ``shared_attn_period`` layers (with per-invocation LoRA), per
arXiv:2411.15242.

Simplifications (recorded in DESIGN.md §6): the shared block is a 2*d-wide
attention — input = concat(hidden, initial embedding), re-normed by its own
input norm — projecting back to d; rank-16 LoRA modulates the q projection
per invocation; placement is uniform every ``period`` layers.

Structure: outer scan over invocation groups (shared block + ``period``
mamba layers) keeps every shape static without lax.cond; a tail scan covers
the remainder layers. TokenWeave weaving: the shared attention behaves like
a dense layer (KV-prefix dependency between splits); mamba blocks pass the
prefix split's final state to the suffix.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import fused_collectives as fc
from repro.layers import attention as A
from repro.layers import embedding as E
from repro.layers import ssm as S
from repro.layers.norms import rms_norm
from repro.models.transformer import _comm_ctx, _decide_split, _entry_norm

LORA_RANK = 16


def _n_groups(cfg):
    p = cfg.shared_attn_period
    return cfg.num_layers // p, cfg.num_layers % p


def _shared_attn_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, d_model=2 * cfg.d_model,
        head_dim=2 * cfg.d_model // cfg.num_heads, qk_norm=False,
        qkv_bias=False, mrope_sections=())


def init_params(key, cfg: ModelConfig, pcfg: ParallelConfig, tp: int,
                ep: int = 1):
    ke, kl, ks, kr = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    n_inv, _ = _n_groups(cfg)

    layers = []
    for k in jax.random.split(kl, cfg.num_layers):
        layers.append({
            "mamba": S.init_mamba2_params(k, cfg, tp),
            "norm_out": jnp.ones((1, d), dtype),
        })
    layers = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    acfg = _shared_attn_cfg(cfg)
    lay = A.attention_layout(tp, acfg.num_heads, acfg.num_kv_heads,
                             acfg.head_dim)
    ka_, kb_, kw_ = jax.random.split(kr, 3)
    shared = {
        "attn": A.init_attention_params(ks, acfg, tp),
        "norm_in": jnp.ones((1, 2 * d), dtype),
        "norm_out": jnp.ones((n_inv, 1, d), dtype),
        "lora_a": (jax.random.normal(ka_, (n_inv, 1, 2 * d, LORA_RANK))
                   * 0.01).astype(dtype),
        "lora_b": jnp.zeros((n_inv, tp, LORA_RANK,
                             lay.h_loc * acfg.head_dim), dtype),
    }
    # out proj maps the shared block back to d (not 2d)
    shared["attn"]["wo"] = (jax.random.normal(
        kw_, (tp, lay.h_loc * acfg.head_dim, d)) * (2 * d) ** -0.5).astype(dtype)
    return {
        "embedding": E.init_embedding_params(ke, cfg, tp),
        "norm_first": jnp.ones((1, d), dtype),
        "layers": layers,
        "shared": shared,
    }


def param_specs(cfg: ModelConfig, pcfg: ParallelConfig):
    from jax.sharding import PartitionSpec as P
    ls = {"mamba": S.mamba2_param_specs(cfg), "norm_out": P(None)}
    layers = jax.tree.map(lambda s: P(None, *s), ls,
                          is_leaf=lambda s: isinstance(s, P))
    acfg = _shared_attn_cfg(cfg)
    shared = {"attn": A.attention_param_specs(acfg), "norm_in": P(None),
              "norm_out": P(None, None),
              "lora_a": P(None, None), "lora_b": P(None, "model")}
    return {"embedding": E.embedding_param_specs(cfg),
            "norm_first": P(None), "layers": layers, "shared": shared}


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _mamba_weave(lp, hs, ress, st, *, cfg, ctx, decode, split_batch,
                 chunk):
    """One mamba2 layer over all splits; st = cache state or None."""
    n = len(hs)
    new_h, new_r, out_states = list(hs), list(ress), []
    if decode and n == 2:
        sts = (jax.tree.map(lambda c: c[:split_batch], st),
               jax.tree.map(lambda c: c[split_batch:], st))
    else:
        sts = [st] * n
    prev_final = None
    for i in range(n):
        if not decode and i > 0:
            init_state = prev_final
        else:
            init_state = sts[i]
        partial, state_i = S.mamba2_forward(
            lp["mamba"], hs[i], cfg=cfg, tp_axis=ctx.tp_axis,
            init_state=init_state, chunk=chunk)
        b, s_, d = hs[i].shape
        h_flat, new_r[i] = fc.comm_norm(partial.reshape(b * s_, d), ress[i],
                                        lp["norm_out"][0], ctx=ctx)
        new_h[i] = h_flat.reshape(b, s_, d)
        prev_final = state_i
        out_states.append(state_i)
    if n == 2:
        st_out = (jax.tree.map(lambda a, b_: jnp.concatenate([a, b_], 0),
                               out_states[0], out_states[1])
                  if decode else out_states[-1])
    else:
        st_out = out_states[0]
    return new_h, new_r, st_out


def _shared_weave(shared, lora_a, lora_b, w_out, hs, ress, embs, poss,
                  cache_inv, *, cfg, pcfg, ctx, decode):
    acfg = _shared_attn_cfg(cfg)
    tp = lax.axis_size(ctx.tp_axis)
    lay = A.attention_layout(tp, acfg.num_heads, acfg.num_kv_heads,
                             acfg.head_dim)
    p_eff = dict(shared["attn"])
    delta = jnp.einsum("dr,rf->df", lora_a[0].astype(jnp.float32),
                       lora_b[0].astype(jnp.float32)).astype(lora_a.dtype)
    p_eff["wq"] = shared["attn"]["wq"] + delta[None]
    n = len(hs)
    new_h, new_r = list(hs), list(ress)
    # chunked prefill: earlier chunks' shared-attn KV is the prefix
    kv_prev = None
    if not decode and cache_inv is not None:
        kv_prev = (cache_inv["k"], cache_inv["v"], cache_inv["pos"])
    kv_outs = []
    offs = [0]
    for h_ in hs[:-1]:
        offs.append(offs[-1] + h_.shape[0])
    for i in range(n):
        u = jnp.concatenate([hs[i], embs[i].astype(hs[i].dtype)], axis=-1)
        u = rms_norm(u, shared["norm_in"][0], cfg.norm_eps)
        b, s_, _ = u.shape
        if decode:
            cl = cache_inv if n == 1 else jax.tree.map(
                lambda c, o=offs[i], l_=hs[i].shape[0]:
                    lax.dynamic_slice_in_dim(c, o, l_, axis=0), cache_inv)
            seq_axis = tuple(pcfg.dp_axes) if pcfg.seq_shard_kv else None
            a_part, kv = A.attn_decode(p_eff, u, cl, positions=poss[i],
                                       cfg=acfg, lay=lay,
                                       theta=cfg.rope_theta,
                                       seq_axis=seq_axis)
        else:
            a_part, kv = A.attn_prefill(
                p_eff, u, positions=poss[i], cfg=acfg, lay=lay,
                theta=cfg.rope_theta, kv_prefix=kv_prev, impl=pcfg.attn_impl,
                block_q=pcfg.attn_block_q, block_kv=pcfg.attn_block_kv)
            kv_prev = kv if kv_prev is None else tuple(
                jnp.concatenate([x, y], axis=1) for x, y in zip(kv_prev, kv))
        kv_outs.append(kv)
        d = cfg.d_model
        h_flat, new_r[i] = fc.comm_norm(a_part.reshape(b * s_, d), ress[i],
                                        w_out, ctx=ctx)
        new_h[i] = h_flat.reshape(b, s_, d)
    if n == 1:
        new_cache = kv_outs[0]
    elif decode:
        new_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *kv_outs)
    else:
        new_cache = tuple(jnp.concatenate([x, y], axis=1)
                          for x, y in zip(*kv_outs))
    return new_h, new_r, new_cache


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def forward(params, tokens, *, cfg: ModelConfig, pcfg: ParallelConfig,
            positions=None, cache=None, decode: bool = False,
            return_kv: bool = True, ssm_chunk: int = 128):
    tp = lax.axis_size(pcfg.tp_axis)
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
    ctx = _comm_ctx(pcfg, cfg, b * s, tp)
    emb = E.embed_tokens(params["embedding"], tokens, tp_axis=ctx.tp_axis,
                         scale=cfg.embed_scale)
    # complete embeddings: reused by every shared-block concat input
    emb = lax.psum(emb, ctx.tp_axis)

    split = _decide_split(b, s, tp=tp, pcfg=pcfg, decode=decode)
    split_batch = None
    if split is not None and not decode:
        s1, _ = split
        embs = [emb[:, :s1], emb[:, s1:]]
        poss = [positions[:, :s1], positions[:, s1:]]
    elif split is not None and decode:
        b1, _ = split
        split_batch = b1
        embs = [emb[:b1], emb[b1:]]
        poss = [positions[:b1], positions[b1:]]
    else:
        embs, poss = [emb], [positions]

    hs, ress = [], []
    for e in embs:
        h_i, r_i = _entry_norm(e / tp, params["norm_first"][0], ctx)
        hs.append(h_i)
        ress.append(r_i)

    n_inv, tail = _n_groups(cfg)
    period = cfg.shared_attn_period
    head_n = n_inv * period

    def take(tree, sl):
        return jax.tree.map(lambda a: a[sl], tree)

    lp_head = jax.tree.map(
        lambda a: a[:head_n].reshape(n_inv, period, *a.shape[1:]),
        params["layers"])
    lp_tail = take(params["layers"], slice(head_n, None))
    shared = params["shared"]

    mcache = None if cache is None else cache["mamba"]
    scache = None if cache is None else cache["shared"]
    chunk = 1 if decode else ssm_chunk

    def mamba_scan(hs, ress, lps, mcs):
        def body(carry, xs):
            hs, ress = carry
            if mcs is None:
                lp, st = xs, None
            else:
                lp, st = xs
            hs, ress, st_out = _mamba_weave(
                lp, hs, ress, st, cfg=cfg, ctx=ctx, decode=decode,
                split_batch=split_batch, chunk=chunk)
            return (hs, ress), st_out
        bodyfn = body
        if pcfg.remat and not decode and cache is None:
            bodyfn = jax.checkpoint(
                bodyfn, policy=jax.checkpoint_policies.nothing_saveable)
        xs = lps if mcs is None else (lps, mcs)
        (hs, ress), sts = lax.scan(bodyfn, (hs, ress), xs)
        return hs, ress, sts

    def group_body(carry, xs):
        hs, ress = carry
        lps, la, lb, w_out, mcs, scs = xs
        hs, ress, new_sc = _shared_weave(
            shared, la, lb, w_out[0], hs, ress, embs, poss, scs,
            cfg=cfg, pcfg=pcfg, ctx=ctx, decode=decode)
        hs, ress, new_mc = mamba_scan(hs, ress, lps, mcs)
        return (hs, ress), (new_mc, new_sc)

    # group scan xs
    mc_head = None if mcache is None else jax.tree.map(
        lambda a: a[:head_n].reshape(n_inv, period, *a.shape[1:]), mcache)
    sc_xs = scache if scache is not None else None
    gb = group_body

    if mcache is None:
        dummy_mc = jnp.zeros((n_inv,), jnp.int32)
        dummy_sc = jnp.zeros((n_inv,), jnp.int32)

        def gb_nc(carry, xs):
            lps, la, lb, w_out, _, _2 = xs
            hs, ress = carry
            hs, ress, new_sc = _shared_weave(
                shared, la, lb, w_out[0], hs, ress, embs, poss, None,
                cfg=cfg, pcfg=pcfg, ctx=ctx, decode=decode)
            hs, ress, new_mc = mamba_scan(hs, ress, lps, None)
            return (hs, ress), (new_mc, new_sc)
        gfn = gb_nc
        if pcfg.remat and not decode:
            gfn = jax.checkpoint(
                gfn, policy=jax.checkpoint_policies.nothing_saveable)
        (hs, ress), (mc_out, sc_out) = lax.scan(
            gfn, (hs, ress),
            (lp_head, shared["lora_a"], shared["lora_b"], shared["norm_out"],
             dummy_mc, dummy_sc))
    else:
        (hs, ress), (mc_out, sc_out) = lax.scan(
            gb, (hs, ress),
            (lp_head, shared["lora_a"], shared["lora_b"], shared["norm_out"],
             mc_head, sc_xs))

    # tail mamba layers
    if tail:
        mc_tail = None if mcache is None else take(
            mcache, slice(head_n, None))
        hs, ress, mc_tail_out = mamba_scan(hs, ress, lp_tail, mc_tail)
    else:
        mc_tail_out = None

    h_out = jnp.concatenate(hs, axis=0 if decode else 1) \
        if len(hs) == 2 else hs[0]

    new_cache = None
    if return_kv or decode:
        mc_flat = jax.tree.map(
            lambda a: a.reshape(head_n, *a.shape[2:]), mc_out)
        if mc_tail_out is not None:
            mc_flat = jax.tree.map(
                lambda a, t: jnp.concatenate([a, t], axis=0),
                mc_flat, mc_tail_out)
        new_cache = {"mamba": mc_flat, "shared": sc_out}
    return h_out, new_cache, jnp.zeros((), jnp.float32)


def train_loss(params, batch, *, cfg, pcfg, aux_weight: float = 0.0):
    h, _, aux = forward(params, batch["tokens"], cfg=cfg, pcfg=pcfg,
                        return_kv=False)
    logits = E.lm_head_logits(params["embedding"], h)
    loss_sum, denom = E.sharded_softmax_xent(
        logits, batch["labels"], vocab_size=cfg.vocab_size,
        tp_axis=pcfg.tp_axis)
    return loss_sum, denom, aux


def prefill(params, tokens, cache, *, cfg, pcfg, positions=None, **_):
    h, new_cache, aux = forward(params, tokens, cfg=cfg, pcfg=pcfg,
                                positions=positions, cache=cache)
    logits = E.lm_head_logits(params["embedding"], h[:, -1:])
    return logits, new_cache, aux


def decode_step(params, tokens, cache, *, cfg, pcfg, positions=None, **_):
    h, new_cache, _ = forward(params, tokens, cfg=cfg, pcfg=pcfg,
                              positions=positions, cache=cache, decode=True)
    logits = E.lm_head_logits(params["embedding"], h)
    return logits, new_cache


def init_cache(batch: int, max_len: int, cfg: ModelConfig, tp: int):
    n_inv, _ = _n_groups(cfg)
    acfg = _shared_attn_cfg(cfg)
    return {
        "mamba": S.init_mamba2_state(batch, cfg, tp, cfg.num_layers),
        "shared": A.init_kv_cache(batch, max_len, acfg, tp, layers=n_inv),
    }


def cache_specs(cfg: ModelConfig, pcfg: ParallelConfig,
                batch1: bool = False):
    from jax.sharding import PartitionSpec as P
    dp = tuple(pcfg.dp_axes)
    b = None if batch1 else dp
    if pcfg.seq_shard_kv:
        shared = {"k": P(None, None, dp, "model", None),
                  "v": P(None, None, dp, "model", None),
                  "pos": P(None, None, dp)}
    else:
        shared = {"k": P(None, b, None, "model", None),
                  "v": P(None, b, None, "model", None),
                  "pos": P(None, b, None)}
    return {
        "mamba": ((P(None, b, None, "model"), P(None, b, None, None)),
                  P(None, b, "model", None, None)),
        "shared": shared,
    }
