"""Vocab-sharded embedding, LM head, and TP-sharded cross-entropy.

The embedding lookup produces *partial* rows (masked gather + psum), which
slots directly into TokenWeave's fused collective: the model entry point is
``comm_norm(embed_partial, residual=0, norm1_weights)`` — the very first
RMSNorm is already token-sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.splitting import pad_to_multiple


def _sq(p):
    return jnp.squeeze(p, axis=0)


def init_embedding_params(key, cfg, tp: int):
    v_pad = pad_to_multiple(cfg.vocab_size, tp)
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    p = {"embed": (jax.random.normal(k1, (tp, v_pad // tp, d)) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k2, (tp, d, v_pad // tp))
                        * d ** -0.5).astype(dtype)
    return p


def embedding_param_specs(cfg):
    from jax.sharding import PartitionSpec as P
    specs = {"embed": P("model")}
    if not cfg.tie_embeddings:
        specs["lm_head"] = P("model")
    return specs


def embed_tokens(params, ids, *, tp_axis: str = "model", scale: float = 1.0):
    """ids: (B, S) -> partial (B, S, d) over TP (complete after psum)."""
    table = _sq(params["embed"])  # (V_loc, d)
    v_loc = table.shape[0]
    lo = lax.axis_index(tp_axis) * v_loc
    local_ids = ids - lo
    in_range = (local_ids >= 0) & (local_ids < v_loc)
    gathered = jnp.take(table, jnp.clip(local_ids, 0, v_loc - 1), axis=0)
    out = jnp.where(in_range[..., None], gathered, 0.0)
    return out * scale


def lm_head_logits(params, x):
    """x: (B, S, d) replicated -> local logits (B, S, V_loc)."""
    if "lm_head" in params:
        w = _sq(params["lm_head"])            # (d, V_loc)
        return jnp.einsum("bsd,dv->bsv", x, w)
    table = _sq(params["embed"])              # (V_loc, d) tied
    return jnp.einsum("bsd,vd->bsv", x, table)


def sharded_softmax_xent(local_logits, labels, *, vocab_size: int,
                         tp_axis: str = "model", ignore_id: int = -100):
    """Cross-entropy over vocab-sharded logits.

    local_logits: (B, S, V_loc); labels: (B, S) global ids. Uses the
    max/psum trick so no shard ever materializes full logits.
    """
    v_loc = local_logits.shape[-1]
    lo = lax.axis_index(tp_axis) * v_loc
    lg = local_logits.astype(jnp.float32)
    # mask padded vocab rows (v_pad > vocab_size tail lives on last shard)
    col = lo + jnp.arange(v_loc)
    lg = jnp.where((col < vocab_size)[None, None], lg, -jnp.inf)
    # stability max is non-differentiable plumbing; pmax has no AD rule, so
    # gather the per-shard maxes (all_gather IS differentiable) instead
    m_loc = jnp.max(lg, axis=-1)                                  # (B, S)
    m = lax.stop_gradient(jnp.max(
        lax.all_gather(m_loc, tp_axis, axis=-1, tiled=False), axis=-1))
    se = jnp.sum(jnp.exp(lg - m[..., None]), axis=-1)
    lse = jnp.log(lax.psum(se, tp_axis)) + m
    local_lab = labels - lo
    in_range = (local_lab >= 0) & (local_lab < v_loc)
    picked = jnp.take_along_axis(
        lg, jnp.clip(local_lab, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
    correct = lax.psum(jnp.where(in_range, picked, 0.0), tp_axis)
    nll = lse - correct
    valid = labels != ignore_id
    nll = jnp.where(valid, nll, 0.0)
    return jnp.sum(nll), jnp.sum(valid)


def sharded_argmax(local_logits, *, vocab_size: int, tp_axis: str = "model"):
    """Greedy token ids from vocab-sharded logits: (B, S, V_loc) -> (B, S)."""
    v_loc = local_logits.shape[-1]
    lo = lax.axis_index(tp_axis) * v_loc
    lg = local_logits.astype(jnp.float32)
    col = lo + jnp.arange(v_loc)
    lg = jnp.where((col < vocab_size)[None, None], lg, -jnp.inf)
    local_max = jnp.max(lg, axis=-1)
    local_arg = jnp.argmax(lg, axis=-1) + lo
    gmax = lax.pmax(local_max, tp_axis)
    # break ties toward the smallest id (deterministic across shards)
    cand = jnp.where(local_max >= gmax, local_arg, jnp.iinfo(jnp.int32).max)
    return lax.pmin(cand.astype(jnp.int32), tp_axis)
