"""Tensor-parallel GQA attention with chunked (flash-style) execution.

Head layout under TP
--------------------
``attention_layout`` decides how query/KV heads map onto the ``tp`` shards of
the model axis. TP degrees larger than the head count (e.g. gemma3-1b's 4
heads on a 16-way model axis) are handled by *replication groups*: the head
shards are replicated ``replicas`` times and the row-parallel output psum is
pre-scaled by 1/replicas, which keeps the math exact while every shard does
useful (if partially redundant) work. When ``attn_tp > num_kv_heads``, each
shard stores exactly one KV head (vLLM-style KV duplication), so the KV cache
stays sharded as far as the architecture allows.

Implementations
---------------
``impl='ref'``      full-score softmax (tests / tiny shapes)
``impl='chunked'``  lax.scan over q- and kv-blocks with running softmax — the
                    memory-efficient pure-jnp path used for CPU dry-run
                    lowering (Pallas cannot lower on the CPU backend)
``impl='pallas'``   kernels/flash_attention.py (TPU target)

All attention math runs per (batch, head) in fp32 accumulation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.norms import rms_norm
from repro.layers.rotary import apply_mrope, apply_rope

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


@dataclasses.dataclass(frozen=True)
class AttnLayout:
    tp: int            # model-axis size
    attn_tp: int       # head-sharding degree (divides tp)
    h_loc: int         # query heads per shard
    kv_store: int      # KV heads stored per shard
    replicas: int      # tp // attn_tp (redundant head-shard copies)
    num_heads: int
    num_kv_heads: int
    head_dim: int

    @property
    def o_scale(self) -> float:
        """Pre-psum scale correcting for replicated head shards."""
        return 1.0 / self.replicas


def attention_layout(tp: int, num_heads: int, num_kv_heads: int,
                     head_dim: int) -> AttnLayout:
    attn_tp = math.gcd(tp, num_heads)
    # shrink attn_tp until the GQA grouping divides cleanly
    while attn_tp > 1:
        if attn_tp <= num_kv_heads:
            if num_kv_heads % attn_tp == 0:
                break
        else:
            g = num_heads // num_kv_heads
            if attn_tp % num_kv_heads == 0 and g % (attn_tp // num_kv_heads) == 0:
                break
        attn_tp //= 2
    h_loc = num_heads // attn_tp
    kv_store = num_kv_heads // attn_tp if attn_tp <= num_kv_heads else 1
    return AttnLayout(tp=tp, attn_tp=attn_tp, h_loc=h_loc, kv_store=kv_store,
                      replicas=tp // attn_tp, num_heads=num_heads,
                      num_kv_heads=num_kv_heads, head_dim=head_dim)


def init_attention_params(key, cfg, tp: int, *, cross: bool = False):
    """Per-shard-leading-axis weights: every array's axis 0 has size tp."""
    lay = attention_layout(tp, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim)
    d, dh = cfg.d_model, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    dtype = jnp.dtype(cfg.dtype)
    p = {
        "wq": (jax.random.normal(k1, (tp, d, lay.h_loc * dh)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (tp, d, lay.kv_store * dh)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (tp, d, lay.kv_store * dh)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (tp, lay.h_loc * dh, d)) * s).astype(dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((tp, lay.h_loc * dh), dtype)
        p["bk"] = jnp.zeros((tp, lay.kv_store * dh), dtype)
        p["bv"] = jnp.zeros((tp, lay.kv_store * dh), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((1, dh), dtype)
        p["k_norm"] = jnp.ones((1, dh), dtype)
    return p


def attention_param_specs(cfg, *, cross: bool = False):
    from jax.sharding import PartitionSpec as P
    specs = {k: P("model") for k in ("wq", "wk", "wv", "wo")}
    if cfg.qkv_bias and not cross:
        specs.update(bq=P("model"), bk=P("model"), bv=P("model"))
    if cfg.qk_norm:
        specs.update(q_norm=P(None), k_norm=P(None))
    return specs


# --------------------------------------------------------------------------
# core attention math. q: (B, Sq, kvh, g, dh); k/v: (B, Sk, kvh, dh)
# qpos: (B, Sq) absolute positions; kpos: (B, Sk) absolute positions of keys
# (-1 marks invalid/unwritten cache slots).
# --------------------------------------------------------------------------

def _mask(qpos, kpos, causal: bool, window):
    """window may be a python int or a traced scalar (<=0 means full)."""
    m = kpos[:, None, :] >= 0
    if causal:
        m &= qpos[:, :, None] >= kpos[:, None, :]
    if isinstance(window, (int, float)):
        if window > 0:
            m &= (qpos[:, :, None] - kpos[:, None, :]) < window
    else:
        m &= ((qpos[:, :, None] - kpos[:, None, :]) < window) | (window <= 0)
    return m  # (B, Sq, Sk)


def _attn_ref(q, k, v, qpos, kpos, *, causal, window, sm_scale):
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sm_scale
    mask = _mask(qpos, kpos, causal, window)  # (B, Sq, Sk)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p / jnp.maximum(denom, 1e-30),
                     v.astype(jnp.float32))
    return out.astype(q.dtype)


def _attn_chunked(q, k, v, qpos, kpos, *, causal, window, sm_scale,
                  block_q: int, block_kv: int):
    """Flash-style two-level blocked attention, O(bq*bkv) live scores."""
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    bq = min(block_q, sq)
    bkv = min(block_kv, sk)
    pq = (-sq) % bq
    pk = (-sk) % bkv
    q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    qpos_p = jnp.pad(qpos, ((0, 0), (0, pq)), constant_values=0)
    k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    kpos_p = jnp.pad(kpos, ((0, 0), (0, pk)), constant_values=-1)
    nq, nk = (sq + pq) // bq, (sk + pk) // bkv

    qb = jnp.moveaxis(q.reshape(b, nq, bq, kvh, g, dh), 1, 0)
    qpb = jnp.moveaxis(qpos_p.reshape(b, nq, bq), 1, 0)
    kb = jnp.moveaxis(k.reshape(b, nk, bkv, kvh, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, bkv, kvh, dh), 1, 0)
    kpb = jnp.moveaxis(kpos_p.reshape(b, nk, bkv), 1, 0)

    def q_step(_, qx):
        qblk, qp = qx  # (b, bq, kvh, g, dh), (b, bq)

        def kv_step(carry, kx):
            m_run, l_run, acc = carry
            kblk, vblk, kp = kx
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                                preferred_element_type=jnp.float32) * sm_scale
            msk = _mask(qp, kp, causal, window)
            logits = jnp.where(msk[:, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kvh, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, bq, dh), jnp.float32)
        (m_f, l_f, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kb, vb, kpb))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, jnp.moveaxis(out, 3, 1).astype(q.dtype)  # (b,bq,kvh,g,dh)

    _, outs = lax.scan(q_step, None, (qb, qpb))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq + pq, kvh, g, dh)
    return out[:, :sq]


def multihead_attention(q, k, v, qpos, kpos, *, causal: bool, window: int = 0,
                        impl: str = "chunked", block_q: int = 512,
                        block_kv: int = 1024, sm_scale: float | None = None,
                        interpret: bool = False):
    """q: (B, Sq, Hq, dh) grouped internally; k/v: (B, Sk, KVh, dh)."""
    b, sq, hq, dh = q.shape
    kvh = k.shape[2]
    g = hq // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    if sm_scale is None:
        sm_scale = dh ** -0.5
    if impl == "ref" or (impl == "chunked" and sq * k.shape[1] <= 256 * 256):
        out = _attn_ref(qg, k, v, qpos, kpos, causal=causal, window=window,
                        sm_scale=sm_scale)
    elif impl == "chunked":
        out = _attn_chunked(qg, k, v, qpos, kpos, causal=causal, window=window,
                            sm_scale=sm_scale, block_q=block_q,
                            block_kv=block_kv)
    elif impl == "pallas":
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(qg, k, v, qpos, kpos, causal=causal,
                              window=window, sm_scale=sm_scale,
                              block_q=block_q, block_kv=block_kv,
                              interpret=interpret)
    else:
        raise ValueError(f"unknown attention impl {impl!r}")
    return out.reshape(b, sq, hq, dh)


# --------------------------------------------------------------------------
# layer-level forward (inside shard_map; params carry the per-shard axis 0)
# --------------------------------------------------------------------------

def _sq(p):
    return jnp.squeeze(p, axis=0)


def _project_qkv(params, x, cfg, lay: AttnLayout, *, positions, theta,
                 mrope_positions=None):
    b, s, _ = x.shape
    dh = cfg.head_dim
    q = jnp.einsum("bsd,df->bsf", x, _sq(params["wq"]))
    k = jnp.einsum("bsd,df->bsf", x, _sq(params["wk"]))
    v = jnp.einsum("bsd,df->bsf", x, _sq(params["wv"]))
    if "bq" in params:
        q = q + _sq(params["bq"])
        k = k + _sq(params["bk"])
        v = v + _sq(params["bv"])
    q = q.reshape(b, s, lay.h_loc, dh)
    k = k.reshape(b, s, lay.kv_store, dh)
    v = v.reshape(b, s, lay.kv_store, dh)
    if "q_norm" in params:
        q = rms_norm(q, _sq(params["q_norm"]), cfg.norm_eps)
        k = rms_norm(k, _sq(params["k_norm"]), cfg.norm_eps)
    if cfg.mrope_sections and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, theta)
    elif not cfg.learned_positions:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attn_prefill(params, x, *, positions, cfg, lay: AttnLayout, theta,
                 causal: bool = True, window: int = 0,
                 kv_prefix: Optional[Tuple] = None, mrope_positions=None,
                 impl: str = "chunked", block_q: int = 512,
                 block_kv: int = 1024, interpret: bool = False):
    """Returns (partial_out (B,S,d) — pre-psum over TP, (k, v, kpos)).

    ``kv_prefix``: (k, v, kpos) from the prefix token-split — the suffix
    split's chunked-attention dependency (paper §3.1).
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, lay, positions=positions,
                           theta=theta, mrope_positions=mrope_positions)
    kpos = positions
    if kv_prefix is not None:
        pk, pv, ppos = kv_prefix
        k_all = jnp.concatenate([pk, k], axis=1)
        v_all = jnp.concatenate([pv, v], axis=1)
        kpos_all = jnp.concatenate([ppos, kpos], axis=1)
    else:
        k_all, v_all, kpos_all = k, v, kpos
    out = multihead_attention(q, k_all, v_all, positions, kpos_all,
                              causal=causal, window=window, impl=impl,
                              block_q=block_q, block_kv=block_kv,
                              interpret=interpret)
    out = out.reshape(b, s, lay.h_loc * cfg.head_dim)
    partial = jnp.einsum("bsf,fd->bsd", out, _sq(params["wo"]))
    if lay.replicas > 1:
        partial = partial * lay.o_scale
    return partial, (k, v, kpos)


def attn_decode(params, x, cache, *, positions, cfg, lay: AttnLayout, theta,
                window: int = 0, mrope_positions=None, seq_axis=None):
    """Single-token decode against a (possibly ring-buffered) KV cache.

    cache: {"k": (B, C, kvh, dh), "v": ..., "pos": (B, C) int32 (-1 = empty)}.
    C = min(max_len, window) for sliding layers — the ring buffer IS the
    sliding window. ``seq_axis`` (axis name) enables context-parallel KV:
    each dp shard owns C_local slots; partial softmax stats are combined with
    pmax/psum (flash-decoding across chips).

    Without ``seq_axis`` this is exactly the S_v == 1 case of
    ``attn_verify`` (one slot-scatter implementation, shared epilogue);
    only the context-parallel branch lives here.
    """
    if seq_axis is None:
        return attn_verify(params, x, cache, positions=positions, cfg=cfg,
                           lay=lay, theta=theta, window=window,
                           mrope_positions=mrope_positions)
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, cfg, lay, positions=positions,
                                   theta=theta,
                                   mrope_positions=mrope_positions)
    c = cache["k"].shape[1]
    pos = positions[:, 0]  # (B,)

    # context parallel: slot `pos % (C_local * n)` lives on shard pos//C_local
    names = (seq_axis,) if isinstance(seq_axis, str) else tuple(seq_axis)
    n = 1
    me = jnp.zeros((), jnp.int32)
    for nm in names:
        n = n * lax.axis_size(nm)
        me = me * lax.axis_size(nm) + lax.axis_index(nm)
    gslot = (pos % (c * n)).astype(jnp.int32)
    owner = gslot // c
    lslot = gslot % c
    mine = (owner == me)[:, None, None]
    bidx = jnp.arange(b)
    k_upd = cache["k"].at[bidx, lslot].set(k_new[:, 0])
    v_upd = cache["v"].at[bidx, lslot].set(v_new[:, 0])
    p_upd = cache["pos"].at[bidx, lslot].set(pos.astype(jnp.int32))
    k_c = jnp.where(mine[..., None], k_upd, cache["k"])
    v_c = jnp.where(mine[..., None], v_upd, cache["v"])
    p_c = jnp.where(mine[:, :, 0], p_upd, cache["pos"])

    partial = _decode_attn_math(params, q, k_c, v_c, p_c, positions,
                                x_dtype=x.dtype, cfg=cfg, lay=lay,
                                window=window, seq_axis=seq_axis)
    return partial, {"k": k_c, "v": v_c, "pos": p_c}


def _decode_attn_math(params, q, k, v, kpos, positions, *, x_dtype, cfg,
                      lay: AttnLayout, window, seq_axis=None):
    """Shared decode/verify epilogue: grouped-QK logits, masked stable
    softmax (optionally flash-decoding combined over a context-parallel
    ``seq_axis``), V accumulate, output projection.
    q: (B, Sq, h_loc, dh) grouped internally (Sq == 1 for plain decode,
    gamma+1 for the speculative verify window); k/v: (B, C, kvh, dh)."""
    b, sq = q.shape[0], q.shape[1]
    kvh = k.shape[2]
    g = lay.h_loc // kvh
    qg = q.reshape(b, sq, kvh, g, cfg.head_dim)
    # bf16 operands + f32 accumulation (MXU-native) — pre-casting the cache
    # to f32 would round-trip the whole KV through HBM at double width
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) \
        * (cfg.head_dim ** -0.5)
    msk = _mask(positions, kpos, True, window)  # (B, Sq, C)
    logits = jnp.where(msk[:, None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    if seq_axis is not None:
        m = lax.pmax(m, seq_axis)
    p = jnp.exp(logits - m[..., None])
    denom = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    if seq_axis is not None:
        # flash-decoding combine across context-parallel shards
        denom = lax.psum(denom, seq_axis)
        acc = lax.psum(acc, seq_axis)
    out = acc / jnp.maximum(denom, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, lay.h_loc * cfg.head_dim)
    partial = jnp.einsum("bsf,fd->bsd", out.astype(x_dtype),
                         _sq(params["wo"]))
    if lay.replicas > 1:
        partial = partial * lay.o_scale
    return partial


def attn_decode_paged(params, x, pool_layer, block_tables, *, positions, cfg,
                      lay: AttnLayout, theta, window: int = 0,
                      mrope_positions=None):
    """Single-token decode against one layer of the paged block pool.

    pool_layer: {"k": (nb, bs, kvh, dh), "v": ..., "pos": (nb, bs)} — the
    pool is SHARED across requests; each row of ``block_tables`` (B, nblk,
    int32, -1 = unallocated) maps a request's logical blocks to physical
    ones.  The new token scatters through the table (OOB-drop for inactive
    rows, pos < 0), then attention runs over the gathered rectangular
    (B, nblk*bs) view.  Sliding windows are enforced purely by the mask —
    paged layers have no ring buffer (DESIGN.md §7).  No ``seq_axis``:
    the shared block axis cannot shard over data, so the paged path is
    single-host (context-parallel decode stays on the slot path).

    Exactly the S_v == 1 case of ``attn_verify_paged`` (one scatter/gather
    implementation, shared epilogue).
    """
    return attn_verify_paged(params, x, pool_layer, block_tables,
                             positions=positions, cfg=cfg, lay=lay,
                             theta=theta, window=window,
                             mrope_positions=mrope_positions)


def attn_verify(params, x, cache, *, positions, cfg, lay: AttnLayout, theta,
                window: int = 0, mrope_positions=None):
    """Multi-token speculative-verify decode against the slot KV cache.

    x: (B, S_v, d) — a causal window of gamma+1 tokens per request (the
    pending decode input followed by the draft proposal; positions carry
    -1 for inactive rows and unused draft slots, whose writes are dropped).
    All S_v tokens are scattered into the cache FIRST, then attention runs
    with the causal mask restricting each query to its own prefix — so the
    epilogue is shared verbatim with ``attn_decode`` (the S_v == 1 case).

    Multi-token windows (S_v > 1) require full-attention layers: a
    sliding-window ring buffer (C == window) would let a later window
    write evict a key an earlier query in the same window still needs.
    The engine rejects spec decoding on the legacy backend for windowed
    models; the paged backend stores full-length KV and enforces windows
    by mask, so it is unaffected.  (S_v == 1 — plain ``attn_decode``
    delegating here — is safe for any layer kind: one write, one query.)
    """
    b, s, _ = x.shape
    q, k_new, v_new = _project_qkv(params, x, cfg, lay, positions=positions,
                                   theta=theta,
                                   mrope_positions=mrope_positions)
    c = cache["k"].shape[1]
    slot = jnp.where(positions >= 0, positions % c, c).astype(jnp.int32)
    bidx = jnp.arange(b)[:, None]
    k_c = cache["k"].at[bidx, slot].set(k_new, mode="drop")
    v_c = cache["v"].at[bidx, slot].set(v_new, mode="drop")
    p_c = cache["pos"].at[bidx, slot].set(positions.astype(jnp.int32),
                                          mode="drop")
    partial = _decode_attn_math(params, q, k_c, v_c, p_c, positions,
                                x_dtype=x.dtype, cfg=cfg, lay=lay,
                                window=window)
    return partial, {"k": k_c, "v": v_c, "pos": p_c}


def attn_verify_paged(params, x, pool_layer, block_tables, *, positions, cfg,
                      lay: AttnLayout, theta, window: int = 0,
                      mrope_positions=None):
    """Multi-token speculative-verify decode against the paged block pool:
    the gamma+1 window scatters through the block-table indirection (the
    engine has already grown/COW'd every block the window touches), then
    attention runs over the gathered rectangular view with the causal mask
    ordering queries within the window.  Shares the epilogue with
    ``attn_decode_paged``; same single-host restriction (DESIGN.md §7).
    """
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, cfg, lay, positions=positions,
                                   theta=theta,
                                   mrope_positions=mrope_positions)
    nb, bs = pool_layer["pos"].shape
    pos = positions                                        # (B, S_v)

    blk = jnp.where(pos >= 0, pos // bs, 0)
    phys = jnp.take_along_axis(block_tables, blk, axis=1)  # (B, S_v)
    phys = jnp.where((pos >= 0) & (phys >= 0), phys, nb)   # OOB -> dropped
    off = jnp.where(pos >= 0, pos % bs, 0)
    k_c = pool_layer["k"].at[phys, off].set(k_new, mode="drop")
    v_c = pool_layer["v"].at[phys, off].set(v_new, mode="drop")
    p_c = pool_layer["pos"].at[phys, off].set(pos.astype(jnp.int32),
                                              mode="drop")

    bt = jnp.maximum(block_tables, 0)
    nblk = bt.shape[1]
    kvh = k_c.shape[2]
    kg = k_c[bt].reshape(b, nblk * bs, kvh, cfg.head_dim)
    vg = v_c[bt].reshape(b, nblk * bs, kvh, cfg.head_dim)
    pg = jnp.where(block_tables[:, :, None] >= 0, p_c[bt], -1)
    pg = pg.reshape(b, nblk * bs)

    partial = _decode_attn_math(params, q, kg, vg, pg, positions,
                                x_dtype=x.dtype, cfg=cfg, lay=lay,
                                window=window)
    return partial, {"k": k_c, "v": v_c, "pos": p_c}


def attn_packed(params, x, cache, *, positions, seg_slots, cfg,
                lay: AttnLayout, theta, window: int = 0):
    """Packed mixed-segment step against the slot KV cache (DESIGN.md §6).

    x: (1, T, d) — prefill-chunk segments, single-token decode slots, and
    speculative verify windows concatenated along one token axis.
    seg_slots: (T,) int32 cache row owning each token (-1 = padding);
    positions: (1, T) absolute query positions (-1 = padding).

    All T tokens scatter into their owning rows FIRST (the same
    scatter-then-attend discipline as ``attn_verify``), then every token
    attends its own row's full cache view with the causal mask ordering
    queries against both pre-existing context and same-step keys — so a
    segment's later tokens see its earlier ones, and tokens never see
    other segments (different rows).  The epilogue is shared verbatim with
    ``attn_decode``/``attn_verify`` via ``_decode_attn_math``.

    Full-attention layers only on this backend: a packed chunk's scatter
    into a sliding-window ring buffer (C == window) could evict a key an
    earlier query in the same step still needs — the engine rejects
    packed mode on windowed legacy models (the paged backend stores
    full-length KV and masks, so it is unaffected).

    Cost note: the per-token row gather materializes (T, C, kvh, dh) —
    tokens of the same segment repeat their request's KV read, a
    T-vs-B amplification over the rectangular paths.  It is the same
    order as the (T, heads, C) score tensor this pure-jnp emulation
    already materializes, so asymptotics are unchanged on the CPU
    target; the TPU production form is a varlen flash kernel that
    streams each segment's KV once (vLLM-style), which this function is
    the reference semantics for.
    """
    _, t, _ = x.shape
    q, k_new, v_new = _project_qkv(params, x, cfg, lay, positions=positions,
                                   theta=theta)
    bslots, c = cache["pos"].shape
    pos = positions[0]                                        # (T,)
    row = jnp.where((seg_slots >= 0) & (pos >= 0), seg_slots,
                    bslots)                                   # OOB -> dropped
    slot = jnp.where(pos >= 0, pos % c, 0)
    k_c = cache["k"].at[row, slot].set(k_new[0], mode="drop")
    v_c = cache["v"].at[row, slot].set(v_new[0], mode="drop")
    p_c = cache["pos"].at[row, slot].set(pos.astype(jnp.int32), mode="drop")

    # per-token gather of the owning row: (T, C, kvh, dh); padding tokens
    # read row 0 but their qpos == -1 masks every key
    rsafe = jnp.clip(seg_slots, 0, bslots - 1)
    kg = k_c[rsafe]
    vg = v_c[rsafe]
    pg = p_c[rsafe]
    partial = _decode_attn_math(params, q[0][:, None], kg, vg, pg,
                                pos[:, None], x_dtype=x.dtype, cfg=cfg,
                                lay=lay, window=window)       # (T, 1, d)
    return jnp.swapaxes(partial, 0, 1), {"k": k_c, "v": v_c, "pos": p_c}


def attn_packed_paged(params, x, pool_layer, block_tables, *, positions,
                      seg_slots, cfg, lay: AttnLayout, theta,
                      window: int = 0):
    """Packed mixed-segment step against the paged block pool: each token
    scatters through its owning request's block table (the engine has
    already allocated/grown/COW'd every block the plan touches), then
    attends the gathered rectangular view of that table.  Unlike paged
    decode, packed steps CAN weave: splits consume the pool sequentially
    (suffix split reads the prefix split's writes) instead of forking it
    across a batch split.  Same single-host restriction as every paged
    path (DESIGN.md §7), and the same per-token gather amplification /
    varlen-kernel production note as ``attn_packed``.
    """
    _, t, _ = x.shape
    q, k_new, v_new = _project_qkv(params, x, cfg, lay, positions=positions,
                                   theta=theta)
    nb, bs = pool_layer["pos"].shape
    pos = positions[0]                                        # (T,)
    rsafe = jnp.clip(seg_slots, 0, block_tables.shape[0] - 1)
    bt_tok = block_tables[rsafe]                              # (T, nblk)
    blk = jnp.where(pos >= 0, pos // bs, 0)
    phys = jnp.take_along_axis(bt_tok, blk[:, None], axis=1)[:, 0]
    valid = (pos >= 0) & (seg_slots >= 0) & (phys >= 0)
    phys = jnp.where(valid, phys, nb)                         # OOB -> dropped
    off = jnp.where(pos >= 0, pos % bs, 0)
    k_c = pool_layer["k"].at[phys, off].set(k_new[0], mode="drop")
    v_c = pool_layer["v"].at[phys, off].set(v_new[0], mode="drop")
    p_c = pool_layer["pos"].at[phys, off].set(pos.astype(jnp.int32),
                                              mode="drop")

    bt = jnp.maximum(bt_tok, 0)
    nblk = bt.shape[1]
    kvh = k_c.shape[2]
    kg = k_c[bt].reshape(t, nblk * bs, kvh, cfg.head_dim)
    vg = v_c[bt].reshape(t, nblk * bs, kvh, cfg.head_dim)
    pg = jnp.where(bt_tok[:, :, None] >= 0, p_c[bt], -1)
    pg = pg.reshape(t, nblk * bs)
    partial = _decode_attn_math(params, q[0][:, None], kg, vg, pg,
                                pos[:, None], x_dtype=x.dtype, cfg=cfg,
                                lay=lay, window=window)       # (T, 1, d)
    return jnp.swapaxes(partial, 0, 1), {"k": k_c, "v": v_c, "pos": p_c}


def attn_cross(params, x, enc_kv, *, cfg, lay: AttnLayout):
    """Whisper-style cross attention: q from decoder x, kv precomputed from
    the encoder output (enc_kv = (k, v, kpos))."""
    b, s, _ = x.shape
    dh = cfg.head_dim
    q = jnp.einsum("bsd,df->bsf", x, _sq(params["wq"])).reshape(
        b, s, lay.h_loc, dh)
    k, v, kpos = enc_kv
    qpos = jnp.zeros((b, s), jnp.int32)
    out = multihead_attention(q, k, v, qpos, kpos, causal=False, impl="ref"
                              if s * k.shape[1] <= 256 * 256 else "chunked")
    out = out.reshape(b, s, lay.h_loc * dh)
    partial = jnp.einsum("bsf,fd->bsd", out, _sq(params["wo"]))
    if lay.replicas > 1:
        partial = partial * lay.o_scale
    return partial


def project_cross_kv(params, enc_out, *, cfg, lay: AttnLayout):
    b, s, _ = enc_out.shape
    dh = cfg.head_dim
    k = jnp.einsum("bsd,df->bsf", enc_out, _sq(params["wk"])).reshape(
        b, s, lay.kv_store, dh)
    v = jnp.einsum("bsd,df->bsf", enc_out, _sq(params["wv"])).reshape(
        b, s, lay.kv_store, dh)
    kpos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return k, v, kpos


def init_kv_cache(batch: int, max_len: int, cfg, tp: int, *, window: int = 0,
                  dtype=None, layers: int | None = None):
    """GLOBAL-shape KV cache pytree (L, B, C, kv_store*tp, dh) — the head
    axis shards over the model axis into per-shard kv_store heads (vLLM
    style KV duplication when kv_heads < tp). ``layers=0`` drops the
    leading layer axis (per-layer caches for unrolled models)."""
    lay = attention_layout(tp, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim)
    c = min(max_len, window) if window > 0 else max_len
    nl = layers if layers is not None else cfg.num_layers
    dt = dtype or jnp.dtype(cfg.dtype)
    lead = () if nl == 0 else (nl,)
    h_global = lay.kv_store * tp
    return {
        "k": jnp.zeros(lead + (batch, c, h_global, cfg.head_dim), dt),
        "v": jnp.zeros(lead + (batch, c, h_global, cfg.head_dim), dt),
        "pos": jnp.full(lead + (batch, c), -1, jnp.int32),
    }
