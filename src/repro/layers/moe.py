"""Mixture-of-Experts with three TP/EP partitionings (see DESIGN.md §3).

    expert : whole experts sharded over the model axis (E % tp == 0).
             Combine = the layer's TP AllReduce -> TokenWeave's fused
             AllReduce-RMSNorm applies unchanged. (olmoe)
    ffn    : every shard holds a d_ff slice of EVERY expert (E < tp,
             vLLM-style TP MoE). Combine = same TP AllReduce. (mixtral)
    ep2d   : experts over the `data` axis x d_ff over the `model` axis —
             the only layout that fits qwen3-moe-235b on v5e. Dispatch and
             return are all-to-alls over `data`; the returned values are
             still *partial over model*, so the layer-final fused
             AllReduce-RMSNorm still performs the reduction (the a2a and the
             model-axis psum commute). This is the DeepSeek-style EP the
             paper contrasts with: the a2a itself cannot fuse with the norm.

All dispatch is static-capacity (GShard-style, token dropping beyond
capacity) so every shape is static under jit.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _sq(p):
    return jnp.squeeze(p, axis=0)


def init_moe_params(key, cfg, tp: int, ep: int = 1):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dtype = jnp.dtype(cfg.dtype)
    kr, kg, ku, kd = jax.random.split(key, 4)
    s = d ** -0.5
    router = (jax.random.normal(kr, (1, d, e)) * s).astype(jnp.float32)
    mode = cfg.moe_partition
    if mode == "expert":
        assert e % tp == 0, (e, tp)
        e_loc, f_loc = e // tp, f
        shard_shape = (tp,)
    elif mode == "ffn":
        assert f % tp == 0
        e_loc, f_loc = e, f // tp
        shard_shape = (tp,)
    elif mode == "ep2d":
        assert e % ep == 0 and f % tp == 0
        e_loc, f_loc = e // ep, f // tp
        shard_shape = (ep, tp)
    else:
        raise ValueError(mode)
    def w(k, *shape, scale):
        return (jax.random.normal(k, shard_shape + shape) * scale).astype(dtype)
    return {
        "router": router,
        "w_gate": w(kg, e_loc, d, f_loc, scale=s),
        "w_up": w(ku, e_loc, d, f_loc, scale=s),
        "w_down": w(kd, e_loc, f_loc, d, scale=f ** -0.5),
    }


def moe_param_specs(cfg):
    from jax.sharding import PartitionSpec as P
    if cfg.moe_partition == "ep2d":
        wp = P("data", "model")
    else:
        wp = P("model")
    return {"router": P(None), "w_gate": wp, "w_up": wp, "w_down": wp}


def _route(x, router, cfg):
    """x: (T, d) -> (weights (T,k), ids (T,k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), _sq(router))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = lax.top_k(probs, cfg.num_experts_per_tok)
    if cfg.norm_topk_prob:
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    # load-balancing aux loss (Switch-style): E * sum(f_e * P_e)
    e = cfg.num_experts
    ids1 = jax.nn.one_hot(topi[:, 0], e)  # fraction by top-1 assignment
    f_e = jnp.mean(ids1, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return topw.astype(x.dtype), topi, aux


def _capacity_dispatch(x, topi, topw, *, n_local: int, lo: int, capacity: int):
    """Scatter tokens into per-expert buffers with static capacity.

    Returns (buf (n_local, C, d), slot (T*k,) int32 with -1 for
    dropped/remote, flat_w (T*k,)).
    """
    t, k = topi.shape
    d = x.shape[-1]
    flat_e = topi.reshape(-1) - lo
    flat_w = topw.reshape(-1)
    local = (flat_e >= 0) & (flat_e < n_local)
    le = jnp.where(local, flat_e, n_local)          # n_local = trash bin
    oh = jax.nn.one_hot(le, n_local + 1, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=-1) - 1
    keep = local & (pos < capacity)
    slot = jnp.where(keep, le * capacity + pos, -1)
    tok = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((n_local * capacity + 1, d), x.dtype)
    buf = buf.at[jnp.where(slot >= 0, slot, n_local * capacity)].set(
        x[tok], mode="drop")
    # row n_local*capacity collects drops; zero it
    buf = buf.at[n_local * capacity].set(0.0)
    return buf[:-1].reshape(n_local, capacity, d), slot, flat_w


def _expert_ffn(buf, params, act: str = "silu"):
    """buf: (E_loc, C, d) -> (E_loc, C, d) via batched expert matmuls."""
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    while wg.ndim > 3:  # strip shard axes (1 or 2 of them)
        wg, wu, wd = wg[0], wu[0], wd[0]
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _combine(out_buf, slot, flat_w, t: int, k: int):
    """Gather expert outputs back per assignment and weight-sum over k."""
    n_local, c, d = out_buf.shape
    flat = jnp.concatenate(
        [out_buf.reshape(-1, d), jnp.zeros((1, d), out_buf.dtype)], axis=0)
    gathered = flat[jnp.where(slot >= 0, slot, n_local * c)]
    gathered = gathered * flat_w[:, None].astype(gathered.dtype)
    return jnp.sum(gathered.reshape(t, k, d), axis=1)


def moe_forward(params, x, cfg, *, tp_axis: str = "model",
                ep_axis: str = "data") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) replicated over TP -> (partial out (B,S,d), aux_loss).

    Output is partial over the model axis in ALL modes; the caller's
    comm_norm performs the reduction (fused with the residual+RMSNorm).
    """
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    k = cfg.num_experts_per_tok
    topw, topi, aux = _route(xt, params["router"], cfg)
    mode = cfg.moe_partition

    if mode in ("expert", "ffn"):
        tp = lax.axis_size(tp_axis)
        if mode == "expert":
            e_loc = cfg.num_experts // tp
            lo = lax.axis_index(tp_axis) * e_loc
        else:
            e_loc, lo = cfg.num_experts, 0
        cap = int(math.ceil(t * k / cfg.num_experts * cfg.capacity_factor))
        cap = max(cap, 4)
        buf, slot, flat_w = _capacity_dispatch(
            xt, topi, topw, n_local=e_loc, lo=lo, capacity=cap)
        out_buf = _expert_ffn(buf, params)
        out = _combine(out_buf, slot, flat_w, t, k)
        return out.reshape(b, s, d), aux

    if mode != "ep2d":
        raise ValueError(mode)

    # ---- ep2d: a2a over `ep_axis`, expert d_ff sharded over `tp_axis` ----
    ep = lax.axis_size(ep_axis)
    e_loc = cfg.num_experts // ep
    dest = topi // e_loc                       # destination data-shard
    cs = int(math.ceil(t * k / ep * cfg.capacity_factor))
    cs = max(cs, 4)
    # slot within destination buffers
    flat_dest = dest.reshape(-1)
    oh = jax.nn.one_hot(flat_dest, ep, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=-1) - 1
    keep = pos < cs
    slot = jnp.where(keep, flat_dest * cs + pos, -1)
    tok = jnp.repeat(jnp.arange(t), k)
    send_x = jnp.zeros((ep * cs + 1, d), x.dtype)
    send_x = send_x.at[jnp.where(slot >= 0, slot, ep * cs)].set(xt[tok])
    send_x = send_x.at[ep * cs].set(0.0)[:-1].reshape(ep, cs, d)
    send_eid = jnp.full((ep * cs + 1,), -1, jnp.int32)
    send_eid = send_eid.at[jnp.where(slot >= 0, slot, ep * cs)].set(
        (topi % e_loc).reshape(-1))
    send_eid = send_eid.at[ep * cs].set(-1)[:-1].reshape(ep, cs)

    recv_x = lax.all_to_all(send_x, ep_axis, split_axis=0, concat_axis=0,
                            tiled=True)
    recv_eid = lax.all_to_all(send_eid, ep_axis, split_axis=0, concat_axis=0,
                              tiled=True)

    # local dispatch of received tokens into per-expert buffers
    rt = ep * cs
    rx = recv_x.reshape(rt, d)
    re = recv_eid.reshape(rt)
    cap2 = int(math.ceil(rt / e_loc * cfg.capacity_factor))
    valid = re >= 0
    le = jnp.where(valid, re, e_loc)
    oh2 = jax.nn.one_hot(le, e_loc + 1, dtype=jnp.int32)
    pos2 = jnp.sum(jnp.cumsum(oh2, axis=0) * oh2, axis=-1) - 1
    keep2 = valid & (pos2 < cap2)
    slot2 = jnp.where(keep2, le * cap2 + pos2, -1)
    buf = jnp.zeros((e_loc * cap2 + 1, d), x.dtype)
    buf = buf.at[jnp.where(slot2 >= 0, slot2, e_loc * cap2)].set(rx)
    buf = buf.at[e_loc * cap2].set(0.0)[:-1].reshape(e_loc, cap2, d)

    out_buf = _expert_ffn(buf, params)        # partial over model (f sliced)

    # return outputs to their arrival slots, then a2a back
    flat_out = jnp.concatenate(
        [out_buf.reshape(-1, d), jnp.zeros((1, d), out_buf.dtype)], axis=0)
    back = flat_out[jnp.where(slot2 >= 0, slot2, e_loc * cap2)]
    back = jnp.where(keep2[:, None], back, 0.0).reshape(ep, cs, d)
    reply = lax.all_to_all(back, ep_axis, split_axis=0, concat_axis=0,
                           tiled=True)

    # combine at home shard (weights never left)
    flat_reply = jnp.concatenate(
        [reply.reshape(-1, d), jnp.zeros((1, d), reply.dtype)], axis=0)
    gathered = flat_reply[jnp.where(slot >= 0, slot, ep * cs)]
    gathered = gathered * topw.reshape(-1)[:, None].astype(gathered.dtype)
    out = jnp.sum(gathered.reshape(t, k, d), axis=1)
    return out.reshape(b, s, d), aux
