"""Rotary position embeddings: standard RoPE, gemma-style dual-theta
(local/global layers), and Qwen2-VL M-RoPE (multimodal 3D sections)."""
from __future__ import annotations

import jax.numpy as jnp


def _rope_angles(positions, dim: int, theta):
    """positions (..., S) -> cos/sin (..., S, dim//2), fp32."""
    half = dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta):
    """x: (B, S, H, dh); positions: (B, S). Rotate-half (llama) convention."""
    dh = x.shape[-1]
    cos, sin = _rope_angles(positions, dh, theta)  # (B, S, dh/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta):
    """Qwen2-VL M-RoPE. positions3: (B, 3, S) — (temporal, height, width)
    position ids; ``sections`` splits the dh/2 frequency bands, each band
    using its own position row. Text tokens carry identical t/h/w ids, making
    M-RoPE degenerate to standard RoPE for them (as in the paper)."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # angles per position row: (B, 3, S, half)
    ang = positions3.astype(jnp.float32)[..., None] * freq
    rows = []
    lo = 0
    for r, sec in enumerate(sections):
        rows.append(ang[:, r, :, lo:lo + sec])
        lo += sec
    ang = jnp.concatenate(rows, axis=-1)  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
