"""State-space layers: Mamba-1 selective scan (falcon-mamba) and Mamba-2 SSD
(zamba2), tensor-parallel over d_inner / heads.

TokenWeave applicability (DESIGN.md §4): each block ends in a row-parallel
out_proj whose AllReduce slots into the fused AllReduce-RMSNorm, and all ops
are token-level except the recurrence itself — the token-split suffix simply
starts its scan from the prefix's final state (the SSM analogue of the
chunked-attention KV dependency).

Sharding notes:
  * mamba1: x_proj (dt/B/C from the sharded inner activation) needs a small
    psum over TP — (dt_rank + 2*state) per token, ~100x smaller than the
    d_model AllReduce.
  * mamba2: B/C are projected straight from the replicated layer input, so
    no extra collective; only the gated-RMSNorm variance needs a scalar psum.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _sq(p):
    return jnp.squeeze(p, axis=0)


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------

def causal_conv1d(x, w, b=None, *, state=None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C). state: (B, K-1, C)
    carry-in from the previous chunk/token. Returns (out, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    if b is not None:
        out = out + b
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return out, new_state


def _ssm_scan_chunked(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t along axis 1; a, b: (B, S, ...); h0 like
    a[:, 0]. Sequential over chunks, associative within. Returns (h_all, h_f).
    """
    bsz, s = a.shape[0], a.shape[1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        a = jnp.concatenate([a, jnp.ones((bsz, pad) + a.shape[2:], a.dtype)], 1)
        b = jnp.concatenate([b, jnp.zeros((bsz, pad) + b.shape[2:], b.dtype)], 1)
    n = (s + pad) // q
    a_c = jnp.moveaxis(a.reshape(bsz, n, q, *a.shape[2:]), 1, 0)
    b_c = jnp.moveaxis(b.reshape(bsz, n, q, *b.shape[2:]), 1, 0)

    def op(left, right):
        al, bl = left
        ar, br = right
        return al * ar, br + ar * bl

    def step(h, xs):
        ac, bc = xs
        pa, pb = lax.associative_scan(op, (ac, bc), axis=1)
        h_all = pa * h[:, None] + pb
        return h_all[:, -1], h_all

    h_f, ys = lax.scan(step, h0, (a_c, b_c))
    ys = jnp.moveaxis(ys, 0, 1).reshape(bsz, s + pad, *a.shape[2:])
    return ys[:, :s], h_f


# --------------------------------------------------------------------------
# Mamba-1 (falcon-mamba)
# --------------------------------------------------------------------------

def init_mamba1_params(key, cfg, tp: int):
    d, di, s_st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, k = cfg.ssm_dt_rank, cfg.ssm_conv
    assert di % tp == 0
    dil = di // tp
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    sc = d ** -0.5
    a_init = jnp.tile(jnp.arange(1, s_st + 1, dtype=jnp.float32)[None],
                      (dil, 1))
    return {
        "in_proj": (jax.random.normal(ks[0], (tp, d, 2 * dil)) * sc).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (tp, k, dil)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((tp, dil), dtype),
        "x_proj": (jax.random.normal(ks[2], (tp, dil, dtr + 2 * s_st))
                   * di ** -0.5).astype(dtype),
        "dt_w": (jax.random.normal(ks[3], (tp, dtr, dil)) * dtr ** -0.5).astype(dtype),
        "dt_b": jnp.full((tp, dil), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.tile(jnp.log(a_init)[None], (tp, 1, 1)).astype(jnp.float32),
        "D": jnp.ones((tp, dil), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (tp, dil, d)) * di ** -0.5).astype(dtype),
    }


def mamba1_param_specs(cfg):
    from jax.sharding import PartitionSpec as P
    return {k: P("model") for k in
            ("in_proj", "conv_w", "conv_b", "x_proj", "dt_w", "dt_b",
             "A_log", "D", "out_proj")}


def mamba1_forward(params, x, *, cfg, tp_axis: str = "model",
                   init_state: Tuple | None = None, chunk: int = 256):
    """x: (B, S, d) replicated -> (partial out (B,S,d), (conv_state, h_state)).

    ``init_state``: (conv_state, h) from a prefix token-split (or decode
    cache); the suffix resumes the recurrence exactly.
    """
    bsz, s, d = x.shape
    dil = params["conv_b"].shape[-1]
    s_st = cfg.ssm_state
    dtr = cfg.ssm_dt_rank
    conv_st, h0 = init_state if init_state is not None else (None, None)

    xz = jnp.einsum("bsd,de->bse", x, _sq(params["in_proj"]))
    xs, z = jnp.split(xz, 2, axis=-1)
    u, conv_st = causal_conv1d(xs, _sq(params["conv_w"]),
                               _sq(params["conv_b"]), state=conv_st)
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)

    # dt/B/C from the full inner dim: local partial + small psum over TP
    dbc = jnp.einsum("bse,ef->bsf", u, _sq(params["x_proj"]))
    dbc = lax.psum(dbc, tp_axis)
    dt_in, b_ssm, c_ssm = jnp.split(dbc.astype(jnp.float32),
                                    [dtr, dtr + s_st], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_in, _sq(params["dt_w"]).astype(jnp.float32))
        + _sq(params["dt_b"]).astype(jnp.float32))          # (B,S,dil)

    a_mat = -jnp.exp(_sq(params["A_log"]))                  # (dil, state)
    uf = u.astype(jnp.float32)
    a_bar = jnp.exp(dt[..., None] * a_mat)                  # (B,S,dil,state)
    b_bar = (dt * uf)[..., None] * b_ssm[:, :, None, :]     # (B,S,dil,state)
    if h0 is None:
        h0 = jnp.zeros((bsz, dil, s_st), jnp.float32)
    hs, h_f = _ssm_scan_chunked(a_bar, b_bar, h0, chunk)
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_ssm)
    y = y + _sq(params["D"]) * uf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    partial = jnp.einsum("bse,ed->bsd", y, _sq(params["out_proj"]))
    return partial, (conv_st, h_f)


def mamba1_decode(params, x, state, *, cfg, tp_axis: str = "model"):
    """Single-token step; state = (conv_state (B,K-1,dil), h (B,dil,s))."""
    out, new_state = mamba1_forward(params, x, cfg=cfg, tp_axis=tp_axis,
                                    init_state=state, chunk=1)
    return out, new_state


def init_mamba1_state(batch: int, cfg, tp: int, layers: int):
    """GLOBAL shapes; d_inner shards over the model axis."""
    di = cfg.d_inner
    return (
        jnp.zeros((layers, batch, cfg.ssm_conv - 1, di), jnp.dtype(cfg.dtype)),
        jnp.zeros((layers, batch, di, cfg.ssm_state), jnp.float32),
    )


# --------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2 backbone)
# --------------------------------------------------------------------------

def init_mamba2_params(key, cfg, tp: int):
    d, di, s_st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, k = cfg.ssm_heads, cfg.ssm_conv
    assert di % tp == 0 and nh % tp == 0
    dil, nhl = di // tp, nh // tp
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    sc = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (tp, d, 2 * dil + nhl)) * sc).astype(dtype),
        "in_proj_bc": (jax.random.normal(ks[1], (1, d, 2 * s_st)) * sc).astype(dtype),
        "conv_x": (jax.random.normal(ks[2], (tp, k, dil)) * 0.2).astype(dtype),
        "conv_bc": (jax.random.normal(ks[3], (1, k, 2 * s_st)) * 0.2).astype(dtype),
        "A_log": jnp.zeros((tp, nhl), jnp.float32),
        "D": jnp.ones((tp, nhl), jnp.float32),
        "dt_bias": jnp.full((tp, nhl), -4.6, jnp.float32),
        "gate_norm": jnp.ones((tp, dil), dtype),
        "out_proj": (jax.random.normal(ks[4], (tp, dil, d)) * di ** -0.5).astype(dtype),
    }


def mamba2_param_specs(cfg):
    from jax.sharding import PartitionSpec as P
    return {"in_proj": P("model"), "in_proj_bc": P(None), "conv_x": P("model"),
            "conv_bc": P(None), "A_log": P("model"), "D": P("model"),
            "dt_bias": P("model"), "gate_norm": P("model"),
            "out_proj": P("model")}


def _gated_rmsnorm_tp(y, z, w, eps, tp_axis):
    """RMSNorm(y * silu(z)) with the variance over the FULL (sharded) di."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    ss = lax.psum(jnp.sum(g * g, axis=-1, keepdims=True), tp_axis)
    n = g.shape[-1] * lax.axis_size(tp_axis)
    inv = lax.rsqrt(ss / n + eps)
    return (g * inv * w.astype(jnp.float32)).astype(z.dtype)


def mamba2_forward(params, x, *, cfg, tp_axis: str = "model",
                   init_state: Tuple | None = None, chunk: int = 128):
    """Chunked SSD. x: (B,S,d) -> (partial (B,S,d), (conv_state, h_state)).

    h_state: (B, nh_loc, dh, state). B/C shared across heads (n_groups=1).
    """
    bsz, s, d = x.shape
    s_st = cfg.ssm_state
    nhl = params["A_log"].shape[-1]
    dil = params["gate_norm"].shape[-1]
    dh = dil // nhl
    conv_st, h0 = init_state if init_state is not None else (None, None)

    zxdt = jnp.einsum("bsd,de->bse", x, _sq(params["in_proj"]))
    z, xs, dt_raw = jnp.split(zxdt, [dil, 2 * dil], axis=-1)
    bc = jnp.einsum("bsd,de->bse", x, _sq(params["in_proj_bc"]))
    xs, conv_x_st = causal_conv1d(xs, _sq(params["conv_x"]),
                                  state=None if conv_st is None else conv_st[0])
    bc, conv_bc_st = causal_conv1d(bc, _sq(params["conv_bc"]),
                                   state=None if conv_st is None else conv_st[1])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    bc = jax.nn.silu(bc.astype(jnp.float32))
    b_ssm, c_ssm = jnp.split(bc, 2, axis=-1)               # (B,S,state) fp32

    a_h = -jnp.exp(_sq(params["A_log"]))                   # (nhl,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + _sq(params["dt_bias"]))         # (B,S,nhl)
    xh = xs.reshape(bsz, s, nhl, dh).astype(jnp.float32)

    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ssm = jnp.pad(b_ssm, ((0, 0), (0, pad), (0, 0)))
        c_ssm = jnp.pad(c_ssm, ((0, 0), (0, pad), (0, 0)))
    n = (s + pad) // q
    xh = jnp.moveaxis(xh.reshape(bsz, n, q, nhl, dh), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(bsz, n, q, nhl), 1, 0)
    bck = jnp.moveaxis(b_ssm.reshape(bsz, n, q, s_st), 1, 0)
    cck = jnp.moveaxis(c_ssm.reshape(bsz, n, q, s_st), 1, 0)
    if h0 is None:
        h0 = jnp.zeros((bsz, nhl, dh, s_st), jnp.float32)

    idx = jnp.arange(q)
    causal = idx[:, None] >= idx[None, :]

    def step(h, xs_c):
        xc, dc, bcu, ccu = xs_c                  # (B,q,...)
        la = dc * a_h                            # log a_t  (B,q,nhl)
        cum = jnp.cumsum(la, axis=1)             # (B,q,nhl)
        # intra-chunk (quadratic within chunk)
        decay = jnp.exp(cum[:, :, None] - cum[:, None, :])      # (B,q,k,nhl)
        cb = jnp.einsum("bqs,bks->bqk", ccu, bcu)               # (B,q,k)
        m = cb[..., None] * decay * dc[:, None]                 # (B,q,k,nhl)
        m = jnp.where(causal[None, :, :, None], m, 0.0)
        y_intra = jnp.einsum("bqkh,bkhd->bqhd", m, xc)
        # inter-chunk from carried state
        y_inter = jnp.einsum("bqs,bhds,bqh->bqhd", ccu, h, jnp.exp(cum))
        # chunk state update
        decay_end = jnp.exp(cum[:, -1:, :] - cum)               # (B,q,nhl)
        s_c = jnp.einsum("bkh,bks,bkhd->bhds", dc * decay_end, bcu, xc)
        h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h + s_c
        return h_new, y_intra + y_inter

    h_f, ys = lax.scan(step, h0, (xh, dtc, bck, cck))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s + pad, nhl, dh)[:, :s]
    y = y + _sq(params["D"])[None, None, :, None] * \
        xs.reshape(bsz, s, nhl, dh).astype(jnp.float32)
    y = y.reshape(bsz, s, dil)
    y = _gated_rmsnorm_tp(y, z, _sq(params["gate_norm"]), cfg.norm_eps, tp_axis)
    partial = jnp.einsum("bse,ed->bsd", y.astype(x.dtype),
                         _sq(params["out_proj"]))
    return partial.astype(x.dtype), ((conv_x_st, conv_bc_st), h_f)


def mamba2_decode(params, x, state, *, cfg, tp_axis: str = "model"):
    return mamba2_forward(params, x, cfg=cfg, tp_axis=tp_axis,
                          init_state=state, chunk=1)


def init_mamba2_state(batch: int, cfg, tp: int, layers: int):
    """GLOBAL shapes; d_inner / heads shard over the model axis. The B/C
    conv state is replicated-per-shard (computed identically everywhere),
    so it carries a leading tp axis sharded over model."""
    di = cfg.d_inner
    nh = cfg.ssm_heads
    dh = di // nh
    k = cfg.ssm_conv
    dt = jnp.dtype(cfg.dtype)
    return (
        (jnp.zeros((layers, batch, k - 1, di), dt),
         jnp.zeros((layers, batch, k - 1, 2 * cfg.ssm_state), dt)),
        jnp.zeros((layers, batch, nh, dh, cfg.ssm_state), jnp.float32),
    )
