"""Tensor-parallel FFN: SwiGLU (llama family) or GELU (whisper family).

Column-parallel up/gate, row-parallel down; the output is *partial* over the
model axis — the reduction is owned by core.fused_collectives.comm_norm so
the AllReduce can fuse with the residual+RMSNorm (the paper's key op).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _sq(p):
    return jnp.squeeze(p, axis=0)


def init_mlp_params(key, cfg, tp: int, *, d_ff: int | None = None):
    d = cfg.d_model
    f = (d_ff or cfg.d_ff)
    assert f % tp == 0, (f, tp)
    f_loc = f // tp
    dtype = jnp.dtype(cfg.dtype)
    s = d ** -0.5
    ks = jax.random.split(key, 3)
    if cfg.act in ("silu", "geglu"):
        return {
            "w_gate": (jax.random.normal(ks[0], (tp, d, f_loc)) * s).astype(dtype),
            "w_up": (jax.random.normal(ks[1], (tp, d, f_loc)) * s).astype(dtype),
            "w_down": (jax.random.normal(ks[2], (tp, f_loc, d)) * (f ** -0.5)).astype(dtype),
        }
    return {
        "w_in": (jax.random.normal(ks[0], (tp, d, f_loc)) * s).astype(dtype),
        "b_in": jnp.zeros((tp, f_loc), dtype),
        "w_out": (jax.random.normal(ks[2], (tp, f_loc, d)) * (f ** -0.5)).astype(dtype),
        "b_out": jnp.zeros((1, d), dtype),
    }


def mlp_param_specs(cfg):
    from jax.sharding import PartitionSpec as P
    if cfg.act in ("silu", "geglu"):
        return {k: P("model") for k in ("w_gate", "w_up", "w_down")}
    return {"w_in": P("model"), "b_in": P("model"), "w_out": P("model"),
            "b_out": P(None)}


def mlp_forward(params, x, *, tp_axis: str = "model", act: str = "silu"):
    """x: (B, S, d) replicated -> partial (B, S, d) over the model axis."""
    if "w_gate" in params:
        g = jnp.einsum("bsd,df->bsf", x, _sq(params["w_gate"]))
        u = jnp.einsum("bsd,df->bsf", x, _sq(params["w_up"]))
        gf = g.astype(jnp.float32)
        gact = jax.nn.gelu(gf) if act == "geglu" else jax.nn.silu(gf)
        h = gact.astype(x.dtype) * u
        return jnp.einsum("bsf,fd->bsd", h, _sq(params["w_down"]))
    h = jnp.einsum("bsd,df->bsf", x, _sq(params["w_in"])) + _sq(params["b_in"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, _sq(params["w_out"]))
    # the psum downstream sums tp copies of the bias -> pre-divide
    return out + _sq(params["b_out"]) / jax.lax.axis_size(tp_axis)
