"""RMSNorm and the (unfused) residual-add + RMSNorm reference path.

All norm math accumulates in float32 regardless of activation dtype (matches
vLLM's layernorm kernels, which the paper's fused kernel was built on).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * weight.astype(jnp.float32)).astype(x.dtype)


def residual_rmsnorm_unfused(x, residual, weight, eps: float = 1e-6):
    """Two-pass reference: r = residual + x; out = rmsnorm(r).

    This is the baseline memory pattern the paper's fused kernel removes:
    write r, read r (variance), read r again (scale) -> 2 extra HBM passes.
    """
    r = (residual.astype(jnp.float32) + x.astype(jnp.float32)).astype(residual.dtype)
    return rms_norm(r, weight, eps), r
