"""Measured-time profiler: wall-clock per-forward timings (DESIGN.md §13).

Everything else in obs/ runs on *virtual* time — the §9 sim roofline
prices each forward and the trace/metrics record those estimates.  The
``WallClockProfiler`` adds the missing ground truth: it wraps the
engine's jitted dispatch functions with ``block_until_ready`` fencing
(drain pending device work keyed off the cache operand before starting
the timer, drain the dispatch's own outputs before stopping it) and
joins each measurement to the SAME ``WeaveAttribution`` record the
engine emits for that forward — so every sample carries
(tokens, mode, split, method) next to its wall seconds.

Jit compilation is excluded by construction: the first
``warmup_per_key`` calls of each compiled shape signature
(kind, batch, seq) are flagged ``warmup=True`` and dropped from the
steady-state statistics (they still appear in ``samples`` for
inspection, and a ``profile/warmup_excluded`` counter records how many
were dropped).

Steady samples land in three places:

  * ``MetricsRegistry``: ``profile/forward_us{mode=...,weave=...}``
    histograms (microseconds);
  * the Chrome trace: a parallel ``<track> [measured]`` track with
    ``cat="measured"`` complete spans (1 tick = 1 wall second, matching
    the virtual-time scale) so Perfetto shows measured durations next to
    the virtual spans they ground;
  * ``steady_samples()``: the raw joined records that
    ``analysis.calibration.fit_calibration`` consumes.

The profiler is pull-only: it never changes what the engine computes
(the wrapped function is called with identical arguments and its output
returned untouched), so profiled and unprofiled runs are token- and
step-identical — tests/test_profiler.py asserts this over the 25-trace
differential corpus.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.obs.attribution import WeaveAttribution
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TraceRecorder

MEASURED_TRACK_SUFFIX = " [measured]"
MEASURED_CAT = "measured"


@dataclasses.dataclass(frozen=True)
class MeasuredForward:
    """One timed dispatch joined to its weave-attribution record."""
    key: Tuple           # (kind, b, s) — the compiled shape signature
    kind: str            # prefill | decode | verify | packed
    method: str          # tokenweave | fuseonly | reordered | vanilla
    weave: bool
    tokens_static: int   # b * s — what the split decision saw
    tokens_real: int     # non-pad tokens committed by this forward
    split: Optional[Tuple[int, int]]
    wall_s: float        # fenced wall-clock seconds for this dispatch
    est_makespan: float  # §9 roofline prediction under the DEFAULT HW
    warmup: bool         # jit compile / first call on this shape: excluded


class WallClockProfiler:
    """Times engine dispatches; join happens at ``commit``.

    Lifecycle (all driven by the engine, see runtime/engine.py):

      1. ``attach(registry, trace=..., track=...)`` binds the sinks;
      2. ``wrap(fn)`` decorates a jitted dispatch function — the wrapper
         fences, times, and stashes the elapsed seconds as *pending*;
      3. ``commit(att)`` — called from the engine's single per-dispatch
         accounting site (``_note_forward``) — pops the pending timing
         and records the joined ``MeasuredForward``.

    Exactly one wrapped call happens between consecutive commits (the
    engine runs one model dispatch per ``_note_forward``), so the join
    needs no correlation ids.
    """

    def __init__(self, warmup_per_key: int = 1):
        self.warmup_per_key = max(int(warmup_per_key), 0)
        self.samples: List[MeasuredForward] = []
        self._seen: Dict[Tuple, int] = {}
        self._pending: Optional[float] = None
        self._registry: Optional[MetricsRegistry] = None
        self._trace: Optional[TraceRecorder] = None
        self._track = "engine"

    # -- wiring ----------------------------------------------------------
    def attach(self, registry: Optional[MetricsRegistry] = None, *,
               trace: Optional[TraceRecorder] = None,
               track: str = "engine") -> "WallClockProfiler":
        self._registry = registry
        self._trace = trace
        self._track = track
        return self

    def wrap(self, fn: Callable) -> Callable:
        """Fenced-timing decorator for a jitted dispatch function.

        ``args[1]`` is the KV-cache pytree by engine convention — fencing
        on it drains the device queue left by prior dispatches, so the
        timer measures only this call.  The output is drained too
        (``block_until_ready``) before the timer stops, then returned
        unmodified: wrapping never changes what the engine computes.
        """
        def timed(*args, **kwargs):
            if len(args) > 1:
                jax.block_until_ready(args[1])
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
            self._pending = time.perf_counter() - t0
            return out
        return timed

    # -- join ------------------------------------------------------------
    def commit(self, att: Optional[WeaveAttribution]) -> None:
        """Join the pending timing to this dispatch's attribution."""
        wall_s, self._pending = self._pending, None
        if wall_s is None or att is None:
            return
        skey = (att.kind, att.b, att.s)
        seen = self._seen.get(skey, 0)
        self._seen[skey] = seen + 1
        warmup = seen < self.warmup_per_key
        self.samples.append(MeasuredForward(
            key=skey, kind=att.kind, method=att.method, weave=att.weave,
            tokens_static=att.tokens_static, tokens_real=att.tokens_real,
            split=att.split, wall_s=wall_s,
            est_makespan=att.est_makespan, warmup=warmup))
        if warmup:
            if self._registry is not None:
                self._registry.counter("profile/warmup_excluded").inc()
            return
        if self._registry is not None:
            self._registry.histogram(
                "profile/forward_us", mode=att.kind,
                weave="on" if att.weave else "off").observe(wall_s * 1e6)
        if self._trace is not None:
            args = att.args()
            args["measured_us"] = round(wall_s * 1e6, 3)
            args["est_makespan"] = att.est_makespan
            self._trace.complete(
                self._track + MEASURED_TRACK_SUFFIX,
                f"measured/{att.kind}", self._trace.now, wall_s,
                cat=MEASURED_CAT, args=args)

    # -- readout ---------------------------------------------------------
    def steady_samples(self) -> List[MeasuredForward]:
        """Samples past the per-shape warmup window (calibration input)."""
        return [s for s in self.samples if not s.warmup]

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-kind steady-state totals: count / total / mean wall sec."""
        out: Dict[str, Dict[str, float]] = {}
        for s in self.steady_samples():
            row = out.setdefault(s.kind, {"n": 0, "total_s": 0.0})
            row["n"] += 1
            row["total_s"] += s.wall_s
        for row in out.values():
            row["mean_s"] = row["total_s"] / row["n"]
        return out
