"""Per-forward weave-decision attribution (DESIGN.md §12).

Every model dispatch the engine runs gets one ``WeaveAttribution``
record: what the weave decision saw (tokens, threshold, wave unit), what
it chose (split + reason, straight from
``models.transformer.weave_decision_info`` — the SAME decision object
that increments ``EngineStats.weave_forwards``, so trace-derived weave
rates match the counter exactly), and what that choice is worth — the
§9 two-stream sim roofline's estimate of compute / comm / overlapped
virtual time for this forward (``sim.overlap_sim.step_attribution``).

The ``Attributor`` prices with ``HW(tile=pcfg.split_unit_for(tp))`` so
the sim's split decisions quantize at the same wave unit the engine
actually uses, and memoizes by (mode, tokens, budget): a steady decode
loop prices each distinct batch size once.

Since DESIGN.md §14 the decision may come from a tuned per-site overlap
plan rather than the global threshold: each record then carries the plan
id + tokens-bucket that keyed the plan entry, the sim pricing follows
the plan's method (``sim_method``) and resource budget, and a tuned
split point is priced explicitly instead of re-derived by the sim.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.transformer import WeaveInfo
from repro.sim.overlap_sim import HW, step_attribution


@dataclasses.dataclass(frozen=True)
class WeaveAttribution:
    """One forward step's weave decision + estimated time breakdown."""
    kind: str            # prefill | decode | verify | packed
    b: int
    s: int
    tokens_real: int     # non-pad tokens committed by this forward
    tokens_static: int   # b * s — what the split decision saw
    weave: bool
    reason: str          # split | below_min_tokens | below_wave_floor |
    #                      weave_disabled | paged_pool_unsplit |
    #                      plan_split | plan_unsplit
    split: Optional[Tuple[int, int]]
    method: str          # tokenweave | ringweave | ring | fuseonly |
    #                      reordered | vanilla
    threshold: int
    unit: int
    est_compute: float
    est_comm: float
    est_overlapped: float
    est_makespan: float
    plan_id: int = 0     # overlap plan that decided (0 = global threshold)
    bucket: str = ""     # tokens-bucket the plan lookup keyed on
    budget: float = 1.0  # comm resource-budget fraction the plan granted

    def args(self) -> dict:
        """JSON-able Chrome-trace ``args`` payload; carries every field
        ``validate_chrome_trace`` requires of a forward span."""
        return {
            "kind": self.kind,
            "weave": self.weave,
            "reason": self.reason,
            "tokens": self.tokens_static,
            "tokens_real": self.tokens_real,
            "threshold": self.threshold,
            "split": list(self.split) if self.split else None,
            "method": self.method,
            "plan_id": self.plan_id,
            "bucket": self.bucket,
            "est_compute": round(self.est_compute, 9),
            "est_comm": round(self.est_comm, 9),
            "est_overlapped": round(self.est_overlapped, 9),
        }


class Attributor:
    """Prices forward steps on the §9 sim roofline for trace spans."""

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig, tp: int):
        self.cfg = cfg
        self.pcfg = pcfg
        self.tp = max(int(tp), 1)
        self.hw = HW(tile=pcfg.split_unit_for(self.tp))
        self._cache: Dict[Tuple, Dict[str, float]] = {}

    def price(self, mode: str, tokens: int,
              split: Optional[Tuple[int, int]] = None,
              budget: float = 1.0) -> Dict[str, float]:
        key = (mode, tokens, split, budget)
        got = self._cache.get(key)
        if got is None:
            got = self._cache[key] = step_attribution(
                self.cfg, mode, max(tokens, 1), tp=self.tp, hw=self.hw,
                split=split,
                comm_budget=None if budget == 1.0 else budget)
        return got

    def attribute(self, info: WeaveInfo, *, b: int, s: int, n_real: int,
                  kind: str) -> WeaveAttribution:
        if info.sim_method:
            # a tuned plan entry forced this pricing mode (DESIGN.md §14);
            # checked BEFORE info.weave so a fused plan split prices as
            # ringweave, not as the composed tokenweave
            method = info.sim_method
        elif info.weave:
            method = "tokenweave"
        else:
            method = {"fused": "fuseonly",
                      "reordered": "reordered"}.get(self.pcfg.comm_mode,
                                                    "vanilla")
        # a tuned (plan_split) weave carries an explicit split point the
        # sim must price verbatim; legacy splits re-derive inside the sim
        # (identical by construction, and token counts may be row counts)
        split = (info.split if info.weave and info.reason == "plan_split"
                 and info.axis == "packed" else None)
        est = self.price(method, b * s, split=split, budget=info.budget)
        return WeaveAttribution(
            kind=kind, b=b, s=s, tokens_real=n_real, tokens_static=b * s,
            weave=info.weave, reason=info.reason, split=info.split,
            method=method, threshold=info.threshold, unit=info.unit,
            est_compute=est["compute"], est_comm=est["comm"],
            est_overlapped=est["overlapped"], est_makespan=est["makespan"],
            plan_id=info.plan_id, bucket=info.bucket, budget=info.budget)
