"""Per-forward weave-decision attribution (DESIGN.md §12).

Every model dispatch the engine runs gets one ``WeaveAttribution``
record: what the weave decision saw (tokens, threshold, wave unit), what
it chose (split + reason, straight from
``models.transformer.weave_decision_info`` — the SAME decision object
that increments ``EngineStats.weave_forwards``, so trace-derived weave
rates match the counter exactly), and what that choice is worth — the
§9 two-stream sim roofline's estimate of compute / comm / overlapped
virtual time for this forward (``sim.overlap_sim.step_attribution``).

The ``Attributor`` prices with ``HW(tile=pcfg.split_unit_for(tp))`` so
the sim's split decisions quantize at the same wave unit the engine
actually uses, and memoizes by (mode, tokens): a steady decode loop
prices each distinct batch size once.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.transformer import WeaveInfo
from repro.sim.overlap_sim import HW, step_attribution


@dataclasses.dataclass(frozen=True)
class WeaveAttribution:
    """One forward step's weave decision + estimated time breakdown."""
    kind: str            # prefill | decode | verify | packed
    b: int
    s: int
    tokens_real: int     # non-pad tokens committed by this forward
    tokens_static: int   # b * s — what the split decision saw
    weave: bool
    reason: str          # split | below_min_tokens | below_wave_floor |
    #                      weave_disabled | paged_pool_unsplit
    split: Optional[Tuple[int, int]]
    method: str          # tokenweave | fuseonly | reordered | vanilla
    threshold: int
    unit: int
    est_compute: float
    est_comm: float
    est_overlapped: float
    est_makespan: float

    def args(self) -> dict:
        """JSON-able Chrome-trace ``args`` payload; carries every field
        ``validate_chrome_trace`` requires of a forward span."""
        return {
            "kind": self.kind,
            "weave": self.weave,
            "reason": self.reason,
            "tokens": self.tokens_static,
            "tokens_real": self.tokens_real,
            "threshold": self.threshold,
            "split": list(self.split) if self.split else None,
            "method": self.method,
            "est_compute": round(self.est_compute, 9),
            "est_comm": round(self.est_comm, 9),
            "est_overlapped": round(self.est_overlapped, 9),
        }


class Attributor:
    """Prices forward steps on the §9 sim roofline for trace spans."""

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig, tp: int):
        self.cfg = cfg
        self.pcfg = pcfg
        self.tp = max(int(tp), 1)
        self.hw = HW(tile=pcfg.split_unit_for(self.tp))
        self._cache: Dict[Tuple[str, int], Dict[str, float]] = {}

    def price(self, mode: str, tokens: int) -> Dict[str, float]:
        key = (mode, tokens)
        got = self._cache.get(key)
        if got is None:
            got = self._cache[key] = step_attribution(
                self.cfg, mode, max(tokens, 1), tp=self.tp, hw=self.hw)
        return got

    def attribute(self, info: WeaveInfo, *, b: int, s: int, n_real: int,
                  kind: str) -> WeaveAttribution:
        if info.weave:
            method = "tokenweave"
        else:
            method = {"fused": "fuseonly",
                      "reordered": "reordered"}.get(self.pcfg.comm_mode,
                                                    "vanilla")
        est = self.price(method, b * s)
        return WeaveAttribution(
            kind=kind, b=b, s=s, tokens_real=n_real, tokens_static=b * s,
            weave=info.weave, reason=info.reason, split=info.split,
            method=method, threshold=info.threshold, unit=info.unit,
            est_compute=est["compute"], est_comm=est["comm"],
            est_overlapped=est["overlapped"], est_makespan=est["makespan"])
