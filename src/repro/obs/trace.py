"""Structured trace events on the deterministic virtual clock
(DESIGN.md §12).

``TraceRecorder`` collects three raw event kinds:

* **spans**    — ``complete(track, name, ts, dur)``: a named interval on a
  replica track.  The engine emits one ``step/*`` span per iteration and
  one nested ``forward/*`` span per model dispatch (carrying the weave
  attribution record, obs/attribution.py).
* **instants** — point events on a track.
* **request lifecycle events** — ``request_event(rid, phase)``: arrival →
  queued → admit → prefill_done → (preempt | handoff_export →
  handoff_adopt)* → finish | cancel | expire.  Exactly one terminal phase
  per admitted request is an exported invariant
  (``validate_chrome_trace``), including cancels that land mid-migration.

Time is whatever virtual clock the caller owns: ``OnlineServer`` /
``Replica`` push their clock in via ``sync`` before each engine step; a
bare offline ``Engine`` self-advances one tick per step via ``auto``
(which defers to ``sync`` forever after the first external sync).  The
recorder never reads wall time, so a trace is a pure function of the
workload — and recording is observation only: tracing on vs off is
token-identical and step-count-identical (DESIGN.md §12, pinned by
tests/test_obs.py on the differential corpus).

``export_chrome_trace`` emits the Chrome-trace / Perfetto JSON object
format (one process per track, plus a ``requests`` process with one
thread per request); load it at https://ui.perfetto.dev.  One virtual
tick maps to one second (1e6 µs).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple, Union

# virtual ticks -> chrome-trace microseconds (1 tick = 1s)
TS_SCALE = 1_000_000.0

TERMINAL_PHASES = ("finish", "cancel", "expire")

# lifecycle phase -> the state the request is in UNTIL its next event
# (drawn as a derived span on the request's thread)
_SEGMENT = {
    "arrival": "pending",
    "queued": "queued",
    "admit": "prefill",
    "prefill_done": "decode",
    "preempt": "queued",
    "handoff_export": "migrating",
    "handoff_adopt": "decode",
    "requeue": "queued",     # replica died; re-admitted elsewhere (§15)
}


class TraceRecorder:
    """Collects structured events; ``None`` (the default everywhere) means
    tracing is off and no observability code runs at all.

    ``request_ns`` prefixes request ids so independent workloads merged
    into one exported trace (the benchmark sweep) cannot collide, while a
    cluster — many engines, ONE recorder — keeps a single lifecycle per
    rid across migrations.
    """

    def __init__(self, request_ns: str = ""):
        self.request_ns = request_ns
        self.now = 0.0
        self.events: List[dict] = []
        self._synced = False

    # ---- clock ---------------------------------------------------------
    def sync(self, t: float) -> None:
        """External virtual-clock owners (OnlineServer, Replica) stamp the
        recorder before each engine step.  Per-track monotonicity follows
        from each owner's clock being monotonic."""
        self._synced = True
        self.now = float(t)

    def auto(self, t: float) -> None:
        """Offline-engine fallback clock (one tick per step); a no-op once
        any external owner has synced."""
        if not self._synced:
            self.now = float(t)

    # ---- raw events ----------------------------------------------------
    def complete(self, track: str, name: str, ts: float, dur: float,
                 cat: str = "step", args: Optional[dict] = None) -> None:
        self.events.append({"kind": "span", "track": track, "name": name,
                            "cat": cat, "ts": float(ts), "dur": float(dur),
                            "args": args or {}})

    def instant(self, track: str, name: str, ts: Optional[float] = None,
                cat: str = "mark", args: Optional[dict] = None) -> None:
        self.events.append({"kind": "instant", "track": track, "name": name,
                            "cat": cat,
                            "ts": self.now if ts is None else float(ts),
                            "args": args or {}})

    def request_event(self, rid, phase: str, ts: Optional[float] = None,
                      args: Optional[dict] = None) -> None:
        self.events.append({"kind": "request",
                            "rid": f"{self.request_ns}{rid}",
                            "phase": phase,
                            "ts": self.now if ts is None else float(ts),
                            "args": args or {}})


def weave_counts_from_trace(rec: TraceRecorder,
                            track: Optional[str] = None
                            ) -> Tuple[int, int]:
    """(weave_forwards, forwards) recomputed from the recorded per-forward
    attribution spans — the trace-side ground truth that must equal
    ``EngineStats.weave_forwards / forwards`` exactly (DESIGN.md §12)."""
    weave = total = 0
    for ev in rec.events:
        if ev["kind"] != "span" or ev["cat"] != "forward":
            continue
        if track is not None and ev["track"] != track:
            continue
        total += 1
        weave += int(bool(ev["args"].get("weave")))
    return weave, total


# --------------------------------------------------------------------------
# Chrome-trace / Perfetto export
# --------------------------------------------------------------------------

def export_chrome_trace(rec: Union[TraceRecorder, List[TraceRecorder]],
                        path: Optional[str] = None) -> dict:
    """Convert recorder(s) to the Chrome-trace JSON object format.

    Layout: pid 1 is the ``requests`` process (one thread per request,
    instants per lifecycle phase plus derived state spans between them);
    every distinct track gets its own process from pid 2 up, events on
    tid 0.  Event order within a (pid, tid) preserves emission order,
    which ``validate_chrome_trace`` checks is time-monotonic.
    """
    recs = rec if isinstance(rec, list) else [rec]
    events: List[dict] = []
    track_pid: Dict[str, int] = {}
    req_tid: Dict[str, int] = {}
    REQ_PID = 1
    events.append({"name": "process_name", "ph": "M", "pid": REQ_PID,
                   "tid": 0, "args": {"name": "requests"}})

    def pid_of(track: str) -> int:
        if track not in track_pid:
            track_pid[track] = 2 + len(track_pid)
            events.append({"name": "process_name", "ph": "M",
                           "pid": track_pid[track], "tid": 0,
                           "args": {"name": track}})
        return track_pid[track]

    def tid_of(rid: str) -> int:
        if rid not in req_tid:
            req_tid[rid] = 1 + len(req_tid)
            events.append({"name": "thread_name", "ph": "M", "pid": REQ_PID,
                           "tid": req_tid[rid],
                           "args": {"name": f"req {rid}"}})
        return req_tid[rid]

    # group request events per rid so derived state spans interleave with
    # their instants in time order
    by_rid: Dict[str, List[dict]] = {}
    for r in recs:
        for ev in r.events:
            if ev["kind"] == "span":
                events.append({"name": ev["name"], "cat": ev["cat"],
                               "ph": "X", "ts": ev["ts"] * TS_SCALE,
                               "dur": ev["dur"] * TS_SCALE,
                               "pid": pid_of(ev["track"]), "tid": 0,
                               "args": ev["args"]})
            elif ev["kind"] == "instant":
                events.append({"name": ev["name"], "cat": ev["cat"],
                               "ph": "i", "s": "t",
                               "ts": ev["ts"] * TS_SCALE,
                               "pid": pid_of(ev["track"]), "tid": 0,
                               "args": ev["args"]})
            else:
                by_rid.setdefault(ev["rid"], []).append(ev)

    for rid, evs in by_rid.items():
        tid = tid_of(rid)
        for i, ev in enumerate(evs):
            events.append({"name": ev["phase"], "cat": "request",
                           "ph": "i", "s": "t", "ts": ev["ts"] * TS_SCALE,
                           "pid": REQ_PID, "tid": tid, "args": ev["args"]})
            seg = _SEGMENT.get(ev["phase"])
            if seg is not None and i + 1 < len(evs):
                events.append({"name": seg, "cat": "request_phase",
                               "ph": "X", "ts": ev["ts"] * TS_SCALE,
                               "dur": (evs[i + 1]["ts"] - ev["ts"])
                               * TS_SCALE,
                               "pid": REQ_PID, "tid": tid, "args": {}})

    doc = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": {"clock": "virtual (1 tick = 1s)",
                         "schema": "repro.obs DESIGN.md §12"}}
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
    return doc


# --------------------------------------------------------------------------
# schema validation (scripts/trace_view.py --validate; CI bench job)
# --------------------------------------------------------------------------

def validate_chrome_trace(doc: dict) -> List[str]:
    """Structural + semantic checks over an exported trace.  Returns a
    list of failure strings (empty = valid):

    * every event carries name/ph/ts/pid/tid; complete spans a dur >= 0;
    * per (pid, tid), timestamps are monotonically nondecreasing in
      emission order (the virtual-clock monotonicity invariant);
    * every ``forward`` span nests inside a ``step`` span on its track;
    * every forward span carries the full weave attribution record;
    * request threads: at most one terminal phase, EXACTLY one for every
      admitted request, and nothing after the terminal.
    """
    fails: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]

    last_ts: Dict[Tuple[int, int], float] = {}
    steps: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    forwards: List[Tuple[Tuple[int, int], float, float, dict]] = []
    req_phases: Dict[Tuple[int, int], List[str]] = {}

    for i, ev in enumerate(evs):
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                fails.append(f"event {i}: missing {field!r}")
                break
        else:
            ph = ev["ph"]
            if ph == "M":
                continue
            if "ts" not in ev or not isinstance(ev["ts"], (int, float)):
                fails.append(f"event {i} ({ev['name']}): bad ts")
                continue
            key = (ev["pid"], ev["tid"])
            if ev["ts"] < last_ts.get(key, float("-inf")) - 1e-6:
                fails.append(
                    f"event {i} ({ev['name']}): ts {ev['ts']} goes "
                    f"backwards on track pid={key[0]} tid={key[1]} "
                    f"(last {last_ts[key]})")
            last_ts[key] = max(last_ts.get(key, float("-inf")), ev["ts"])
            if ph == "X":
                dur = ev.get("dur")
                if not isinstance(dur, (int, float)) or dur < 0:
                    fails.append(f"event {i} ({ev['name']}): complete span "
                                 f"needs dur >= 0, got {dur!r}")
                    continue
                if ev.get("cat") == "step":
                    steps.setdefault(key, []).append((ev["ts"],
                                                      ev["ts"] + dur))
                elif ev.get("cat") == "forward":
                    forwards.append((key, ev["ts"], ev["ts"] + dur,
                                     ev.get("args", {})))
            elif ph == "i" and ev.get("cat") == "request":
                req_phases.setdefault(key, []).append(ev["name"])

    eps = 1e-3  # µs — float slack on nested span edges
    required = ("weave", "reason", "tokens", "threshold", "method",
                "plan_id", "est_compute", "est_comm", "est_overlapped")
    for key, t0, t1, args in forwards:
        if not any(s0 - eps <= t0 and t1 <= s1 + eps
                   for s0, s1 in steps.get(key, [])):
            fails.append(f"forward span at ts={t0} on pid={key[0]} not "
                         f"nested in any step span")
        missing = [f for f in required if f not in args]
        if missing:
            fails.append(f"forward span at ts={t0}: attribution record "
                         f"missing {missing}")

    for key, phases in req_phases.items():
        terms = [p for p in phases if p in TERMINAL_PHASES]
        admitted = any(p in ("admit", "handoff_adopt") for p in phases)
        if len(terms) > 1:
            fails.append(f"request tid={key[1]}: {len(terms)} terminal "
                         f"events {terms}")
        if admitted and len(terms) != 1:
            fails.append(f"request tid={key[1]}: admitted but "
                         f"{len(terms)} terminal event(s) (phases: "
                         f"{phases})")
        if terms and phases[-1] not in TERMINAL_PHASES:
            fails.append(f"request tid={key[1]}: events after terminal "
                         f"{terms[0]!r}: {phases}")
    return fails
