"""Weave-aware observability layer (DESIGN.md §12, §13).

Four pieces, all zero-cost when off:

* ``metrics``      — typed registry (counters / gauges / histograms with
                     labels) that ``Engine``, ``OnlineServer`` and
                     ``ClusterServer`` publish through; ``snapshot()``
                     feeds the CI-gated benchmark metrics.
* ``trace``        — ``TraceRecorder`` structured events + nested spans on
                     the deterministic virtual clock, exported as
                     Chrome-trace / Perfetto JSON
                     (``export_chrome_trace``), one track per replica plus
                     a per-request lifecycle track.
* ``attribution``  — the per-forward weave-decision record: tokens seen,
                     threshold, split chosen, overlap method, and the
                     §9 sim-roofline estimate of compute / comm /
                     overlapped virtual time, so ``EngineStats.weave_rate``
                     is derivable from the trace (DESIGN.md §12).
* ``profiler``     — the one deliberate exception to virtual-clock-only:
                     ``WallClockProfiler`` measures fenced per-dispatch
                     wall time joined to the attribution record, feeding
                     the ``analysis.calibration`` cost-model fit and the
                     ``[measured]`` trace track (DESIGN.md §13).
"""
from repro.obs.attribution import Attributor, WeaveAttribution
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               percentile)
from repro.obs.profiler import (MEASURED_CAT, MeasuredForward,
                                WallClockProfiler)
from repro.obs.trace import (TERMINAL_PHASES, TraceRecorder,
                             export_chrome_trace, validate_chrome_trace,
                             weave_counts_from_trace)

__all__ = [
    "Attributor", "WeaveAttribution",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "percentile",
    "MEASURED_CAT", "MeasuredForward", "WallClockProfiler",
    "TERMINAL_PHASES", "TraceRecorder", "export_chrome_trace",
    "validate_chrome_trace", "weave_counts_from_trace",
]
