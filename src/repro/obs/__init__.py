"""Weave-aware observability layer (DESIGN.md §12).

Three pieces, all deterministic (virtual-clock time only, never wall
clock) and all zero-cost when tracing is off:

* ``metrics``      — typed registry (counters / gauges / histograms with
                     labels) that ``Engine``, ``OnlineServer`` and
                     ``ClusterServer`` publish through; ``snapshot()``
                     feeds the CI-gated benchmark metrics.
* ``trace``        — ``TraceRecorder`` structured events + nested spans on
                     the deterministic virtual clock, exported as
                     Chrome-trace / Perfetto JSON
                     (``export_chrome_trace``), one track per replica plus
                     a per-request lifecycle track.
* ``attribution``  — the per-forward weave-decision record: tokens seen,
                     threshold, split chosen, overlap method, and the
                     §10 sim-roofline estimate of compute / comm /
                     overlapped virtual time, so ``EngineStats.weave_rate``
                     is derivable from the trace (DESIGN.md §12).
"""
from repro.obs.attribution import Attributor, WeaveAttribution
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               percentile)
from repro.obs.trace import (TERMINAL_PHASES, TraceRecorder,
                             export_chrome_trace, validate_chrome_trace,
                             weave_counts_from_trace)

__all__ = [
    "Attributor", "WeaveAttribution",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "percentile",
    "TERMINAL_PHASES", "TraceRecorder", "export_chrome_trace",
    "validate_chrome_trace", "weave_counts_from_trace",
]
