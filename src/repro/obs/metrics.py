"""Typed metrics registry (DESIGN.md §12).

Counters, gauges and histograms with optional labels.  The runtime's
public ``stats`` objects (``EngineStats``, ``LatencyStats``,
``ClusterStats``) are thin read views over one of these registries —
every mutation goes through an instrument, so a registry ``snapshot()``
is the single source of truth the benchmark harness emits gated metrics
from (scripts/check_bench.py enforces that provenance).

Everything here is deterministic host-side bookkeeping: values come from
request/token counters and the virtual clock, never from wall time.
Instruments are cheap plain-attribute objects; the hot engine counters
are fetched once at construction and mutated via ``inc`` — no dict
lookup per step.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple


def percentile(xs: List[float], q: float) -> float:
    """Linear-interpolation percentile (numpy default) over a copy —
    deterministic, no numpy dtype surprises in JSON metrics."""
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    pos = (len(s) - 1) * q
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (pos - lo))


class Counter:
    """Monotonically increasing integer-ish counter."""
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        self.value += n


class Gauge:
    """Last-value (or running-max) instrument for derived/level metrics."""
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def set_max(self, v: float) -> None:
        if v > self.value:
            self.value = float(v)


class Histogram:
    """Exact-sample histogram: virtual-time latency distributions are
    small (one sample per request), so we keep the samples and compute
    percentiles exactly — the same math `LatencyStats` always used."""
    __slots__ = ("name", "labels", "values")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def total(self) -> float:
        return float(sum(self.values))

    def percentile(self, q: float) -> float:
        return percentile(self.values, q)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """One namespace of typed instruments.

    ``counter/gauge/histogram(name, **labels)`` get-or-create; asking for
    an existing name with a different kind is a type error (that is what
    makes the registry *typed*).  ``snapshot()`` flattens everything to a
    ``{key: float}`` dict — ``name`` or ``name{k=v,...}``, histograms as
    ``<name>/count`` and ``<name>/p50|p90|p99`` — which is exactly the
    shape ``benchmarks/run.py --json`` and the CI gate consume.
    """

    def __init__(self):
        self._instruments: Dict[Tuple[str, Tuple], object] = {}
        self._kind_of: Dict[Tuple[str, Tuple], str] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, str]):
        lk = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        key = (name, lk)
        inst = self._instruments.get(key)
        if inst is None:
            self._instruments[key] = inst = _KINDS[kind](name, lk)
            self._kind_of[key] = kind
            return inst
        if self._kind_of[key] != kind:
            raise TypeError(
                f"metric {name!r}{dict(lk) or ''} is a "
                f"{self._kind_of[key]}, not a {kind}")
        return inst

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels: str) -> Histogram:
        return self._get("histogram", name, labels)

    def get(self, name: str, **labels: str) -> Optional[object]:
        """Peek an instrument without creating it (None when absent)."""
        lk = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        return self._instruments.get((name, lk))

    @staticmethod
    def _render(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={v}" for k, v in labels)
        return f"{name}{{{inner}}}"

    def snapshot(self, quantiles: Tuple[float, ...] = (0.5, 0.9, 0.99)
                 ) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for (name, lk), inst in sorted(self._instruments.items()):
            base = self._render(name, lk)
            if isinstance(inst, Histogram):
                out[f"{base}/count"] = float(inst.count)
                for q in quantiles:
                    out[f"{base}/p{int(q * 100)}"] = inst.percentile(q)
            else:
                out[base] = float(inst.value)
        return out
