"""repro: TokenWeave — efficient compute-communication overlap for distributed
LLM inference — reproduced and extended as a TPU-native JAX framework."""

from repro import compat as _compat  # noqa: F401  (installs jax shims)

__version__ = "0.1.0"
