"""repro: TokenWeave — efficient compute-communication overlap for distributed
LLM inference — reproduced and extended as a TPU-native JAX framework."""

__version__ = "0.1.0"
