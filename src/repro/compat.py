"""Compatibility shims for the range of jax releases this repo runs on.

The codebase is written against the current jax API surface:

* ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
* ``jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto, ...))``

Older releases (the container ships jax 0.4.37) expose ``shard_map`` only
under ``jax.experimental.shard_map`` (with ``check_rep`` instead of
``check_vma``), ``jax.make_mesh`` without the ``axis_types`` parameter, and
no ``jax.sharding.AxisType`` at all.  ``install()`` patches the ``jax``
namespace so the same call sites work on both; it is a no-op on new jax.

``install()`` runs automatically on ``import repro`` (and, because
``src/sitecustomize.py`` imports this module, in every interpreter launched
with ``PYTHONPATH=src`` — including the subprocess snippets the distributed
tests spawn, which call ``jax.make_mesh`` before importing repro).
"""
from __future__ import annotations

import enum
import functools
import inspect

_installed = False

# True on jax with varying-manual-axes tracking (jax.typeof(...).vma).
# Pre-VMA releases transpose manual-collective bodies with different seed
# conventions (see training/train_step.py); set by install().
HAS_VMA = True


def install() -> None:
    global _installed, HAS_VMA
    if _installed:
        return
    _installed = True
    import jax

    HAS_VMA = hasattr(jax, "typeof")

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            # old jax has no explicit/auto mesh-axis distinction; the repo
            # only ever asks for Auto, so dropping the argument is exact
            return _make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh

    if not hasattr(jax.lax, "axis_size"):
        from jax import core as _core

        def axis_size(axis_name):
            # 0.4.x: core.axis_frame(name) IS the static int size
            if isinstance(axis_name, (tuple, list)):
                n = 1
                for nm in axis_name:
                    n *= _core.axis_frame(nm)
                return n
            return _core.axis_frame(axis_name)

        jax.lax.axis_size = axis_size

    if not hasattr(jax, "typeof"):
        from jax import core as _core2

        class _AllAxes:
            """Pre-VMA jax cannot track varying-manual-axes; report every
            value as varying over every axis.  Callers branching on
            ``axis in typeof(x).vma`` then emit the conservative psum,
            which matches the unchecked (check_rep=False) transpose that
            leaves cotangents as per-shard partials."""

            def __contains__(self, axis):
                return True

        class _CompatAval:
            vma = _AllAxes()

            def __init__(self, aval):
                self._aval = aval

            def __getattr__(self, name):
                return getattr(self._aval, name)

        def typeof(x):
            aval = _core2.get_aval(x)
            return aval if hasattr(aval, "vma") else _CompatAval(aval)

        jax.typeof = typeof

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @functools.wraps(_shard_map)
        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                      **kw):
            if "check_rep" not in kw:
                # check_vma -> check_rep (renamed in jax 0.6); when unset,
                # default False: the old replication checker predates VMA
                # and rejects valid manual-collective bodies
                kw["check_rep"] = bool(check_vma)
            return _shard_map(f, mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map


install()
