"""Checkpointing: atomic, async, elastic.

- save(): flattens the pytree to npz (keypath -> array), writes to a temp
  dir, fsyncs, atomically renames to ``step_N`` and updates ``LATEST``.
  Async mode hands the (already host-transferred) arrays to a background
  thread so the train loop never blocks on disk.
- restore(): loads by keypath and ``jax.device_put``s against the *current*
  mesh/shardings — a checkpoint written on one topology restores onto
  another (elastic re-scale: 512 -> 256 chips or CPU), because saved arrays
  are full logical values, not per-device shards.
- keep_last trims old checkpoints; partial restore tolerates added params
  (warm-starting a grown model) by falling back to the provided init value.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    """Keypath -> np array; bf16 (no numpy dtype) rides as a uint16 view
    with a dtype sidecar so npz stays pickle-free."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays, dtypes = {}, {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        a = np.asarray(leaf)
        if a.dtype.kind not in "fiub":   # ml_dtypes (bf16 etc.): kind 'V'
            dtypes[key] = a.dtype.name
            a = a.view(np.uint16) if a.dtype.itemsize == 2 else \
                a.astype(np.float32)
        arrays[key] = a
    return arrays, dtypes


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        """Snapshot to host memory now; write to disk (possibly async)."""
        arrays, dtypes = _flatten(tree)  # device->host transfer happens here
        meta = dict(metadata or {}, step=step, time=time.time(),
                    dtypes=dtypes)
        if self._pool is not None:
            self.wait()                  # one outstanding save at a time
            self._pending = self._pool.submit(self._write, step, arrays,
                                              meta)
        else:
            self._write(step, arrays, meta)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, arrays, meta):
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        os.replace(tmp, final) if not os.path.exists(final) else \
            shutil.rmtree(tmp)
        with self._lock:
            latest = os.path.join(self.dir, "LATEST.tmp")
            with open(latest, "w") as f:
                f.write(str(step))
            os.replace(latest, os.path.join(self.dir, "LATEST"))
        self._trim()

    def _trim(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                s = int(f.read().strip())
            if os.path.exists(os.path.join(self.dir, f"step_{s}")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None):
        """Restore into the structure of ``target`` (arrays or
        ShapeDtypeStructs). ``shardings``: matching tree of Sharding (or
        None -> default placement). Missing keys keep the target's value
        (partial/elastic restore)."""
        path = os.path.join(self.dir, f"step_{step}", "arrays.npz")
        data = np.load(path)
        with open(os.path.join(self.dir, f"step_{step}", "meta.json")) as f:
            dtypes = json.load(f).get("dtypes", {})
        flat = jax.tree_util.tree_flatten_with_path(target)[0]
        shard_flat = (jax.tree_util.tree_flatten_with_path(shardings)[0]
                      if shardings is not None else [(None, None)] * len(flat))
        treedef = jax.tree_util.tree_structure(target)
        leaves = []
        import ml_dtypes
        for (pathk, leaf), (_, shard) in zip(flat, shard_flat):
            key = jax.tree_util.keystr(pathk)
            if key in data.files:
                arr = data[key]
                if key in dtypes:
                    dt = np.dtype(getattr(ml_dtypes, dtypes[key]))
                    arr = arr.view(dt) if arr.dtype.itemsize == dt.itemsize \
                        else arr.astype(dt)
                leaves.append(jax.device_put(arr, shard) if shard is not None
                              else jax.numpy.asarray(arr))
            else:
                leaves.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, target: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target, shardings)
