"""Custom cost analyzer over optimized per-device HLO text (DESIGN.md §9).

XLA's `compiled.cost_analysis()` visits while (= lax.scan) bodies ONCE, so a
95-layer scanned transformer reports 1/95th of its FLOPs. This module walks
the computation call graph instead:

  * computations split by brace matching (tuple-typed while params included)
  * while trip counts read from `backend_config={"known_trip_count":{"n":..}}`
    (fallback: the largest constant in the condition computation)
  * per-computation FLOPs from `dot` ops (2 * result_elems * contraction),
    resolving operand shapes from the computation-local name->shape map
  * per-computation HBM bytes: sum of operand+result bytes of top-level ops
    (fusion internals excluded — their intermediates live in registers/VMEM)
  * collectives with ring wire-cost per device:
        all-reduce          2 (N-1)/N * bytes
        reduce-scatter      (N-1)/N * operand bytes (= (N-1) * result bytes)
        all-gather          (N-1)/N * result bytes
        all-to-all          (N-1)/N * bytes
        collective-permute  bytes
  * total = Sum over computations of (cost * product of enclosing trips)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_RHS_RE = re.compile(
    r"^(\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+([\w\-]+)\(")
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-reduce", "reduce-scatter", "all-gather", "all-to-all",
                "collective-permute")
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "add-dependency", "domain",
               "opt-barrier", "partition-id", "replica-id",
               # control flow: the bodies account for their own traffic
               "while", "call", "conditional", "async-start", "async-done",
               "async-update",
               # TPU semantics: bf16<->f32 element-type converts fuse into
               # their consumers (MXU takes bf16 operands with f32
               # accumulation); standalone converts are CPU-backend dot
               # legalization artifacts and carry no HBM traffic of their own
               "convert"}
# ops that read only their (small) result-shaped window of a big operand
_SLICING = {"dynamic-slice", "slice", "gather"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclasses.dataclass
class Collective:
    kind: str
    result_bytes: int
    group_size: int
    multiplier: float = 1.0

    @property
    def wire_bytes(self) -> float:
        n = max(self.group_size, 1)
        rb = self.result_bytes
        if self.kind == "all-reduce":
            per = 2 * (n - 1) / n * rb
        elif self.kind == "reduce-scatter":
            per = (n - 1) * rb
        elif self.kind == "all-gather":
            per = (n - 1) / n * rb
        elif self.kind == "all-to-all":
            per = (n - 1) / n * rb
        else:
            per = rb
        return per * self.multiplier


@dataclasses.dataclass
class Computation:
    name: str
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: List[Collective] = dataclasses.field(default_factory=list)
    whiles: List[Tuple[str, str, float]] = dataclasses.field(
        default_factory=list)  # (cond, body, trips)
    calls: List[str] = dataclasses.field(default_factory=list)
    fusions: List[Tuple[str, float]] = dataclasses.field(
        default_factory=list)  # (callee, result bytes)
    branches: List[str] = dataclasses.field(default_factory=list)
    max_constant: float = 1.0
    # param index -> bytes actually read inside (fusion call sites): a param
    # consumed only through slicing ops contributes its window, not itself
    param_reads: Dict[int, float] = dataclasses.field(default_factory=dict)
    # fusion rooted in dynamic-update-slice: written in place (XLA aliases
    # the buffer inside loops); cost = 2*update window, not the full result
    root_dus_update_bytes: Optional[float] = None
    # computation is convert/bitcast-only (CPU dot-legalization artifact)
    pure_convert: bool = False


def _split_computations(text: str) -> Tuple[Dict[str, List[str]], str]:
    comps: Dict[str, List[str]] = {}
    entry = ""
    current: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if current is None:
            if stripped.endswith("{") and ("->" in stripped) and \
                    (stripped.startswith("%") or stripped.startswith("ENTRY")):
                name = stripped.split()[1] if stripped.startswith("ENTRY") \
                    else stripped.split()[0]
                name = name.lstrip("%")
                current = name
                comps[current] = []
                if stripped.startswith("ENTRY"):
                    entry = name
        else:
            if stripped == "}":
                current = None
            else:
                comps[current].append(line)
    return comps, entry


def _parse_computation(name: str, lines: List[str]) -> Computation:
    comp = Computation(name=name)
    shapes: Dict[str, str] = {}
    param_idx: Dict[str, int] = {}
    # first pass: name -> type string (+ parameter indices)
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            rhs = m.group(2)
            op_end = rhs.find("(")
            shapes[m.group(1)] = rhs[:op_end] if op_end > 0 else rhs
            pm = re.search(r"parameter\((\d+)\)", rhs)
            if pm:
                param_idx[m.group(1)] = int(pm.group(1))

    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        # rhs = "<type> <op>(operands...), attrs" where <type> is either a
        # single `dtype[dims]{layout}` or a parenthesized tuple type
        m2 = _RHS_RE.match(rhs)
        if not m2:
            continue
        result_type, op = m2.group(1), m2.group(2)
        paren = m2.end() - 1  # position of the op's '('

        for c in re.findall(r"constant\((\d+)\)", line):
            comp.max_constant = max(comp.max_constant, float(c))

        base_op = op.replace("-start", "").replace("-done", "")
        if base_op in _COLLECTIVES:
            if op.endswith("-done"):
                continue
            gb = _GROUPS_BRACE_RE.search(line)
            gi = _GROUPS_IOTA_RE.search(line)
            gsize = (len(gb.group(1).split(",")) if gb
                     else int(gi.group(2)) if gi else 1)
            comp.collectives.append(Collective(
                kind=base_op, result_bytes=_shape_bytes(result_type),
                group_size=gsize))
        if op == "while":
            wm = _WHILE_ATTR_RE.search(line)
            tm = _TRIP_RE.search(line)
            if wm:
                comp.whiles.append((wm.group(1), wm.group(2),
                                    float(tm.group(1)) if tm else -1.0))
        elif op in ("call", "async-start"):
            cm = _CALL_RE.search(line)
            if cm:
                comp.calls.append(cm.group(1))
        elif op == "fusion":
            cm = _CALL_RE.search(line)
            if cm:
                comp.fusions.append((cm.group(1),
                                     float(_shape_bytes(result_type))))
        elif op == "conditional":
            bm = _BRANCH_RE.search(line)
            if bm:
                comp.branches.extend(
                    b.lstrip("%") for b in
                    re.findall(r"%?([\w\.\-]+)", bm.group(1)))

        # FLOPs: dot ops
        if op == "dot":
            cm = _CONTRACT_RE.search(line)
            # operand list ends at the first ')' (dot operands are arrays,
            # never tuple-typed); older XLA prints operand types inline
            # ("dot(f32[8,64]{1,0} %a, ...)"), newer prints bare "%a"
            close = rhs.find(")", paren)
            operands = re.findall(r"%([\w\.\-]+)",
                                  rhs[paren:close if close > 0 else None])
            result_elems = 1
            for _, dims in _shape_dims(result_type):
                for d in dims:
                    result_elems *= d
                break
            contract = 1
            if cm and operands:
                lhs_type = shapes.get(operands[0], "")
                dims_list = _shape_dims(lhs_type)
                if dims_list:
                    lhs_dims = dims_list[0][1]
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contract *= lhs_dims[int(idx)]
            comp.flops += 2.0 * result_elems * contract

        # HBM traffic model
        if op in _NO_TRAFFIC or op.endswith("-done") or op == "fusion":
            continue  # fusion sites handled after param_reads are known
        rb = _shape_bytes(result_type)
        if op in _SLICING:
            comp.bytes_accessed += 2 * rb      # read window + write result
            continue
        if op == "dynamic-update-slice":
            ops_ = re.findall(r"%([\w\.\-]+)", rhs[paren:])
            upd = _shape_bytes(shapes.get(ops_[1], "")) if len(ops_) > 1 \
                else rb
            comp.bytes_accessed += 2 * upd     # read update + write window
            continue
        if op == "scatter":
            ops_ = re.findall(r"%([\w\.\-]+)", rhs[paren:])
            upd = _shape_bytes(shapes.get(ops_[2], "")) if len(ops_) > 2 \
                else rb
            comp.bytes_accessed += 3 * upd     # read idx+update, write window
            continue
        if op in ("broadcast", "iota"):
            comp.bytes_accessed += rb          # write only
            continue
        tb = rb
        for opnd in re.findall(r"%([\w\.\-]+)", rhs[paren:]):
            if opnd in shapes:
                tb += _shape_bytes(shapes[opnd])
        comp.bytes_accessed += tb

    # classify the computation for fusion call-site costing
    ops_seen = []
    for line in lines:
        m3 = _DEF_RE.match(line)
        if not m3:
            continue
        m4 = _RHS_RE.match(m3.group(2))
        if not m4:
            continue
        op2 = m4.group(2)
        ops_seen.append(op2)
        if op2 in ("dynamic-update-slice", "scatter"):
            # in-place window write (XLA aliases the base buffer): record
            # the update operand's size, looking through convert/bitcast
            rhs2 = m3.group(2)
            ops_ = re.findall(r"%([\w\.\-]+)", rhs2[m4.end() - 1:])
            idx = 1 if op2 == "dynamic-update-slice" else 2
            if len(ops_) > idx and ops_[idx] in shapes:
                comp.root_dus_update_bytes = float(
                    _shape_bytes(shapes[ops_[idx]]))
    if ops_seen and all(o in ("parameter", "convert", "bitcast", "copy")
                        for o in ops_seen):
        comp.pure_convert = True

    # param read footprint (for fusion call sites): how much of each param
    # is actually touched inside this computation
    for pname, idx in param_idx.items():
        full = _shape_bytes(shapes.get(pname, ""))
        best: Optional[float] = None
        pat = re.compile(r"%" + re.escape(pname) + r"\b")
        for line in lines:
            m2 = _DEF_RE.match(line)
            if not m2 or not pat.search(m2.group(2)):
                continue
            rhs2 = m2.group(2)
            mm = _RHS_RE.match(rhs2)
            if not mm:
                continue
            rt, op2 = mm.group(1), mm.group(2)
            if m2.group(1) == pname:
                continue  # its own definition line
            if op2 in _SLICING:
                r = float(_shape_bytes(rt))
            elif op2 == "dynamic-update-slice":
                r = float(full)  # written through: count full
            else:
                r = float(full)
            best = r if best is None else max(best, r)
        comp.param_reads[idx] = best if best is not None else 0.0
    return comp


@dataclasses.dataclass
class ModuleCosts:
    flops: float
    bytes_accessed: float
    wire_bytes: float
    by_kind: Dict[str, float]
    num_collectives_static: int
    num_collectives_dynamic: float
    per_computation: Dict[str, Tuple[float, float]]


def analyze_text(text: str) -> ModuleCosts:
    raw, entry = _split_computations(text)
    comps = {n: _parse_computation(n, ls) for n, ls in raw.items()}

    mult: Dict[str, float] = {}
    fusion_mult: Dict[str, float] = {}

    def visit_fusion(name: str, m: float):
        if name not in comps:
            return
        fusion_mult[name] = fusion_mult.get(name, 0.0) + m
        for f, _ in comps[name].fusions:
            visit_fusion(f, m)
        for c in comps[name].calls:
            visit_fusion(c, m)

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] = mult.get(name, 0.0) + m
        for cond, body, trips in comp.whiles:
            if trips < 0:
                trips = comps.get(cond, Computation(cond)).max_constant
            visit(cond, m * (trips + 1))
            visit(body, m * trips)
        for c in comp.calls:
            visit(c, m)
        for b in comp.branches:
            visit(b, m)
        # fusion internals: FLOPs counted (dots inside fused computations do
        # execute) but bytes are NOT (intermediates stay on-chip); call-site
        # traffic is added below via param_reads
        for f, _ in comp.fusions:
            visit_fusion(f, m)

    if entry:
        visit(entry, 1.0)
    else:
        mult = {n: 1.0 for n in comps}

    flops = 0.0
    byts = 0.0
    wire = 0.0
    by_kind: Dict[str, float] = {}
    n_static, n_dyn = 0, 0.0
    per_comp: Dict[str, Tuple[float, float]] = {}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        fm = fusion_mult.get(name, 0.0)
        if m <= 0 and fm <= 0:
            continue
        flops += comp.flops * (m + fm)
        if m > 0:
            cb = comp.bytes_accessed
            for callee, result_bytes in comp.fusions:
                callee_comp = comps.get(callee)
                if callee_comp is None:
                    cb += result_bytes
                    continue
                if callee_comp.pure_convert:
                    continue  # element-type plumbing: no HBM cost on TPU
                reads = sum(callee_comp.param_reads.values())
                if callee_comp.root_dus_update_bytes is not None:
                    upd = callee_comp.root_dus_update_bytes
                    # in-place DUS: drop the aliased big param + full result
                    reads = sum(v for v in callee_comp.param_reads.values()
                                if v < result_bytes * 0.99)
                    cb += 2 * upd + reads
                else:
                    cb += result_bytes + reads
            byts += cb * m
            per_comp[name] = (comp.flops * m, cb * m)
            for c in comp.collectives:
                wb = dataclasses.replace(c, multiplier=m).wire_bytes
                wire += wb
                by_kind[c.kind] = by_kind.get(c.kind, 0.0) + wb
                n_static += 1
                n_dyn += m
    return ModuleCosts(flops=flops, bytes_accessed=byts, wire_bytes=wire,
                       by_kind=by_kind, num_collectives_static=n_static,
                       num_collectives_dynamic=n_dyn,
                       per_computation=per_comp)


def collective_summary(text: str) -> Dict:
    mc = analyze_text(text)
    return {
        "total_wire_bytes_per_device": mc.wire_bytes,
        "by_kind": mc.by_kind,
        "num_ops_static": mc.num_collectives_static,
        "num_ops_dynamic": mc.num_collectives_dynamic,
    }
