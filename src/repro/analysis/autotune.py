"""Offline overlap-plan autotuner (DESIGN.md §14).

Searches, per (site, tokens-bucket, tp, model family), over the overlap
scheme the engine should run at that key — method ∈ {none, weave,
fused-unsplit, fused}, the weave's prefix-wave split fraction, and the
comm resource-budget fraction — by pricing every candidate with the §9
two-stream sim (``sim.overlap_sim.step_attribution``) under a calibrated
``HW`` (``HW.from_calibration``, DESIGN.md §13) or the roofline
defaults.  The fused methods dispatch the REAL ring AllReduce-RMSNorm
kernel and are priced from their ring-lane resource grant
(``ring_channels(budget)``, the paper's 2-8 SM knob) via the sim's
``ring``/``ringweave`` modes — not the generic contention model.  The
winner per bucket minimizes the simulated makespan, ties broken toward
more overlapped virtual time and then toward the earlier candidate in
the deterministic preference order (fused@0.5/full-budget first — the
one-kernel ring path strictly dominates the composed path in the model,
so ties collapse to the canonical fused weave).

The result is a versioned JSON plan cache (``core/policy.TunedPolicy``)
committed under ``benchmarks/plans/`` and loaded by ``Engine`` /
``OnlineServer`` / ``ClusterServer`` at startup.  The search is pure
deterministic float math — same plan on every machine — which is what
lets CI regenerate and diff it (``scripts/check_plan.py``).

CLI::

    python -m repro.analysis.autotune --out benchmarks/plans/default.json
    python -m repro.analysis.autotune --calibration cal.json --out tuned.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import zlib
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.policy import (PLAN_VERSION, PlanEntry, SITES, TunedPolicy)
from repro.core.splitting import DEFAULT_BUCKET_EDGES, plan_split
from repro.sim.overlap_sim import HW, step_attribution

# candidate grid: preference order matters — the FIRST candidate at the
# minimal (makespan, -overlapped) key wins, so ties collapse to the
# canonical balanced full-budget fused (ring-kernel) weave, then
# alternative fracs/budgets, then the composed weave, then the unsplit
# ring kernel, then no fused collective at all.
SPLIT_FRACS = (0.5, 0.25, 0.75)
BUDGETS = (1.0, 0.75, 0.5)
_SIM_MODE = {"fused": "ringweave", "weave": "tokenweave",
             "fused-unsplit": "ring", "none": "vanilla"}


@dataclasses.dataclass(frozen=True)
class TuneTarget:
    """One deployment the plan is tuned for: a model/parallelism pair and
    the wave quantum its engine splits at (``ParallelConfig.
    split_unit_for(tp)`` of the deployment's actual config — the sim must
    quantize at the same tile the engine's split decision uses)."""
    name: str
    cfg: ModelConfig
    tp: int
    family: str
    unit: int


def _bucket_rep(lo: int, hi: Optional[int]) -> int:
    """Representative token count priced for a bucket (mid-point;
    2*lo for the open last bucket)."""
    return 2 * lo if hi is None else (lo + hi + 1) // 2


def _buckets(edges: Tuple[int, ...]) -> List[Tuple[str, int]]:
    out = [(f"{lo}-{hi - 1}", _bucket_rep(lo, hi - 1))
           for lo, hi in zip(edges, edges[1:])]
    out.append((f"{edges[-1]}+", _bucket_rep(edges[-1], None)))
    return out


def _candidates(rep: int, unit: int) -> List[Tuple[str, float, float]]:
    """(method, split_frac, budget) grid, preference-ordered; split
    candidates (fused/weave) structurally infeasible at the
    representative size are dropped.  The fused methods search the
    budget axis as their ring-lane grant (``ring_channels``); the
    composed weave keeps the generic contention budget."""
    cands: List[Tuple[str, float, float]] = []
    for b in BUDGETS:
        for f in SPLIT_FRACS:
            if plan_split(rep, unit, f) is not None:
                cands.append(("fused", f, b))
    for b in BUDGETS:
        for f in SPLIT_FRACS:
            if plan_split(rep, unit, f) is not None:
                cands.append(("weave", f, b))
    for b in BUDGETS:
        cands.append(("fused-unsplit", 0.5, b))
    cands.append(("none", 0.5, 1.0))
    return cands


def tune_entries(target: TuneTarget, *, hw: Optional[HW] = None,
                 edges: Tuple[int, ...] = DEFAULT_BUCKET_EDGES
                 ) -> List[PlanEntry]:
    """Search every (site, bucket) of one target; returns plan entries.

    All four sites price identically in the token-level sim (the site
    distinction exists because the ENGINE's axes and floors differ), so
    one bucket search serves all sites — but entries are emitted per
    site, because that is the lookup key the runtime uses and a future
    site-aware cost model refines them independently."""
    hw = hw or HW(tile=target.unit)
    entries: List[PlanEntry] = []
    for bucket, rep in _buckets(edges):
        best_key = None
        best: Optional[Tuple[str, float, float]] = None
        for method, frac, budget in _candidates(rep, hw.tile):
            est = step_attribution(
                target.cfg, _SIM_MODE[method], rep, tp=target.tp, hw=hw,
                split=(plan_split(rep, hw.tile, frac)
                       if method in ("weave", "fused") else None),
                comm_budget=None if budget == 1.0 else budget)
            key = (round(est["makespan"], 15), -round(est["overlapped"], 15))
            if best_key is None or key < best_key:
                best_key, best = key, (method, frac, budget)
        method, frac, budget = best
        for site in SITES:
            entries.append(PlanEntry(site=site, bucket=bucket, tp=target.tp,
                                     family=target.family, method=method,
                                     split_frac=frac, budget=budget))
    return entries


def _plan_id(entries: List[PlanEntry]) -> int:
    """Deterministic nonzero id derived from the plan content, so any
    entry change is visible as a plan-id change in traces and metrics."""
    blob = json.dumps([dataclasses.asdict(e) for e in entries],
                      sort_keys=True).encode()
    return 1 + (zlib.crc32(blob) % 999_999)


def autotune_plan(targets: List[TuneTarget], *, hw: Optional[HW] = None,
                  edges: Tuple[int, ...] = DEFAULT_BUCKET_EDGES
                  ) -> TunedPolicy:
    """Tune every target and assemble one ``TunedPolicy`` plan cache."""
    entries: List[PlanEntry] = []
    for t in targets:
        entries.extend(tune_entries(
            t, hw=hw if hw is not None else HW(tile=t.unit), edges=edges))
    return TunedPolicy(plan_id=_plan_id(entries), version=PLAN_VERSION,
                       bucket_edges=edges, entries=tuple(entries))


def default_targets() -> List[TuneTarget]:
    """The committed ``benchmarks/plans/default.json`` covers the paper's
    serving model at its TP degree plus the CI-tiny config every CPU test
    and the ``serve/policy`` benchmark run (DESIGN.md §14)."""
    from repro.configs import get_config
    paper = get_config("llama3.3-70b")
    tiny = ModelConfig(name="tiny", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                       d_ff=128, vocab_size=128, dtype="float32")
    paper_pcfg = ParallelConfig()                       # split_unit 256
    tiny_pcfg = ParallelConfig(split_unit=16)           # conftest tiny_pcfg
    return [TuneTarget("llama3.3-70b/tp8", paper, 8, paper.family,
                       paper_pcfg.split_unit_for(8)),
            TuneTarget("tiny/tp1", tiny, 1, tiny.family,
                       tiny_pcfg.split_unit_for(1))]


def _target_hw(target: TuneTarget, cal: Optional[dict]) -> HW:
    if cal is None:
        return HW(tile=target.unit)
    hw = HW.from_calibration(cal)
    hw.tile = target.unit
    return hw


def build_default_plan(calibration: Optional[dict] = None) -> TunedPolicy:
    """The plan CI regenerates and diffs against the committed cache."""
    targets = default_targets()
    entries: List[PlanEntry] = []
    for t in targets:
        entries.extend(tune_entries(t, hw=_target_hw(t, calibration)))
    return TunedPolicy(plan_id=_plan_id(entries), version=PLAN_VERSION,
                       bucket_edges=DEFAULT_BUCKET_EDGES,
                       entries=tuple(entries))


def _meta(calibration_path: Optional[str]) -> Dict[str, object]:
    return {
        "targets": [t.name for t in default_targets()],
        "search": {"split_fracs": list(SPLIT_FRACS),
                   "budgets": list(BUDGETS),
                   "objective": "lexicographic(makespan, -overlapped)"},
        "calibration": calibration_path or "defaults",
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Tune the per-site overlap plan cache on the §9 sim "
                    "(DESIGN.md §14)",
        epilog="The committed benchmarks/plans/default.json must equal "
               "the output of a defaults run; CI's autotune job enforces "
               "this (scripts/check_plan.py).")
    ap.add_argument("--out", required=True,
                    help="plan-cache JSON path to write")
    ap.add_argument("--calibration", default=None,
                    help="CalibrationReport JSON (analysis/calibration.py) "
                         "to tune under measured hardware; default: "
                         "roofline-default HW")
    args = ap.parse_args(argv)
    cal = None
    if args.calibration:
        with open(args.calibration) as f:
            cal = json.load(f)
    plan = build_default_plan(cal)
    plan.save(args.out, **_meta(args.calibration))
    print(f"wrote plan id {plan.plan_id} ({len(plan.entries)} entries) "
          f"-> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
