"""Three-term roofline from a compiled (dry-run) artifact (DESIGN.md §9).

TPU v5e per chip: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

    compute_s    = HLO_FLOPs_per_device / peak_flops
    memory_s     = HLO_bytes_per_device / hbm_bw
    collective_s = wire_bytes_per_device / ici_bw

XLA's `cost_analysis()` visits while (scan) bodies once, so all three terms
come from our own call-graph-walking HLO analyzer (analysis/hlo.py), which
weights every computation by its enclosing trip counts. MODEL_FLOPS uses
6*N*D (dense) or 6*N_active*D (MoE), 2*N*D for inference (no backward).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo import analyze_text
from repro.configs.base import ModelConfig

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link
ICI_LINKS = 2             # ring collectives on a torus axis drive both
                          # directions -> 2 links active per chip
ICI_EFF = ICI_BW * ICI_LINKS


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_device: float
    useful_ratio: float
    loop_multiplier: float
    by_kind: Dict[str, float]

    def to_dict(self):
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, n_tokens: int, *, train: bool,
                decode_context: int = 0, seq_len: int = 0) -> float:
    """6*N*D (train) / 2*N*D (inference) active-param flops + attention."""
    n = cfg.active_param_count()
    mult = 6.0 if train else 2.0
    total = mult * n * n_tokens
    # attention score/value flops (not in 6ND): 2*2*L*H*dh*S_kv per token
    if cfg.num_heads:
        kv = decode_context if decode_context else (seq_len or n_tokens)
        att = (2 * 2 * cfg.num_layers * cfg.num_heads * cfg.head_dim
               * n_tokens * kv)
        if cfg.sliding_window and cfg.local_global_period:
            # most layers see only the window
            loc = (cfg.local_global_period - 1) / cfg.local_global_period
            att = att * (1 - loc) + att * loc * min(
                1.0, cfg.sliding_window / max(kv, 1))
        total += (3.0 if train else 1.0) * att / 2  # causal halves it
    return total


def analyze(compiled, lowered_text: Optional[str], cfg: ModelConfig,
            *, n_devices: int, n_tokens_global: int, train: bool,
            decode_context: int = 0, seq_len: int = 0) -> Roofline:
    text = compiled.as_text()
    mc = analyze_text(text)
    flops = mc.flops
    byts = mc.bytes_accessed
    wire = mc.wire_bytes
    loop_mult = (mc.num_collectives_dynamic
                 / max(mc.num_collectives_static, 1))

    mf_global = model_flops(cfg, n_tokens_global, train=train,
                            decode_context=decode_context, seq_len=seq_len)
    mf = mf_global / n_devices
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = wire / ICI_EFF
    dom = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)], key=lambda kv: kv[1])[0]
    return Roofline(
        flops_per_device=flops, bytes_per_device=byts,
        wire_bytes_per_device=wire, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dom,
        model_flops_per_device=mf,
        useful_ratio=mf / flops if flops else 0.0,
        loop_multiplier=loop_mult, by_kind=mc.by_kind)
