"""Cost-model calibration: fit sim ``HW`` params to measured time (DESIGN.md §13).

The §9 roofline and everything priced on it (weave attribution, the
virtual clock's crossover numbers) run on hardcoded ``HW`` constants.
This module closes the loop against the wall clock:
``fit_calibration`` takes the steady-state per-forward samples a
``WallClockProfiler`` collected (each carrying method + token count +
fenced wall seconds), buckets them by (method, tokens), and fits the
three free parameters of the dispatch-time model

    measured(method, tokens) ~= step_attribution(..., hw)["makespan"]
                              = roofline(mfu_cap, ici) + overhead

by least squares on RELATIVE error (absolute error would let the
largest-token buckets drown out the small ones where ``overhead``
lives): ``overhead`` (fixed per-dispatch seconds) is linear in the
residual so it has a closed-form optimum for fixed (mfu_cap, ici)
(clamped at zero only after the search), and the search over
(mfu_cap, ici) runs in log space — a coarse grid seeding a 2-D
Nelder-Mead simplex — dependency-free and deterministic.

The result is a ``CalibrationReport``: fitted params, per-bucket
predicted-vs-measured relative error, worst-case divergence, and a
dispatch-granularity linear fit (``step_base`` + ``step_per_token`` ×
real tokens) for the OnlineServer virtual clock.  It round-trips
through JSON, loads back via ``HW.from_calibration`` /
``StepCost.from_calibration``, and — because report predictions are
computed with ``step_attribution`` under the fitted ``HW`` — reloading
and re-predicting reproduces the report's numbers exactly.
``export_to`` publishes the per-mode ``profile/predicted_vs_measured``
divergence gauges that scripts/check_calibration.py gates in CI.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Iterable, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.sim.overlap_sim import HBM_BW, HW, PEAK_FLOPS, step_attribution

MFU_BOUNDS = (1e-4, 1.0)        # wide: CPU smoke runs sit far below tpu peak
ICI_BOUNDS = (1e6, 1e13)        # bytes/s


@dataclasses.dataclass(frozen=True)
class TimingSample:
    """Minimal duck-type of ``obs.profiler.MeasuredForward`` — what the
    fit actually reads.  Synthetic tests construct these directly."""
    method: str
    tokens: int
    wall_s: float
    tokens_real: int = 0


def _tokens(s) -> int:
    t = getattr(s, "tokens", None)
    return int(t if t is not None else s.tokens_static)


@dataclasses.dataclass
class CalibrationReport:
    """Fitted cost-model params + divergence accounting (DESIGN.md §13)."""
    model: str
    tp: int
    tile: int
    n_layers: int
    peak: float
    hbm: float
    mfu_cap: float
    ici: float
    overhead: float            # fixed per-dispatch seconds
    step_base: float           # virtual-clock linear fit: wall seconds
    step_per_token: float      # ... per real token
    n_samples: int
    buckets: List[dict]        # {method, tokens, n, measured_s,
    #                             predicted_s, rel_err}
    per_mode_rel_err: Dict[str, float]
    worst_rel_err: float
    worst_bucket: str

    def hw(self) -> HW:
        return HW.from_calibration(self)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationReport":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "CalibrationReport":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def export_to(self, registry) -> None:
        """Publish divergence + fitted params as gauges (CI-gated)."""
        for mode in sorted(self.per_mode_rel_err):
            registry.gauge("profile/predicted_vs_measured",
                           mode=mode).set(self.per_mode_rel_err[mode])
        registry.gauge("profile/calibration/mfu_cap").set(self.mfu_cap)
        registry.gauge("profile/calibration/ici_gbps").set(self.ici / 1e9)
        registry.gauge("profile/calibration/overhead_us").set(
            self.overhead * 1e6)
        registry.gauge("profile/calibration/worst_rel_err").set(
            self.worst_rel_err)
        registry.gauge("profile/calibration/n_samples").set(self.n_samples)


def _geomspace(lo: float, hi: float, n: int) -> List[float]:
    if n == 1:
        return [math.sqrt(lo * hi)]
    r = (hi / lo) ** (1.0 / (n - 1))
    return [lo * r ** i for i in range(n)]


def _nelder_mead2(f, x0: Tuple[float, float], *, step: float = 0.3,
                  iters: int = 200, tol: float = 1e-9
                  ) -> Tuple[float, float]:
    """Derivative-free 2-D minimizer (deterministic, no scipy).  The
    (mfu_cap, ici) objective is a narrow curved valley — coordinate
    descent zigzags and stalls there, a simplex tracks it."""
    pts = [x0, (x0[0] + step, x0[1]), (x0[0], x0[1] + step)]
    vals = [f(p) for p in pts]
    for _ in range(iters):
        order = sorted(range(3), key=vals.__getitem__)
        pts = [pts[i] for i in order]
        vals = [vals[i] for i in order]
        if max(abs(pts[2][0] - pts[0][0]),
               abs(pts[2][1] - pts[0][1])) < tol:
            break
        cx = (pts[0][0] + pts[1][0]) / 2
        cy = (pts[0][1] + pts[1][1]) / 2
        rx, ry = 2 * cx - pts[2][0], 2 * cy - pts[2][1]   # reflect
        fr = f((rx, ry))
        if fr < vals[0]:
            ex, ey = 3 * cx - 2 * pts[2][0], 3 * cy - 2 * pts[2][1]
            fe = f((ex, ey))                               # expand
            pts[2], vals[2] = ((ex, ey), fe) if fe < fr else ((rx, ry), fr)
        elif fr < vals[1]:
            pts[2], vals[2] = (rx, ry), fr
        else:
            kx = (cx + pts[2][0]) / 2                      # contract
            ky = (cy + pts[2][1]) / 2
            fk = f((kx, ky))
            if fk < vals[2]:
                pts[2], vals[2] = (kx, ky), fk
            else:                                          # shrink
                for i in (1, 2):
                    pts[i] = ((pts[0][0] + pts[i][0]) / 2,
                              (pts[0][1] + pts[i][1]) / 2)
                    vals[i] = f(pts[i])
    i = min(range(3), key=vals.__getitem__)
    return pts[i]


def fit_calibration(cfg: ModelConfig, samples: Iterable, *, tp: int,
                    tile: int, model: Optional[str] = None,
                    peak: float = PEAK_FLOPS, hbm: float = HBM_BW,
                    n_layers: int = 4) -> CalibrationReport:
    """Least-squares fit of (mfu_cap, ici, overhead) to steady samples.

    ``samples`` need ``method`` / ``wall_s`` / token-count attributes
    (``MeasuredForward`` or ``TimingSample``).  ``tile`` must be the wave
    unit the engine's ``Attributor`` priced with
    (``pcfg.split_unit_for(tp)``) so predictions quantize identically.
    """
    samples = [s for s in samples if not getattr(s, "warmup", False)]
    if not samples:
        raise ValueError("fit_calibration needs at least one steady sample")

    # -- bucket: (method, static tokens) -> mean measured seconds --------
    acc: Dict[Tuple[str, int], List[float]] = {}
    for s in samples:
        acc.setdefault((s.method, _tokens(s)), []).append(float(s.wall_s))
    keys = sorted(acc)
    meas = [sum(acc[k]) / len(acc[k]) for k in keys]
    wts = [float(len(acc[k])) for k in keys]
    # relative-error weights: w_i / y_i^2 turns (pred - y) into
    # (pred - y)/y inside the quadratic
    rws = [w / max(y, 1e-12) ** 2 for w, y in zip(wts, meas)]

    def roofline(mfu: float, ici: float) -> List[float]:
        hw = HW(peak=peak, hbm=hbm, ici=ici, tile=tile, mfu_cap=mfu)
        return [step_attribution(cfg, m, max(t, 1), tp=tp, hw=hw,
                                 n_layers=n_layers)["makespan"]
                for m, t in keys]

    def best_overhead(base: List[float]) -> float:
        # unclamped during the search: clamping mid-descent kinks the
        # objective and strands the coordinate descent in a local valley
        return (sum(rw * (y - b) for rw, y, b in zip(rws, meas, base))
                / sum(rws))

    def sse(mfu: float, ici: float) -> float:
        base = roofline(mfu, ici)
        ovh = best_overhead(base)
        return sum(rw * (y - b - ovh) ** 2
                   for rw, y, b in zip(rws, meas, base))

    # -- search in (log mfu, log ici): coarse grid seeds Nelder-Mead -----
    def clamp(v, lo, hi):
        return min(max(v, lo), hi)

    def obj(p):
        return sse(clamp(math.exp(p[0]), *MFU_BOUNDS),
                   clamp(math.exp(p[1]), *ICI_BOUNDS))

    grid = [(math.log(m), math.log(i))
            for m in _geomspace(*MFU_BOUNDS, 7)
            for i in _geomspace(*ICI_BOUNDS, 7)]
    x0 = min(grid, key=obj)
    xm, xi = _nelder_mead2(obj, x0)
    mfu = clamp(math.exp(xm), *MFU_BOUNDS)
    ici = clamp(math.exp(xi), *ICI_BOUNDS)
    overhead = max(best_overhead(roofline(mfu, ici)), 0.0)

    # -- final predictions under the FITTED HW (exact round-trip) --------
    fitted = HW(peak=peak, hbm=hbm, ici=ici, tile=tile, mfu_cap=mfu,
                overhead=overhead)
    buckets, per_mode_num, per_mode_den = [], {}, {}
    worst, worst_key = 0.0, ""
    for (m, t), y, w in zip(keys, meas, wts):
        pred = step_attribution(cfg, m, max(t, 1), tp=tp, hw=fitted,
                                n_layers=n_layers)["makespan"]
        rel = abs(pred - y) / max(y, 1e-12)
        buckets.append({"method": m, "tokens": t, "n": int(w),
                        "measured_s": y, "predicted_s": pred,
                        "rel_err": rel})
        per_mode_num[m] = per_mode_num.get(m, 0.0) + w * rel
        per_mode_den[m] = per_mode_den.get(m, 0.0) + w
        if rel > worst:
            worst, worst_key = rel, f"{m}/{t}"

    # -- dispatch-granularity linear fit for the virtual clock -----------
    xs = [float(getattr(s, "tokens_real", 0) or _tokens(s))
          for s in samples]
    ys = [float(s.wall_s) for s in samples]
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    var = sum((x - mx) ** 2 for x in xs)
    slope = (sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / var
             if var > 0 else 0.0)
    slope = max(slope, 0.0)
    intercept = max(my - slope * mx, 0.0)

    return CalibrationReport(
        model=model or cfg.name, tp=int(tp), tile=int(tile),
        n_layers=int(n_layers), peak=float(peak), hbm=float(hbm),
        mfu_cap=float(mfu), ici=float(ici), overhead=float(overhead),
        step_base=float(intercept), step_per_token=float(slope),
        n_samples=n, buckets=buckets,
        per_mode_rel_err={m: per_mode_num[m] / per_mode_den[m]
                          for m in sorted(per_mode_num)},
        worst_rel_err=float(worst), worst_bucket=worst_key)
