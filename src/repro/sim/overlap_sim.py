"""Two-stream (compute / comm) event simulator for the TokenWeave schedule.

The CPU container cannot measure TPU wall time, so the paper's latency
figures are reproduced analytically: per-op durations derive from the same
roofline terms the dry-run reports (flops/peak, bytes/HBM-bw, wire/ICI-bw
on v5e), and the schedule is executed by a dependency-respecting
list scheduler with one compute stream and one comm stream — the XLA
latency-hiding scheduler's idealization. Wave quantization is modeled by
rounding compute tokens up to the tile unit, which is what makes
smart-splitting matter (paper Fig. 9).

Modes (match core.fused_collectives + the weave):
    vanilla    serial: AR -> unfused add+norm on every device
    reordered  serial: RS -> add+norm(1/N) -> AG, unfused ops
    fuseonly   serial: fused RS+norm+AG composition (XLA collectives +
               fused add/norm kernel between them)
    tokenweave composed-fused kernel + two-split overlap (naive-weave /
               the pre-ring full TokenWeave)
    ring       serial: the REAL one-kernel ring AllReduce-RMSNorm
               (kernels/ring_ar_rmsnorm.py) — norm math never leaves
               VMEM, priced from its ring-lane resource budget
               (``ring_channels``, DESIGN.md §14) instead of the generic
               contention model
    ringweave  ring kernel + two-split overlap — the full TokenWeave
               configuration the paper ships (plan method ``fused``)
    nocomm     collectives removed (paper vllm-nocomm counterfactual)

Speculative decoding (``spec_decode_latency`` / ``spec_decode_summary``)
re-models the decode step as a gamma+1-token verify batch per sequence, so
the weave-vs-unsplit crossover on the latency-critical decode path is
visible analytically (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.analysis.roofline import HBM_BW, ICI_EFF, PEAK_FLOPS
from repro.configs.base import ModelConfig
from repro.core.splitting import (MAX_RING_CHANNELS, naive_split,
                                  ring_channels, smart_split)

BYTES = 2  # bf16


@dataclasses.dataclass
class Op:
    name: str
    stream: str                  # "compute" | "comm"
    duration: float
    deps: Tuple[str, ...] = ()


def simulate(ops: List[Op]) -> Tuple[float, Dict[str, Tuple[float, float]]]:
    """List-schedule ops on two serial streams; returns (makespan, spans)."""
    done: Dict[str, float] = {}
    spans: Dict[str, Tuple[float, float]] = {}
    stream_free = {"compute": 0.0, "comm": 0.0}
    pending = list(ops)
    while pending:
        progressed = False
        for op in list(pending):
            if all(d in done for d in op.deps):
                start = max(stream_free[op.stream],
                            max((done[d] for d in op.deps), default=0.0))
                end = start + op.duration
                stream_free[op.stream] = end
                done[op.name] = end
                spans[op.name] = (start, end)
                pending.remove(op)
                progressed = True
        if not progressed:
            raise RuntimeError("dependency cycle in schedule")
    return max(done.values(), default=0.0), spans


# --------------------------------------------------------------------------
# per-op cost models (per device, v5e)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class HW:
    peak: float = PEAK_FLOPS
    hbm: float = HBM_BW
    ici: float = ICI_EFF            # bidirectional ring on a torus axis
    tile: int = 256                 # token tile (wave quantum)
    mfu_cap: float = 0.6            # achievable fraction of peak on GEMMs
    overhead: float = 0.0           # fixed per-dispatch seconds (launch,
    #                                 host sync, runtime bookkeeping) —
    #                                 fitted by analysis/calibration.py;
    #                                 0.0 keeps legacy pure-roofline numbers

    @classmethod
    def from_calibration(cls, cal) -> "HW":
        """Rebuild the fitted hardware model from a ``CalibrationReport``
        (analysis/calibration.py, DESIGN.md §13) or its ``to_dict()`` /
        JSON-loaded mapping.  Missing fields fall back to the defaults so
        partial calibrations (e.g. mfu_cap only) still load."""
        def get(key, default):
            if isinstance(cal, dict):
                return cal.get(key, default)
            return getattr(cal, key, default)
        return cls(peak=float(get("peak", PEAK_FLOPS)),
                   hbm=float(get("hbm", HBM_BW)),
                   ici=float(get("ici", ICI_EFF)),
                   tile=int(get("tile", 256)),
                   mfu_cap=float(get("mfu_cap", 0.6)),
                   overhead=float(get("overhead", 0.0)))


def _quantize(t: int, hw: HW) -> int:
    return max(hw.tile, math.ceil(t / hw.tile) * hw.tile)


def t_gemm(tokens: int, flops_per_token: float, weight_bytes: float,
           hw: HW) -> float:
    tq = _quantize(tokens, hw)
    f = flops_per_token * tq
    return max(f / (hw.peak * hw.mfu_cap),
               (weight_bytes + tq * 0) / hw.hbm)


def t_attn_layer(cfg: ModelConfig, tokens: int, ctx: int, tp: int,
                 hw: HW) -> float:
    """QKV+O projections + scores/values for `tokens` new tokens vs ctx."""
    d, dh = cfg.d_model, cfg.head_dim
    h_loc = max(cfg.num_heads // tp, 1)
    kv_loc = max(cfg.num_kv_heads // tp, 1)
    proj_flops = 2 * d * (h_loc + 2 * kv_loc) * dh + 2 * h_loc * dh * d
    attn_flops = 4 * h_loc * dh * ctx / 2          # causal
    w_bytes = (d * (h_loc + 2 * kv_loc) * dh + h_loc * dh * d) * BYTES
    kv_bytes = 2 * ctx * kv_loc * dh * BYTES       # stream KV once (flash)
    tq = _quantize(tokens, hw)
    f = (proj_flops + attn_flops) * tq
    return max(f / (hw.peak * hw.mfu_cap), (w_bytes + kv_bytes) / hw.hbm)


def t_ffn_layer(cfg: ModelConfig, tokens: int, tp: int, hw: HW) -> float:
    d = cfg.d_model
    if cfg.is_moe:
        f_loc = cfg.moe_d_ff * cfg.num_experts_per_tok
        mult = 3
        w_bytes = 3 * d * cfg.moe_d_ff * BYTES * max(
            cfg.num_experts // tp, 1)              # expert weights streamed
    else:
        f_loc = cfg.d_ff // tp
        mult = 3 if cfg.act in ("silu", "geglu") else 2
        w_bytes = mult * d * f_loc * BYTES
    flops_per_tok = 2 * mult * d * (f_loc if not cfg.is_moe else
                                    f_loc // tp)
    tq = _quantize(tokens, hw)
    return max(flops_per_tok * tq / (hw.peak * hw.mfu_cap), w_bytes / hw.hbm)


def t_allreduce(tokens: int, d: int, n: int, hw: HW) -> float:
    return 2 * (n - 1) / n * tokens * d * BYTES / hw.ici


def t_rs_or_ag(tokens: int, d: int, n: int, hw: HW) -> float:
    return (n - 1) / n * tokens * d * BYTES / hw.ici


def t_norm(tokens: int, d: int, hw: HW, *, fused: bool) -> float:
    """unfused residual+norm: write r, read r twice, write out (+reads);
    fused single pass: read x + res, write r' + out."""
    passes = 5 if not fused else 4
    return passes * tokens * d * BYTES / hw.hbm


def t_ring_norm(tokens: int, d: int, hw: HW) -> float:
    """The one-kernel ring path's norm epilogue on the owned 1/N chunk:
    the reduced x arrives over the wire straight into VMEM and the normed
    output leaves the same way, so only the residual stream touches HBM —
    one read + one write (2 passes vs the composed path's 4; the paper's
    'minimal HBM traffic' property, kernels/ring_ar_rmsnorm.py)."""
    return 2 * tokens * d * BYTES / hw.hbm


# --------------------------------------------------------------------------
# schedules
# --------------------------------------------------------------------------

# comm-budget contention model (DESIGN.md §14): a tuned plan may cap the
# fraction b of interconnect/SM resources the fused collective kernel
# claims (Flash Communication's knob).  b scales wire bandwidth directly
# (ici_eff = ici*b) and RELIEVES compute by the share of the MFU cap the
# resident comm kernel taxes: mfu_eff = mfu_cap*(1 - TAX*b)/(1 - TAX),
# normalized so b = 1.0 reproduces the legacy single-hw numbers bit-exactly
# (the default mfu_cap already prices a full-budget comm kernel).
_BUDGET_TAX = 0.2


def _budgeted(hw: HW, comm_budget: Optional[float]) -> Tuple[HW, HW]:
    """(hw_compute, hw_comm) under comm resource-budget fraction b."""
    if comm_budget is None or comm_budget == 1.0:
        return hw, hw
    b = comm_budget
    if not (0.0 < b <= 1.0):
        raise ValueError(f"comm_budget must be in (0, 1], got {b}")
    hw_comm = dataclasses.replace(hw, ici=hw.ici * b)
    mfu = hw.mfu_cap * (1.0 - _BUDGET_TAX * b) / (1.0 - _BUDGET_TAX)
    hw_compute = dataclasses.replace(hw, mfu_cap=mfu)
    return hw_compute, hw_comm


# ring-kernel resource model (DESIGN.md §14): the fused ring kernel's
# resource grant is its LANE COUNT c = ring_channels(budget), the paper's
# 2-8 SM knob.  A few lanes already saturate the wire (the paper's fused
# kernel holds AR bandwidth with 2-8 of 132 SMs): wire efficiency is
# min(1, c/_RING_SAT).  Compute is relieved by the lanes NOT granted —
# the same MFU-tax shape as ``_budgeted`` with b_eff = c/MAX_RING_CHANNELS
# — so a half-budget ring entry keeps full wire speed while returning
# compute, which is exactly why the tuner prefers it over the composed
# path's linear-in-b wire model.
_RING_SAT = 4


def _ring_budgeted(hw: HW, comm_budget: Optional[float]) -> Tuple[HW, HW]:
    """(hw_compute, hw_comm) for the ring modes, priced from lanes."""
    b = 1.0 if comm_budget is None else comm_budget
    if not (0.0 < b <= 1.0):
        raise ValueError(f"comm_budget must be in (0, 1], got {b}")
    c = max(1, ring_channels(b))
    hw_comm = dataclasses.replace(hw, ici=hw.ici * min(1.0, c / _RING_SAT))
    b_eff = c / MAX_RING_CHANNELS
    mfu = hw.mfu_cap * (1.0 - _BUDGET_TAX * b_eff) / (1.0 - _BUDGET_TAX)
    hw_compute = dataclasses.replace(hw, mfu_cap=mfu)
    return hw_compute, hw_comm


def layer_ops(cfg: ModelConfig, mode: str, tokens: int, ctx: int, tp: int,
              hw: HW, n_layers: int = 4, smart: bool = True,
              split: Optional[Tuple[int, int]] = None,
              comm_budget: Optional[float] = None
              ) -> List[Op]:
    """Build the op list for `n_layers` consecutive layers.

    ``split`` pins the tokenweave split point explicitly (a tuned plan's
    ``plan_split``); None keeps the built-in smart/naive split.
    ``comm_budget`` applies the §14 resource-budget contention model;
    None / 1.0 is the legacy full-budget pricing, bit-exact."""
    d = cfg.d_model
    n = tp
    ops: List[Op] = []
    ring = mode in ("ring", "ringweave")
    hwc, hwm = (_ring_budgeted if ring else _budgeted)(hw, comm_budget)

    def comm_block(tag: str, t: int, deps) -> Tuple[str, List[Op]]:
        """the AR(+norm) slot; returns (terminal op name, ops)."""
        if ring:
            # one-kernel ring RS+norm+AG: norm never leaves VMEM
            dur = (2 * t_rs_or_ag(t, d, n, hwm)
                   + t_ring_norm(max(t // n, 1), d, hwm))
            o = Op(f"ring{tag}", "comm", dur, tuple(deps))
            return o.name, [o]
        if mode == "nocomm":
            o = Op(f"norm{tag}", "compute", t_norm(t, d, hwc, fused=False),
                   tuple(deps))
            return o.name, [o]
        if mode == "vanilla":
            a = Op(f"ar{tag}", "comm", t_allreduce(t, d, n, hwm), tuple(deps))
            b = Op(f"norm{tag}", "compute", t_norm(t, d, hwc, fused=False),
                   (a.name,))
            return b.name, [a, b]
        if mode == "reordered":
            a = Op(f"rs{tag}", "comm", t_rs_or_ag(t, d, n, hwm), tuple(deps))
            b = Op(f"norm{tag}", "compute",
                   t_norm(max(t // n, 1), d, hwc, fused=False), (a.name,))
            c = Op(f"ag{tag}", "comm", t_rs_or_ag(t, d, n, hwm), (b.name,))
            return c.name, [a, b, c]
        # fused kernel: RS + single-pass norm on t/N + AG as ONE comm op
        dur = (2 * t_rs_or_ag(t, d, n, hwm)
               + t_norm(max(t // n, 1), d, hwm, fused=True))
        o = Op(f"fused{tag}", "comm", dur, tuple(deps))
        return o.name, [o]

    if mode in ("vanilla", "reordered", "fuseonly", "nocomm", "ring"):
        prev = ()
        for i in range(n_layers):
            at = Op(f"attn{i}", "compute",
                    t_attn_layer(cfg, tokens, ctx, tp, hwc), prev)
            ops.append(at)
            t1, blk = comm_block(f"_a{i}", tokens, [at.name])
            ops += blk
            ff = Op(f"ffn{i}", "compute", t_ffn_layer(cfg, tokens, tp, hwc),
                    (t1,))
            ops.append(ff)
            t2, blk2 = comm_block(f"_f{i}", tokens, [ff.name])
            ops += blk2
            prev = (t2,)
        return ops

    assert mode in ("tokenweave", "ringweave")
    if split is None:
        split = smart_split(tokens, hw.tile) if smart else naive_split(tokens)
    if split is None:
        return layer_ops(cfg, "ring" if ring else "fuseonly", tokens, ctx,
                         tp, hw, n_layers, comm_budget=comm_budget)
    t0, t1v = split
    cache_ctx = max(ctx - tokens, 0)   # pre-existing (chunked-prefill) kv
    prev = {0: (), 1: ()}
    for i in range(n_layers):
        # paper Fig 8 order; suffix attends prefix's kv -> dep on attn0
        a0 = Op(f"attn0_{i}", "compute",
                t_attn_layer(cfg, t0, cache_ctx + t0, tp, hwc),
                prev[0])
        c0, blk0 = comm_block(f"_a0{i}", t0, [a0.name])
        a1 = Op(f"attn1_{i}", "compute",
                t_attn_layer(cfg, t1v, cache_ctx + tokens, tp, hwc),
                prev[1] + (a0.name,))
        c1, blk1 = comm_block(f"_a1{i}", t1v, [a1.name])
        f0 = Op(f"ffn0_{i}", "compute", t_ffn_layer(cfg, t0, tp, hwc), (c0,))
        d0, blkd0 = comm_block(f"_f0{i}", t0, [f0.name])
        f1 = Op(f"ffn1_{i}", "compute", t_ffn_layer(cfg, t1v, tp, hwc), (c1,))
        d1, blkd1 = comm_block(f"_f1{i}", t1v, [f1.name])
        ops += [a0, a1, f0, f1] + blk0 + blk1 + blkd0 + blkd1
        prev = {0: (d0,), 1: (d1,)}
    return ops


def layer_latency(cfg: ModelConfig, mode: str, tokens: int, *, tp: int = 8,
                  ctx: Optional[int] = None, hw: Optional[HW] = None,
                  n_layers: int = 4, smart: bool = True,
                  split: Optional[Tuple[int, int]] = None,
                  comm_budget: Optional[float] = None) -> float:
    """Steady-state per-layer latency (simulate n_layers, divide)."""
    hw = hw or HW()
    ctx = ctx if ctx is not None else tokens
    total, _ = simulate(layer_ops(cfg, mode, tokens, ctx, tp, hw,
                                  n_layers=n_layers, smart=smart,
                                  split=split, comm_budget=comm_budget))
    return total / n_layers


def e2e_latency(cfg: ModelConfig, mode: str, tokens: int, *,
                hw: Optional[HW] = None, **kw) -> float:
    per_layer = layer_latency(cfg, mode, tokens, hw=hw, **kw)
    return per_layer * cfg.num_layers + (hw.overhead if hw else 0.0)


def step_attribution(cfg: ModelConfig, mode: str, tokens: int, *,
                     tp: int = 8, ctx: Optional[int] = None,
                     hw: Optional[HW] = None, n_layers: int = 4,
                     split: Optional[Tuple[int, int]] = None,
                     comm_budget: Optional[float] = None
                     ) -> Dict[str, float]:
    """Per-forward compute/comm/overlap attribution (DESIGN.md §12).

    Runs the mode's schedule through the two-stream simulator and
    decomposes the makespan into stream-busy totals:

        overlapped = compute_busy + comm_busy - makespan   (clamped >= 0)

    i.e. the virtual time where both streams were occupied at once — the
    quantity TokenWeave exists to maximize.  Scaled from the simulated
    ``n_layers`` window to the full ``cfg.num_layers`` model, matching
    ``e2e_latency``.  This prices the per-forward weave attribution
    record the engine attaches to trace spans (obs/attribution.py).

    ``hw.overhead`` (the fixed per-dispatch cost fitted by
    analysis/calibration.py, DESIGN.md §13) is added once to the makespan
    — it is neither compute- nor comm-stream time, so the busy totals and
    the overlapped term are unaffected.

    ``split`` / ``comm_budget`` price a tuned plan's explicit split point
    and resource budget (DESIGN.md §14); the defaults keep the legacy
    smart-split full-budget pricing bit-exact — this is what the
    ``analysis/autotune.py`` offline search evaluates per candidate."""
    hw = hw or HW()
    ctx = ctx if ctx is not None else tokens
    ops = layer_ops(cfg, mode, tokens, ctx, tp, hw, n_layers=n_layers,
                    split=split, comm_budget=comm_budget)
    makespan, _ = simulate(ops)
    busy = {"compute": 0.0, "comm": 0.0}
    for op in ops:
        busy[op.stream] += op.duration
    scale = cfg.num_layers / n_layers
    return {
        "compute": busy["compute"] * scale,
        "comm": busy["comm"] * scale,
        "overlapped": max(busy["compute"] + busy["comm"] - makespan, 0.0)
        * scale,
        "makespan": makespan * scale + hw.overhead,
    }


# --------------------------------------------------------------------------
# speculative decoding (runtime/spec.py, DESIGN.md §8): decode modeled as a
# gamma+1-token verify batch per sequence
# --------------------------------------------------------------------------

def expected_tokens_per_step(gamma: int, alpha: float) -> float:
    """E[committed tokens per sequence per verify step] when each draft
    token is accepted independently with probability ``alpha``:
    1 + a + ... + a^gamma (Leviathan et al., 2023)."""
    return sum(alpha ** i for i in range(gamma + 1))


def spec_decode_latency(cfg: ModelConfig, mode: str, batch: int, gamma: int,
                        alpha: float, *, tp: int = 8, ctx: int = 8192,
                        hw: Optional[HW] = None, n_layers: int = 4,
                        smart: bool = True) -> float:
    """Per-COMMITTED-token decode latency under speculative verification.

    A plain decode iteration over ``batch`` sequences carries ``batch``
    tokens (``gamma == 0`` reduces to exactly that); a verify iteration
    carries ``batch * (gamma+1)`` tokens and commits
    ``batch * E[tokens/step]`` of them.  Because the verify batch is what
    the model actually sees, the TokenWeave split decision applies to it —
    this is where the weave-vs-unsplit crossover on the latency-critical
    decode path becomes visible: ``mode='tokenweave'`` only diverges from
    ``'fuseonly'`` once ``batch*(gamma+1)`` clears the wave/threshold
    floor, which plain decode (gamma = 0) essentially never does.
    """
    toks = batch * (gamma + 1)
    step = e2e_latency(cfg, mode, toks, tp=tp, ctx=ctx, hw=hw,
                       n_layers=n_layers, smart=smart)
    return step / (batch * expected_tokens_per_step(gamma, alpha))


def spec_decode_summary(cfg: ModelConfig, batch: int, gamma: int,
                        alpha: float, *, tp: int = 8, ctx: int = 8192,
                        hw: Optional[HW] = None) -> Dict[str, float]:
    """Per-committed-token latencies for the spec-vs-plain / weave-vs-unsplit
    grid the `serve/spec_decode` benchmark reports."""
    out = {}
    for mode in ("vanilla", "fuseonly", "tokenweave"):
        out[f"plain/{mode}"] = spec_decode_latency(
            cfg, mode, batch, 0, 0.0, tp=tp, ctx=ctx, hw=hw)
        out[f"spec/{mode}"] = spec_decode_latency(
            cfg, mode, batch, gamma, alpha, tp=tp, ctx=ctx, hw=hw)
    out["tokens_per_step"] = expected_tokens_per_step(gamma, alpha)
    out["verify_tokens"] = float(batch * (gamma + 1))
    return out


# --------------------------------------------------------------------------
# packed hybrid batching (DESIGN.md §6): the two-dispatch engine judges the
# decode batch and the prefill chunk against the weave threshold SEPARATELY;
# a packed iteration is one forward over the combined token count
# --------------------------------------------------------------------------

def packed_hybrid_latency(cfg: ModelConfig, mode: str, decode_tokens: int,
                          chunk_tokens: int, *, tp: int = 8, ctx: int = 8192,
                          hw: Optional[HW] = None,
                          n_layers: int = 4) -> Dict[str, float]:
    """One mixed continuous-batching iteration, both dispatch schemes.

    two_dispatch: decode forward (``decode_tokens``) + prefill forward
    (``chunk_tokens``), each independently falling back to the unsplit
    path when it alone sits under the wave/threshold floor.
    packed: ONE forward over ``decode_tokens + chunk_tokens`` — the weave
    decision sees the true combined iteration size, which is exactly the
    regime the two-dispatch scheme misses: each half sub-threshold, the
    sum comfortably above it.
    """
    kw = dict(tp=tp, ctx=ctx, hw=hw, n_layers=n_layers)
    two = (e2e_latency(cfg, mode, decode_tokens, **kw)
           + e2e_latency(cfg, mode, chunk_tokens, **kw))
    packed = e2e_latency(cfg, mode, decode_tokens + chunk_tokens, **kw)
    return {"two_dispatch": two, "packed": packed}


def online_load_mix(cfg: ModelConfig, mode: str, rate: float, *,
                    mean_in: int = 161, mean_out: int = 338, tp: int = 8,
                    ctx: int = 8192, hw: Optional[HW] = None,
                    packed: bool = True, iters: int = 60,
                    max_decode_tokens: int = 512,
                    max_chunk_tokens: int = 2048) -> Dict[str, float]:
    """Steady-state per-iteration token mix at offered load ``rate``
    (requests per virtual-time unit), via a Little's-law fixed point.

    At rate λ the engine must retire λ·mean_in prefill and λ·mean_out
    decode tokens per unit time; with iteration time t the per-iteration
    shares are c = λ·mean_in·t (chunk) and d = λ·mean_out·t (decode batch:
    λ·mean_out·t sequences × 1 token).  t itself depends on (d, c) through
    the latency model — packed: one forward over d+c; two-dispatch: two
    forwards judged separately — so we iterate to the fixed point (damped;
    converges because latency is flat under the wave quantum and ~linear
    above it).  This is what makes the ONLINE weave rate load-dependent:
    low load ⇒ tiny iterations ⇒ no weave; the packed engine crosses the
    threshold at a LOWER offered load than two-dispatch because it judges
    the combined d+c (DESIGN.md §10).

    ``max_decode_tokens`` / ``max_chunk_tokens`` mirror the engine's
    max_batch / chunk_tokens admission caps: past saturation the mix pins
    at the caps (queues grow unboundedly instead — the regime where
    goodput, not latency, is the metric) rather than diverging.
    """
    hw = hw or HW()
    kw = dict(tp=tp, ctx=ctx, hw=hw)
    t = e2e_latency(cfg, mode, 1, **kw)
    d = c = 1.0
    for _ in range(iters):
        d = min(max(rate * mean_out * t, 1.0), float(max_decode_tokens))
        c = min(max(rate * mean_in * t, 1.0), float(max_chunk_tokens))
        if packed:
            t_new = e2e_latency(cfg, mode, int(round(d + c)), **kw)
        else:
            t_new = (e2e_latency(cfg, mode, int(round(d)), **kw)
                     + e2e_latency(cfg, mode, int(round(c)), **kw))
        t = 0.5 * t + 0.5 * t_new
    return {"t_iter": t, "decode_tokens": d, "chunk_tokens": c}


def online_summary(cfg: ModelConfig, rates: List[float], *,
                   mean_in: int = 161, mean_out: int = 338, tp: int = 8,
                   ctx: int = 8192, hw: Optional[HW] = None,
                   max_decode_tokens: int = 256,
                   max_chunk_tokens: int = 2048
                   ) -> Dict[float, Dict[str, float]]:
    """Weave activation and latency vs offered load, both dispatch schemes
    — the `serve/online` analytic rows.  Per rate: the steady-state token
    mix, whether the packed iteration / the separate halves clear the
    split floor, and the tokenweave-vs-fuseonly iteration latencies.

    The default ``max_decode_tokens`` (= engine max_batch) sits under the
    2·tile split floor on purpose: a pure decode batch then NEVER weaves
    under two-dispatch — exactly the vLLM serving regime the paper calls
    out — so the mid-load window where the packed d+c clears the floor
    while both halves sit under it is visible in the sweep."""
    hw = hw or HW()
    caps = dict(max_decode_tokens=max_decode_tokens,
                max_chunk_tokens=max_chunk_tokens)
    out: Dict[float, Dict[str, float]] = {}
    for rate in rates:
        pk = online_load_mix(cfg, "tokenweave", rate, mean_in=mean_in,
                             mean_out=mean_out, tp=tp, ctx=ctx, hw=hw,
                             packed=True, **caps)
        two = online_load_mix(cfg, "tokenweave", rate, mean_in=mean_in,
                              mean_out=mean_out, tp=tp, ctx=ctx, hw=hw,
                              packed=False, **caps)
        pk_fo = online_load_mix(cfg, "fuseonly", rate, mean_in=mean_in,
                                mean_out=mean_out, tp=tp, ctx=ctx, hw=hw,
                                packed=True, **caps)
        d, c = pk["decode_tokens"], pk["chunk_tokens"]
        d2, c2 = two["decode_tokens"], two["chunk_tokens"]
        out[rate] = {
            "decode_tokens": d, "chunk_tokens": c,
            "t_iter_packed": pk["t_iter"], "t_iter_two": two["t_iter"],
            "packed_gain": pk_fo["t_iter"] / pk["t_iter"],
            "packed_weaves": float(
                smart_split(int(round(d + c)), hw.tile) is not None),
            "halves_weave": float(
                smart_split(int(round(d2)), hw.tile) is not None
                or smart_split(int(round(c2)), hw.tile) is not None),
        }
    return out


def online_crossover_rate(cfg: ModelConfig, rates: List[float],
                          **kw) -> Optional[float]:
    """Lowest offered load where the packed iteration weaves but the
    two-dispatch halves do not — the load window the online frontend
    opens (None when no swept rate lands in it)."""
    summary = online_summary(cfg, sorted(rates), **kw)
    for rate in sorted(summary):
        s = summary[rate]
        if s["packed_weaves"] and not s["halves_weave"]:
            return rate
    return None


def decode_fleet_mix(cfg: ModelConfig, mode: str, rate: float, *,
                     mean_out: int = 338, tp: int = 8, ctx: int = 8192,
                     hw: Optional[HW] = None, iters: int = 60,
                     max_decode_tokens: int = 1024) -> Dict[str, float]:
    """Steady-state per-iteration token count of a DEDICATED decode
    replica absorbing decode traffic at ``rate`` requests per virtual-time
    unit (runtime/cluster.py's disaggregated mode, DESIGN.md §11): the
    Little's-law fixed point ``d = rate * mean_out * t(d)`` with pure
    decode iterations (no chunk share — prefill lives on other replicas),
    capped by the replica's batch capacity."""
    hw = hw or HW()
    kw = dict(tp=tp, ctx=ctx, hw=hw)
    t = e2e_latency(cfg, mode, 1, **kw)
    d = 1.0
    for _ in range(iters):
        d = min(max(rate * mean_out * t, 1.0), float(max_decode_tokens))
        t = 0.5 * t + 0.5 * e2e_latency(cfg, mode, int(round(d)), **kw)
    return {"t_iter": t, "decode_tokens": d}


def cluster_summary(cfg: ModelConfig, rates: List[float], n_replicas: int,
                    *, n_decode: int = 1, mean_in: int = 161,
                    mean_out: int = 338, tp: int = 8, ctx: int = 8192,
                    hw: Optional[HW] = None, max_decode_tokens: int = 1024,
                    max_chunk_tokens: int = 2048
                    ) -> Dict[float, Dict[str, float]]:
    """Disaggregation crossover vs TOTAL offered load (the `serve/cluster`
    analytic rows, DESIGN.md §11).

    Monolithic fleet: ``n_replicas`` engines each serving ``rate /
    n_replicas`` of the mixed traffic — per-replica packed iterations of
    ``d + c`` tokens from ``online_load_mix``.  Disaggregated fleet of the
    SAME size: ``n_decode`` dedicated decode replicas concentrate the
    whole load's decode tokens (``rate / n_decode`` each), so their merged
    batches grow ``n_replicas * mean_out / (n_decode * (mean_in +
    mean_out))``-fold relative to a monolithic engine's share — the factor
    that pushes them over the TokenWeave split floor first."""
    hw = hw or HW()
    out: Dict[float, Dict[str, float]] = {}
    for rate in rates:
        mono = online_load_mix(cfg, "tokenweave", rate / n_replicas,
                               mean_in=mean_in, mean_out=mean_out, tp=tp,
                               ctx=ctx, hw=hw, packed=True,
                               max_decode_tokens=max_decode_tokens,
                               max_chunk_tokens=max_chunk_tokens)
        fleet = decode_fleet_mix(cfg, "tokenweave", rate / n_decode,
                                 mean_out=mean_out, tp=tp, ctx=ctx, hw=hw,
                                 max_decode_tokens=max_decode_tokens)
        m_tok = int(round(mono["decode_tokens"] + mono["chunk_tokens"]))
        d_tok = int(round(fleet["decode_tokens"]))
        fleet_fo = decode_fleet_mix(cfg, "fuseonly", rate / n_decode,
                                    mean_out=mean_out, tp=tp, ctx=ctx,
                                    hw=hw,
                                    max_decode_tokens=max_decode_tokens)
        out[rate] = {
            "mono_iter_tokens": float(m_tok),
            "decode_fleet_tokens": float(d_tok),
            "t_iter_mono": mono["t_iter"],
            "t_iter_decode_fleet": fleet["t_iter"],
            "decode_fleet_gain": fleet_fo["t_iter"] / fleet["t_iter"],
            "mono_weaves": float(smart_split(m_tok, hw.tile) is not None),
            "decode_fleet_weaves": float(
                smart_split(d_tok, hw.tile) is not None),
        }
    return out


def cluster_crossover_rate(cfg: ModelConfig, rates: List[float],
                           n_replicas: int, **kw) -> Optional[float]:
    """Lowest TOTAL offered load where the disaggregated decode fleet's
    merged batches weave while a monolithic engine's share of the same
    traffic does not — the load window disaggregation opens (None when no
    swept rate lands in it)."""
    summary = cluster_summary(cfg, sorted(rates), n_replicas, **kw)
    for rate in sorted(summary):
        s = summary[rate]
        if s["decode_fleet_weaves"] and not s["mono_weaves"]:
            return rate
    return None


def packed_summary(cfg: ModelConfig, decode_tokens: int, chunk_tokens: int,
                   *, tp: int = 8, ctx: int = 8192,
                   hw: Optional[HW] = None) -> Dict[str, float]:
    """The weave-crossover grid the `serve/packed` benchmark reports.

    ``packed_weaves`` / ``halves_weave`` expose the split decisions so the
    interesting cell — halves both unsplit, packed split — is visible:
    there ``two/tokenweave == two/fuseonly`` (the weave never fired) while
    ``packed/tokenweave < packed/fuseonly`` (it did)."""
    hw = hw or HW()
    out: Dict[str, float] = {}
    for mode in ("fuseonly", "tokenweave"):
        r = packed_hybrid_latency(cfg, mode, decode_tokens, chunk_tokens,
                                  tp=tp, ctx=ctx, hw=hw)
        out[f"two/{mode}"] = r["two_dispatch"]
        out[f"packed/{mode}"] = r["packed"]
    out["halves_weave"] = float(
        smart_split(decode_tokens, hw.tile) is not None
        or smart_split(chunk_tokens, hw.tile) is not None)
    out["packed_weaves"] = float(
        smart_split(decode_tokens + chunk_tokens, hw.tile) is not None)
    return out
