"""qwen2-vl-7b [vlm]: 28L d=3584 28H (GQA kv=4) d_ff=18944 vocab=152064;
M-RoPE (16,24,24 sections); vision frontend stubbed (input_specs supplies
patch embeddings). [arXiv:2409.12191]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
)
