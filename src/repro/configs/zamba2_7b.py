"""zamba2-7b [hybrid]: 81 Mamba-2 blocks d=3584 + shared 2d-wide attention
(32H) every 6 blocks w/ per-invocation LoRA; ssm_state=64, d_inner=7168,
112 ssm heads (dh=64). [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_version=2, ssm_heads=112, ssm_conv=4,
    shared_attn_period=6,
    rope_theta=10_000.0,
    supports_long_context=True,
)
