"""llama-3.3-70b (paper model): 80L d=8192 64H (GQA kv=8) d_ff=28672
vocab=128256. [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.3-70b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    rope_theta=500_000.0,
)
