"""Model + parallelism configuration dataclasses.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
``ModelConfig.reduced()`` returns a tiny same-family config for CPU smoke
tests; the full configs are only ever lowered via ShapeDtypeStruct in the
multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention details ---
    sliding_window: int = 0          # 0 = full attention
    local_global_period: int = 0     # gemma3: 5 local then 1 global -> period 6
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen1.5
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0    # gemma3 local layers
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) splits
    learned_positions: bool = False  # whisper

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    moe_partition: str = "expert"    # expert | ffn | ep2d
    capacity_factor: float = 1.25
    norm_topk_prob: bool = True

    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1             # 1 = mamba1 selective scan, 2 = SSD
    ssm_heads: int = 0               # mamba2 heads
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model/16)

    # --- hybrid (zamba2) ---
    shared_attn_period: int = 0      # apply shared attention block every k blocks

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    max_source_positions: int = 0

    # --- misc ---
    embed_scale: float = 1.0         # gemma: sqrt(d_model)
    sandwich_norms: bool = False     # gemma3 post-attn/post-ffn norms
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # long-context capability flag: archs with bounded attention state can run
    # the 500k decode cell. (full-attention archs skip it; see DESIGN.md)
    supports_long_context: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm_dt_rank == 0 and self.ssm_state > 0:
            object.__setattr__(self, "ssm_dt_rank", math.ceil(self.d_model / 16))

    # ----- derived sizes -------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate parameter count N (used for MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # embeddings
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "hybrid", "encdec"):
            hd = self.head_dim
            qkv = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd)
            o = self.num_heads * hd * d
            attn = qkv + o
        else:
            attn = 0
        if self.family == "ssm":
            di, s = self.d_inner, self.ssm_state
            per_layer = (d * 2 * di            # in_proj (x and z)
                         + di * self.ssm_conv  # conv
                         + di * (self.ssm_dt_rank + 2 * s)  # x_proj
                         + self.ssm_dt_rank * di            # dt_proj
                         + di * s              # A_log
                         + di                  # D
                         + di * d)             # out_proj
            n += L * (per_layer + d)
            return n
        if self.is_moe:
            ffn = 3 * d * self.moe_d_ff * self.num_experts
            ffn += d * self.num_experts  # router
        else:
            mult = 3 if self.act == "silu" else 2
            ffn = mult * d * self.d_ff
        n += L * (attn + ffn + 2 * d)
        if self.family == "encdec":
            # encoder layers + cross attention in decoder
            enc_ffn = 2 * d * self.d_ff
            n += self.encoder_layers * (attn + enc_ffn + 2 * d)
            n += L * attn  # cross attention
        if self.family == "hybrid":
            # mamba2 backbone blocks
            di, s = self.d_inner, self.ssm_state
            nh = max(self.ssm_heads, 1)
            mamba = (d * 2 * di + di * self.ssm_conv + di * d
                     + di * 2 * s + nh + nh + di)
            n += L * mamba
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        n = self.param_count()
        dead = 3 * self.d_model * self.moe_d_ff * (
            self.num_experts - self.num_experts_per_tok) * self.num_layers
        return n - dead

    # ----- reduced config for smoke tests --------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config: runs a forward/train step on 1 CPU core."""
        kw = dataclasses.asdict(self)
        kw.update(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32 if self.num_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            dtype="float32",
        )
        if self.local_global_period:
            kw["num_layers"] = self.local_global_period  # one full pattern
            kw["sliding_window"] = 16
        if self.sliding_window and not self.local_global_period:
            kw["sliding_window"] = 16
        if self.is_moe:
            kw.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=64)
        if self.ssm_state:
            kw.update(ssm_state=8, ssm_dt_rank=8,
                      ssm_heads=4 if self.ssm_heads else 0)
        if self.family == "hybrid":
            kw.update(num_layers=6, shared_attn_period=3)
        if self.family == "encdec":
            kw.update(encoder_layers=2, max_source_positions=64)
        if self.mrope_sections:
            kw["mrope_sections"] = (8, 4, 4)  # sums to head_dim//2 = 16
        return ModelConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the model is laid out on the mesh + which TokenWeave features run."""
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("data",)   # ("pod","data") multi-pod
    # comm_mode: how the post-matmul AllReduce + residual + RMSNorm executes
    #   vanilla   : psum -> (+residual) -> full redundant RMSNorm   (baseline)
    #   reordered : psum_scatter -> +res -> RMSNorm -> all_gather (unfused ops)
    #   fused     : psum_scatter -> single-pass fused add+norm -> all_gather
    #   ring      : ONE Pallas ring AllReduce-RMSNorm kernel (reduce-scatter,
    #               fused add+norm on the owned chunk, all-gather; falls back
    #               to `fused` where unsupported — core/fused_collectives.py)
    #   nocomm    : skip collectives entirely (perf counterfactual, wrong math)
    comm_mode: str = "fused"
    tokenweave: bool = True
    tokenweave_min_tokens: int = 512
    split_unit: int = 0                    # 0 = auto (lcm(tp, 256))
    attn_impl: str = "chunked"             # ref | chunked | pallas
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    remat: bool = True
    scan_layers: bool = True
    use_pallas_norm: bool = False          # pallas fused rmsnorm (TPU target)
    # §Perf: pin collectives to bf16 (optimization_barrier stops XLA's
    # excess-precision pass from hoisting downstream f32 casts above the
    # RS/AG, which doubles wire bytes)
    bf16_wire: bool = False
    seq_shard_kv: bool = False             # context-parallel KV over dp axis
    grad_compression: str = "none"         # none | int8
    moe_ep_axis: str = "data"              # a2a axis for ep2d partitioning
    # per-site overlap policy (core/policy.OverlapPolicy, DESIGN.md §14);
    # None = degenerate global-threshold policy (token-identical to the
    # legacy split_decision path). Typed Any to avoid a configs->core
    # import; policies are frozen/hashable so the config stays hashable.
    overlap_policy: "object | None" = None

    @property
    def axes(self) -> Tuple[str, ...]:
        return tuple(self.dp_axes) + (self.tp_axis,)

    def split_unit_for(self, tp: int) -> int:
        if self.split_unit:
            u = self.split_unit
        else:
            u = 256
        # every split must be divisible by tp for tiled psum_scatter
        return math.lcm(u, max(tp, 1))
