"""mixtral-8x22b (paper model) [moe]: 56L d=6144 48H (GQA kv=8)
d_ff(expert)=16384, 8 experts top-2 vocab=32768; 'ffn' partitioning (every
shard holds a d_ff slice of every expert — vLLM-style TP MoE, E < tp).
[arXiv:2401.04088]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    num_experts=8, num_experts_per_tok=2, moe_d_ff=16384,
    moe_partition="ffn",
    rope_theta=1_000_000.0,
)
