"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4) d_ff(expert)=1536
vocab=151936, 128 experts top-8; ep2d partitioning (experts over data x d_ff
over model) — the only layout that fits 235B on v5e-256; dispatch a2a is the
DeepSeek-style comm the paper contrasts with. [hf:Qwen/Qwen3 family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    num_experts=128, num_experts_per_tok=8, moe_d_ff=1536,
    moe_partition="ep2d", qk_norm=True,
    rope_theta=1_000_000.0,
)
