"""olmoe-1b-7b [moe]: 16L d=2048 16H d_ff(expert)=1024 vocab=50304,
64 experts top-8; expert-parallel over the model axis (combine = the layer's
TP AllReduce -> TokenWeave fused kernel applies unchanged). [arXiv:2409.02060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304,
    num_experts=64, num_experts_per_tok=8, moe_d_ff=1024,
    moe_partition="expert", norm_topk_prob=False,
    rope_theta=10_000.0,
)
