"""Architecture registry: the 10 assigned archs + the paper's own models."""
from repro.configs.base import ModelConfig, ParallelConfig  # noqa: F401

_MODULES = {
    "gemma3-1b": "gemma3_1b",
    "qwen1.5-4b": "qwen1_5_4b",
    "deepseek-67b": "deepseek_67b",
    "qwen3-14b": "qwen3_14b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "zamba2-7b": "zamba2_7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-base": "whisper_base",
    "llama3.3-70b": "llama3_70b",
    "qwen2.5-72b": "qwen2_5_72b",
    "mixtral-8x22b": "mixtral_8x22b",
}

ASSIGNED = list(_MODULES)[:10]
PAPER_MODELS = list(_MODULES)[10:]


def get_config(name: str) -> ModelConfig:
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def list_configs():
    return list(_MODULES)
