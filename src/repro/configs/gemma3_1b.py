"""gemma3-1b [dense]: 26L d=1152 4H (GQA kv=1, head_dim=256) d_ff=6912
vocab=262144; 5 local (sw=512) : 1 global pattern; dual rope theta; qk-norm,
sandwich norms, GEGLU, tied+scaled embeddings. [hf:google/gemma-3-1b-pt]"""
import math

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    sliding_window=512, local_global_period=6,
    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
    qk_norm=True, sandwich_norms=True, act="geglu",
    embed_scale=math.sqrt(1152.0), tie_embeddings=True,
    supports_long_context=True,
)
