"""whisper-base [audio enc-dec]: 6L enc + 6L dec, d=512 8H d_ff=2048
vocab=51865; conv frontend STUBBED (input_specs supplies frame embeddings);
learned positions (decoder table grown for long decode cells — documented
deviation). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    num_layers=6, encoder_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    head_dim=64, d_ff=2048, vocab_size=51865,
    act="gelu", learned_positions=True, max_source_positions=1500,
)
