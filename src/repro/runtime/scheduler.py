"""Iteration-level scheduler with Sarathi-style chunked prefill and
(optionally) paged-KV admission control.

Each engine iteration the scheduler emits:
  * a decode batch: one token for every DECODE-state request (if any), and
  * a prefill chunk: up to ``chunk_tokens`` tokens from WAITING/PREFILL
    requests with equal chunk lengths (rectangular batches keep shapes
    static; lengths are bucketed to powers of two to bound recompilation).

The two are dispatched as two forward calls per iteration (documented
simplification vs. packed ragged hybrid batches, DESIGN.md §6). TokenWeave
is applied inside the model per batch: chunks >= ``tokenweave_min_tokens``
take the two-split weave; small decode batches fall back to the unsplit
fused kernel — the same policy the paper uses for vLLM integration.

Paged mode (``SchedulerConfig.paged``) changes admission and accounting:

* a request is admitted only when the block manager has room for its miss
  suffix plus one decode block (FIFO head-of-line; no skipping, so no
  starvation), and its ``prefill_pos`` starts at the prefix-cache hit
  length — so only MISS tokens are charged against ``chunk_tokens`` and
  the weave-threshold decision (``tokenweave_min_tokens``) sees the true
  compute size of the batch, not the nominal prompt size.
* a running request can be preempted (DECODE -> WAITING, recompute): its
  blocks are freed and it re-enters the queue front with its generated
  tokens folded into the context (``Request.resumed``).

Speculative decoding (``spec_gamma > 0``) charges the verify batch —
gamma+1 tokens per decoding sequence — against ``chunk_tokens`` before
sizing the prefill chunk, so the combined iteration token count stays
bounded (DESIGN.md §8).

Packed mode (``packed=True``, DESIGN.md §6) replaces the two dispatches
with ONE plan per iteration: decode slots (1 token), speculative verify
windows (γ+1 tokens, worst case — the engine may shrink a draft), and
per-request prefill takes are concatenated along a single token axis.
Prefill takes need no rectangularity (the packed axis is ragged by
construction), so the whole remaining ``chunk_tokens`` budget is usable
every iteration, and the engine's single forward judges the weave
threshold against the TRUE combined token count.  Invariant (tests pin
it): ``PackedPlan.total_tokens <= chunk_tokens``, which requires
``chunk_tokens >= max_batch * (spec_gamma + 1)`` — validated here.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

from repro.runtime.requests import Request, State


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 8              # cache slots
    chunk_tokens: int = 2048        # Sarathi chunk budget (vLLM default 2k)
    max_len: int = 4096
    prefill_bucket: int = 64        # chunk lengths rounded to this multiple
    # --- paged KV cache (vLLM-style block pool) ---
    paged: bool = False
    block_size: int = 16            # tokens per KV block
    num_blocks: int = 0             # 0 -> max_batch * ceil(max_len/block)
    prefix_caching: bool = True
    # --- speculative decoding (runtime/spec.py, DESIGN.md §8) ---
    spec_gamma: int = 0             # draft tokens per verify step (0 = off)
    spec_ngram: int = 3             # n-gram length of the default draft
    # --- packed hybrid batching (one forward per iteration, DESIGN.md §6) --
    packed: bool = False
    # --- online admission policy (runtime/server.py, DESIGN.md §10) ---
    # "fcfs": queue order (arrival order; preempted requests resume first).
    # "edf":  earliest-deadline-first among waiting requests (requests
    #         without a deadline sort last, FCFS among themselves).
    # A callable can be plugged directly via ``Scheduler(..., policy=fn)``.
    policy: str = "fcfs"
    # --- overlap policy plan cache (core/policy.py, DESIGN.md §14) ---
    # path to a tuned-plan JSON under benchmarks/plans/; the engine loads
    # it at startup and installs the TunedPolicy on the model's
    # ParallelConfig.  None keeps the degenerate global threshold.
    plan_path: Optional[str] = None

    def __post_init__(self):
        if self.policy not in ADMISSION_POLICIES:
            raise ValueError(
                f"unknown admission policy {self.policy!r}; known: "
                f"{sorted(ADMISSION_POLICIES)}")
        if self.packed:
            w = self.spec_gamma + 1
            if self.chunk_tokens < self.max_batch * w:
                raise ValueError(
                    f"packed mode needs chunk_tokens >= max_batch * "
                    f"(spec_gamma+1) = {self.max_batch * w} so mandatory "
                    f"decode/verify slots always fit the packed budget "
                    f"(got {self.chunk_tokens})")

    @property
    def max_blocks_per_req(self) -> int:
        return -(-self.max_len // self.block_size)

    @property
    def effective_num_blocks(self) -> int:
        return self.num_blocks or self.max_batch * self.max_blocks_per_req


def _edf_key(r: Request):
    """Earliest-deadline-first: deadline-less requests sort after every
    deadline-carrying one and stay FCFS among themselves (stable sort on
    the queue preserves arrival/preemption order for ties)."""
    return (r.deadline if r.deadline is not None else float("inf"),)


# name -> sort key over waiting requests, or None to keep queue order.
# The sort is STABLE, so equal keys preserve arrival order and a preempted
# request (re-queued at the front) resumes before same-priority peers.
ADMISSION_POLICIES = {
    "fcfs": None,
    "edf": _edf_key,
}


@dataclasses.dataclass
class ScheduleStep:
    decode_slots: List[int]
    prefill: Optional[Tuple[List[Request], int]]  # (requests, chunk_len)


@dataclasses.dataclass
class PackedSegment:
    """One contiguous run of the packed token axis (DESIGN.md §6).

    kind encodes the cache interaction: ``prefill`` scatters ``n_tokens``
    new context positions; ``decode`` carries the single pending input;
    ``verify`` budgets a speculative window of 1 + gamma tokens (the
    engine packs 1 + len(draft) actual tokens, never more).  Query
    positions and causal extent derive from the owning request: a
    segment's tokens occupy absolute positions ``pos0 .. pos0+n-1`` and
    attend the request's cache rows up to their own position.
    """
    req: Request
    kind: str                       # "prefill" | "decode" | "verify"
    n_tokens: int                   # budgeted tokens (verify: worst case)


@dataclasses.dataclass
class PackedPlan:
    segments: List[PackedSegment]
    total_tokens: int               # sum of budgeted segment tokens
    # the overlap decision for this plan (a models.transformer.WeaveInfo),
    # stamped by the engine's overlap hint at planning time so the packed
    # planner and the forward dispatch consume ONE plan format
    # (DESIGN.md §14); None until the hint runs (or when no hint is wired)
    overlap: Optional[object] = None


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, block_mgr=None, policy=None,
                 on_admit=None, overlap_hint=None):
        self.cfg = cfg
        self.block_mgr = block_mgr          # BlockManager when cfg.paged
        self.waiting: List[Request] = []
        self.active: List[Optional[Request]] = [None] * cfg.max_batch
        self.finished: List[Request] = []
        # pluggable priority: explicit callable wins, else the named policy
        # (NB: ``policy`` here is the ADMISSION policy — the per-site
        # OVERLAP policy arrives through ``overlap_hint`` below)
        self.policy_key = (policy if policy is not None
                           else ADMISSION_POLICIES[cfg.policy])
        # observation-only admission hook (the engine's trace recorder,
        # DESIGN.md §12) — fired after the request lands in its slot
        self.on_admit = on_admit
        # tokens -> WeaveInfo: the engine's view of the active overlap
        # policy at the packed site (DESIGN.md §14); stamps
        # PackedPlan.overlap so the planner shares the dispatch's plan
        self.overlap_hint = overlap_hint

    # ---- admission -------------------------------------------------------
    def add(self, req: Request):
        self.waiting.append(req)

    def remove_waiting(self, req: Request) -> bool:
        """Drop a not-yet-admitted request (online cancellation)."""
        try:
            self.waiting.remove(req)
            return True
        except ValueError:
            return False

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def _admit(self):
        if self.policy_key is not None and len(self.waiting) > 1:
            self.waiting.sort(key=self.policy_key)   # stable: FCFS ties
        for slot in self._free_slots():
            if not self.waiting:
                break
            req = self.waiting[0]
            if self.block_mgr is not None:
                # one-shot: prefix-match + allocate (+1 decode-block
                # headroom), rolled back atomically on failure.
                # FIFO head-of-line: no skipping, so no starvation
                hit = self.block_mgr.allocate_prompt(req.rid,
                                                     req.context_tokens)
                if hit < 0:
                    break
                req.prefill_pos = hit
                req.prompt_hit_tokens = hit
            self.waiting.pop(0)
            req.slot = slot
            req.state = State.PREFILL
            self.active[slot] = req
            if self.on_admit is not None:
                self.on_admit(req)

    # ---- preemption ------------------------------------------------------
    def preempt(self, req: Request):
        """Recompute-mode preemption: blocks are gone (the engine freed
        them); the request re-prefills prompt + generated-so-far on its
        next admission.  Front of the queue so it resumes first."""
        assert req.state in (State.DECODE, State.PREFILL)
        self.active[req.slot] = None
        req.slot = None
        req.state = State.WAITING
        req.prefill_pos = 0
        req.prompt_hit_tokens = 0
        req.preemptions += 1
        req.resumed = bool(req.output)
        self.waiting.insert(0, req)

    # ---- one iteration ----------------------------------------------------
    def next_step(self) -> Optional[Union[ScheduleStep, "PackedPlan"]]:
        self._admit()
        decode_slots = [r.slot for r in self.active
                        if r is not None and r.state == State.DECODE]

        prefilling = [r for r in self.active
                      if r is not None and r.state == State.PREFILL]
        if self.cfg.packed:
            return self._next_packed(prefilling)
        prefill = None
        budget = self.cfg.chunk_tokens
        if self.cfg.spec_gamma and decode_slots:
            # speculative verify rides the same iteration as the chunk and
            # carries gamma+1 tokens per decoding sequence: charge them
            # against the chunk budget so the combined iteration token
            # count stays bounded (and the weave-threshold decision inside
            # the model sees honestly-sized batches on both calls)
            budget -= len(decode_slots) * (self.cfg.spec_gamma + 1)
        if prefilling and budget >= min(self.cfg.prefill_bucket,
                                        self.cfg.chunk_tokens):
            b = self.cfg.prefill_bucket
            # chunk length: bucketized max remaining MISS tokens, capped by
            # the budget (prefix-hit tokens are never re-charged)
            remains = [len(r.context_tokens) - r.prefill_pos
                       for r in prefilling]
            chunk = min(budget, max(remains))
            chunk = min(max(b, ((chunk + b - 1) // b) * b), budget)
            group, n_tok = [], 0
            for r in prefilling:
                if n_tok + chunk > budget and group:
                    break
                group.append(r)
                n_tok += chunk
            prefill = (group, chunk)

        if not decode_slots and prefill is None:
            return None
        return ScheduleStep(decode_slots=decode_slots, prefill=prefill)

    def _next_packed(self, prefilling: List[Request]) -> Optional[PackedPlan]:
        """Build one packed plan: mandatory decode/verify segments first
        (charged at their worst-case width), then per-request prefill
        takes filling the remaining ``chunk_tokens`` budget.  Prefill
        takes are ragged — no bucketing, no shared chunk length — so the
        budget is fully usable; the ENGINE pads only the plan total (to a
        recompilation bucket), never individual segments."""
        budget = self.cfg.chunk_tokens
        w = self.cfg.spec_gamma + 1 if self.cfg.spec_gamma else 1
        kind = "verify" if self.cfg.spec_gamma else "decode"
        segs = []
        for r in self.active:
            if r is not None and r.state == State.DECODE:
                segs.append(PackedSegment(req=r, kind=kind, n_tokens=w))
                budget -= w
        for r in prefilling:
            if budget <= 0:
                break
            take = min(budget, len(r.context_tokens) - r.prefill_pos)
            if take <= 0:
                continue
            segs.append(PackedSegment(req=r, kind="prefill", n_tokens=take))
            budget -= take
        if not segs:
            return None
        plan = PackedPlan(segments=segs,
                          total_tokens=sum(s.n_tokens for s in segs))
        if self.overlap_hint is not None:
            plan.overlap = self.overlap_hint(plan.total_tokens)
        return plan

    # ---- bookkeeping ------------------------------------------------------
    def finish(self, req: Request, step: int):
        req.state = State.DONE
        req.done_step = step
        self.active[req.slot] = None
        self.finished.append(req)

    def all_done(self) -> bool:
        return not self.waiting and all(r is None for r in self.active)
