"""Iteration-level scheduler with Sarathi-style chunked prefill.

Each engine iteration the scheduler emits:
  * a decode batch: one token for every DECODE-state request (if any), and
  * a prefill chunk: up to ``chunk_tokens`` tokens from WAITING/PREFILL
    requests with equal chunk lengths (rectangular batches keep shapes
    static; lengths are bucketed to powers of two to bound recompilation).

The two are dispatched as two forward calls per iteration (documented
simplification vs. packed ragged hybrid batches, DESIGN.md §6). TokenWeave
is applied inside the model per batch: chunks >= ``tokenweave_min_tokens``
take the two-split weave; small decode batches fall back to the unsplit
fused kernel — the same policy the paper uses for vLLM integration.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.runtime.requests import Request, State


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 8              # cache slots
    chunk_tokens: int = 2048        # Sarathi chunk budget (vLLM default 2k)
    max_len: int = 4096
    prefill_bucket: int = 64        # chunk lengths rounded to this multiple


@dataclasses.dataclass
class ScheduleStep:
    decode_slots: List[int]
    prefill: Optional[Tuple[List[Request], int]]  # (requests, chunk_len)


class Scheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.waiting: List[Request] = []
        self.active: List[Optional[Request]] = [None] * cfg.max_batch
        self.finished: List[Request] = []

    # ---- admission -------------------------------------------------------
    def add(self, req: Request):
        self.waiting.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def _admit(self):
        for slot in self._free_slots():
            if not self.waiting:
                break
            req = self.waiting.pop(0)
            req.slot = slot
            req.state = State.PREFILL
            self.active[slot] = req

    # ---- one iteration ----------------------------------------------------
    def next_step(self) -> Optional[ScheduleStep]:
        self._admit()
        decode_slots = [r.slot for r in self.active
                        if r is not None and r.state == State.DECODE]

        prefilling = [r for r in self.active
                      if r is not None and r.state == State.PREFILL]
        prefill = None
        if prefilling:
            budget = self.cfg.chunk_tokens
            b = self.cfg.prefill_bucket
            # chunk length: bucketized max remaining, capped by the budget
            remains = [len(r.prompt) - r.prefill_pos for r in prefilling]
            chunk = min(budget, max(remains))
            chunk = min(max(b, ((chunk + b - 1) // b) * b), budget)
            group, n_tok = [], 0
            for r in prefilling:
                if n_tok + chunk > budget and group:
                    break
                group.append(r)
                n_tok += chunk
            prefill = (group, chunk)

        if not decode_slots and prefill is None:
            return None
        return ScheduleStep(decode_slots=decode_slots, prefill=prefill)

    # ---- bookkeeping ------------------------------------------------------
    def finish(self, req: Request, step: int):
        req.state = State.DONE
        req.done_step = step
        self.active[req.slot] = None
        self.finished.append(req)

    def all_done(self) -> bool:
        return not self.waiting and all(r is None for r in self.active)
