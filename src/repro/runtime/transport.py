"""Wire transport for cross-host serving (DESIGN.md §15).

One versioned binary codec, three carriers:

* **Framing codec** — ``encode_frame(kind, obj)`` / ``decode_frame(buf)``:
  a self-delimiting frame (magic, version, body length, CRC-32) around a
  tagged recursive value encoding that covers everything the serving
  layer ships — request/response envelopes (plain dicts of scalars,
  strings and lists) and the ``export_blocks``/``import_blocks``
  KV-migration payload trees (nested dicts of numpy arrays, stacked or
  per-layer).  Arrays round-trip BIT-identical (dtype string + shape +
  raw C-order bytes); truncated or corrupted frames raise
  ``TransportError`` instead of mis-importing (pinned by
  tests/test_transport.py).

* **LoopbackTransport** — the deterministic in-memory wire the
  virtual-clock cluster twin uses (``ClusterConfig.wire="loopback"``):
  every transfer is a real encode→decode round trip through the codec
  with frame/byte accounting, but no sockets and no wall time, so CI
  exercises the serialization boundary bit-for-bit while staying
  replayable.

* **Socket transport** — the same codec over real connections:
  ``read_frame_async``/``write_frame_async`` for asyncio streams,
  ``SocketChannel`` as the blocking client.  ``EngineHost`` serves one
  ``Engine`` behind a small command protocol (submit / step / adopt /
  abort / quiesce / …), and ``RemoteEngine`` is the client-side proxy
  that plugs into ``runtime/cluster.py``'s ``Replica`` unchanged — the
  multi-process launch mode (``python -m repro.runtime.transport``)
  spawns one host per replica process.  A dead peer surfaces as
  ``ReplicaGone``, which the cluster treats as a missed heartbeat
  (failure handling, DESIGN.md §15).
"""
from __future__ import annotations

import asyncio
import os
import socket
import struct
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.engine import Engine, Handoff
from repro.runtime.requests import Request, State, reset_for_requeue

MAGIC = b"TKWV"
WIRE_VERSION = 1
_HEADER = struct.Struct("!4sHI")     # magic, version, body length
_CRC = struct.Struct("!I")           # crc32 over the body
_U32 = struct.Struct("!I")
_I64 = struct.Struct("!q")
_F64 = struct.Struct("!d")
_U8 = struct.Struct("!B")

# one frame tops out well under this; a corrupted length field must not
# make a reader try to allocate gigabytes
MAX_FRAME_BODY = 1 << 30


class TransportError(RuntimeError):
    """Malformed wire data: truncated, corrupted, or version-skewed."""


class ReplicaGone(TransportError):
    """The peer vanished mid-conversation (socket EOF/reset) — the
    cluster's dead-replica detector treats this as a missed heartbeat."""


# --------------------------------------------------------------------------
# tagged value encoding
# --------------------------------------------------------------------------

def _enc_value(obj, out: List[bytes]) -> None:
    if obj is None:
        out.append(b"N")
    elif obj is True:
        out.append(b"T")
    elif obj is False:
        out.append(b"F")
    elif isinstance(obj, (int, np.integer)):
        out.append(b"I" + _I64.pack(int(obj)))
    elif isinstance(obj, (float, np.floating)):
        out.append(b"D" + _F64.pack(float(obj)))
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out.append(b"S" + _U32.pack(len(raw)) + raw)
    elif isinstance(obj, (bytes, bytearray)):
        out.append(b"B" + _U32.pack(len(obj)) + bytes(obj))
    elif isinstance(obj, np.ndarray):
        # NOT ascontiguousarray: that promotes 0-d arrays to 1-d, which
        # would silently change the decoded shape
        arr = np.asarray(obj, order="C")
        dt = arr.dtype.str.encode("ascii")
        out.append(b"A" + _U8.pack(len(dt)) + dt + _U8.pack(arr.ndim))
        for dim in arr.shape:
            out.append(_U32.pack(dim))
        raw = arr.tobytes()
        out.append(_U32.pack(len(raw)))
        out.append(raw)
    elif isinstance(obj, (list, tuple)):
        out.append(b"L" + _U32.pack(len(obj)))
        for item in obj:
            _enc_value(item, out)
    elif isinstance(obj, dict):
        out.append(b"M" + _U32.pack(len(obj)))
        for k, v in obj.items():
            if not isinstance(k, str):
                raise TypeError(f"wire dict keys must be str, got {k!r}")
            raw = k.encode("utf-8")
            out.append(_U32.pack(len(raw)) + raw)
            _enc_value(v, out)
    else:
        raise TypeError(f"cannot encode {type(obj).__name__!r} for the wire")


def _take(buf: bytes, off: int, n: int) -> Tuple[bytes, int]:
    if off + n > len(buf):
        raise TransportError(
            f"truncated frame body: need {n} bytes at offset {off}, "
            f"have {len(buf) - off}")
    return buf[off:off + n], off + n


def _dec_value(buf: bytes, off: int):
    tag, off = _take(buf, off, 1)
    if tag == b"N":
        return None, off
    if tag == b"T":
        return True, off
    if tag == b"F":
        return False, off
    if tag == b"I":
        raw, off = _take(buf, off, 8)
        return _I64.unpack(raw)[0], off
    if tag == b"D":
        raw, off = _take(buf, off, 8)
        return _F64.unpack(raw)[0], off
    if tag == b"S":
        raw, off = _take(buf, off, 4)
        raw, off = _take(buf, off, _U32.unpack(raw)[0])
        return raw.decode("utf-8"), off
    if tag == b"B":
        raw, off = _take(buf, off, 4)
        raw, off = _take(buf, off, _U32.unpack(raw)[0])
        return raw, off
    if tag == b"A":
        raw, off = _take(buf, off, 1)
        dt, off = _take(buf, off, _U8.unpack(raw)[0])
        try:
            dtype = np.dtype(dt.decode("ascii"))
        except (TypeError, ValueError) as e:
            raise TransportError(f"bad array dtype on the wire: {e}")
        raw, off = _take(buf, off, 1)
        ndim = _U8.unpack(raw)[0]
        shape = []
        for _ in range(ndim):
            raw, off = _take(buf, off, 4)
            shape.append(_U32.unpack(raw)[0])
        raw, off = _take(buf, off, 4)
        nbytes = _U32.unpack(raw)[0]
        want = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        if nbytes != want:
            raise TransportError(
                f"array payload length {nbytes} != shape/dtype size {want}")
        raw, off = _take(buf, off, nbytes)
        arr = np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
        return arr, off
    if tag == b"L":
        raw, off = _take(buf, off, 4)
        n = _U32.unpack(raw)[0]
        items = []
        for _ in range(n):
            item, off = _dec_value(buf, off)
            items.append(item)
        return items, off
    if tag == b"M":
        raw, off = _take(buf, off, 4)
        n = _U32.unpack(raw)[0]
        d = {}
        for _ in range(n):
            raw, off = _take(buf, off, 4)
            raw, off = _take(buf, off, _U32.unpack(raw)[0])
            key = raw.decode("utf-8")
            d[key], off = _dec_value(buf, off)
        return d, off
    raise TransportError(f"unknown value tag {tag!r} at offset {off - 1}")


# --------------------------------------------------------------------------
# framing
# --------------------------------------------------------------------------

def encode_frame(kind: str, obj) -> bytes:
    """One self-delimiting frame: header (magic, version, body length) +
    body (kind + tagged value) + CRC-32 of the body."""
    kraw = kind.encode("utf-8")
    if len(kraw) > 255:
        raise ValueError(f"frame kind too long: {kind!r}")
    parts: List[bytes] = [_U8.pack(len(kraw)), kraw]
    _enc_value(obj, parts)
    body = b"".join(parts)
    return (_HEADER.pack(MAGIC, WIRE_VERSION, len(body)) + body
            + _CRC.pack(zlib.crc32(body) & 0xFFFFFFFF))


def decode_frame(buf: bytes) -> Tuple[str, object]:
    """Inverse of ``encode_frame``; raises ``TransportError`` on any
    truncation, corruption, version skew, or trailing garbage."""
    if len(buf) < _HEADER.size + _CRC.size:
        raise TransportError(f"truncated frame: {len(buf)} bytes")
    magic, version, body_len = _HEADER.unpack_from(buf, 0)
    if magic != MAGIC:
        raise TransportError(f"bad frame magic {magic!r}")
    if version != WIRE_VERSION:
        raise TransportError(
            f"wire version {version} != {WIRE_VERSION} (no negotiation: "
            f"both ends must run the same codec)")
    if body_len > MAX_FRAME_BODY:
        raise TransportError(f"frame body length {body_len} exceeds cap")
    if len(buf) != _HEADER.size + body_len + _CRC.size:
        raise TransportError(
            f"frame length mismatch: header says {body_len} body bytes, "
            f"buffer has {len(buf) - _HEADER.size - _CRC.size}")
    body = buf[_HEADER.size:_HEADER.size + body_len]
    (crc,) = _CRC.unpack_from(buf, _HEADER.size + body_len)
    if crc != (zlib.crc32(body) & 0xFFFFFFFF):
        raise TransportError("frame CRC mismatch (corrupted body)")
    raw, off = _take(body, 0, 1)
    kraw, off = _take(body, off, _U8.unpack(raw)[0])
    obj, off = _dec_value(body, off)
    if off != len(body):
        raise TransportError(f"{len(body) - off} trailing bytes after the "
                             f"frame value")
    return kraw.decode("utf-8"), obj


# --------------------------------------------------------------------------
# request / handoff envelopes
# --------------------------------------------------------------------------

# everything except engine-local placement (``slot``); step counters ride
# along so latency accounting survives a migration
_REQ_SCALARS = ("rid", "max_new_tokens", "prefill_pos", "arrival_step",
                "first_token_step", "done_step", "preemptions",
                "prompt_hit_tokens", "migrations", "requeues",
                "arrival_time", "deadline", "admit_time",
                "first_token_time", "finish_time")


def request_to_wire(req: Request) -> dict:
    d = {k: getattr(req, k) for k in _REQ_SCALARS}
    d["prompt"] = [int(t) for t in req.prompt]
    d["output"] = [int(t) for t in req.output]
    d["state"] = req.state.value
    d["resumed"] = bool(req.resumed)
    d["handoff_after_prefill"] = bool(req.handoff_after_prefill)
    d["finish_reason"] = req.finish_reason
    return d


def request_from_wire(d: dict) -> Request:
    req = Request(rid=int(d["rid"]), prompt=list(d["prompt"]),
                  max_new_tokens=int(d["max_new_tokens"]))
    req.state = State(d["state"])
    req.output = list(d["output"])
    req.resumed = bool(d["resumed"])
    req.handoff_after_prefill = bool(d["handoff_after_prefill"])
    req.finish_reason = d["finish_reason"]
    for k in _REQ_SCALARS:
        if k != "rid":
            setattr(req, k, d[k])
    return req


def handoff_to_wire(h: Handoff) -> dict:
    return {"req": request_to_wire(h.req), "n_tokens": int(h.n_tokens),
            "payload": h.payload}


def handoff_from_wire(d: dict, req: Optional[Request] = None) -> Handoff:
    """Rebuild a ``Handoff``; pass ``req`` to keep an existing Request
    object's identity (the loopback twin tracks requests by object, only
    the payload bytes need to cross the codec)."""
    return Handoff(req=req if req is not None else
                   request_from_wire(d["req"]),
                   n_tokens=int(d["n_tokens"]), payload=d["payload"])


class LoopbackTransport:
    """Deterministic in-memory wire: every ``transfer`` is a full
    encode→decode round trip through the frame codec (the same bytes a
    socket would carry) with frame/byte accounting and zero wall-time —
    what ``ClusterConfig.wire="loopback"`` plugs into the virtual-clock
    twin (DESIGN.md §15)."""

    def __init__(self):
        self.frames = 0
        self.bytes = 0

    def transfer(self, kind: str, obj) -> Tuple[object, int]:
        frame = encode_frame(kind, obj)
        self.frames += 1
        self.bytes += len(frame)
        got_kind, got = decode_frame(frame)
        if got_kind != kind:
            raise TransportError(f"loopback kind skew: sent {kind!r}, "
                                 f"decoded {got_kind!r}")
        return got, len(frame)


# --------------------------------------------------------------------------
# socket framing (asyncio server side, blocking client side — one codec)
# --------------------------------------------------------------------------

async def read_frame_async(reader: asyncio.StreamReader
                           ) -> Tuple[str, object]:
    try:
        hdr = await reader.readexactly(_HEADER.size)
        _, _, body_len = _HEADER.unpack(hdr)
        if body_len > MAX_FRAME_BODY:
            raise TransportError(f"frame body length {body_len} exceeds cap")
        rest = await reader.readexactly(body_len + _CRC.size)
    except (asyncio.IncompleteReadError, ConnectionError, OSError) as e:
        raise ReplicaGone(f"peer closed mid-frame: {e}")
    return decode_frame(hdr + rest)


async def write_frame_async(writer: asyncio.StreamWriter, kind: str,
                            obj) -> int:
    frame = encode_frame(kind, obj)
    try:
        writer.write(frame)
        await writer.drain()
    except (ConnectionError, OSError) as e:
        raise ReplicaGone(f"peer closed mid-write: {e}")
    return len(frame)


class SocketChannel:
    """Blocking request/response client over one TCP connection, sharing
    the frame codec with the asyncio host side."""

    def __init__(self, host: str, port: int, timeout: float = 120.0):
        try:
            self.sock = socket.create_connection((host, port),
                                                 timeout=timeout)
        except OSError as e:
            raise ReplicaGone(f"connect {host}:{port} failed: {e}")
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.sent_frames = 0
        self.sent_bytes = 0

    def _recv_exact(self, n: int) -> bytes:
        chunks = []
        while n:
            try:
                chunk = self.sock.recv(min(n, 1 << 20))
            except OSError as e:
                raise ReplicaGone(f"recv failed: {e}")
            if not chunk:
                raise ReplicaGone("peer closed mid-frame")
            chunks.append(chunk)
            n -= len(chunk)
        return b"".join(chunks)

    def send(self, kind: str, obj) -> int:
        frame = encode_frame(kind, obj)
        try:
            self.sock.sendall(frame)
        except OSError as e:
            raise ReplicaGone(f"send failed: {e}")
        self.sent_frames += 1
        self.sent_bytes += len(frame)
        return len(frame)

    def recv(self) -> Tuple[str, object]:
        hdr = self._recv_exact(_HEADER.size)
        _, _, body_len = _HEADER.unpack(hdr)
        if body_len > MAX_FRAME_BODY:
            raise TransportError(f"frame body length {body_len} exceeds cap")
        rest = self._recv_exact(body_len + _CRC.size)
        return decode_frame(hdr + rest)

    def request(self, kind: str, obj) -> object:
        """One RPC: send a command frame, wait for its ``re:`` reply."""
        self.send(kind, obj)
        rkind, reply = self.recv()
        if rkind == "error":
            raise TransportError(f"host error for {kind!r}: {reply}")
        if rkind != f"re:{kind}":
            raise TransportError(f"reply kind skew: sent {kind!r}, "
                                 f"got {rkind!r}")
        return reply

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


# --------------------------------------------------------------------------
# EngineHost: one Engine served behind the command protocol
# --------------------------------------------------------------------------

class EngineHost:
    """Asyncio socket server wrapping ONE engine replica.  Commands are
    synchronous at the engine (steps are atomic); the host handles one
    frame at a time per connection, so the frontend's RPC order IS the
    engine's event order — the determinism contract the virtual-clock
    twin relies on carries over to real sockets (DESIGN.md §15).

    ``die_after`` arms a fault-injection kill switch: the process exits
    hard (``os._exit``) after N more engine steps, BEFORE replying — the
    frontend observes the death as ``ReplicaGone`` on that very RPC, the
    same way a crashed machine would present."""

    def __init__(self, engine: Engine, name: str = "host"):
        self.engine = engine
        self.name = name
        self._reqs: Dict[int, Request] = {}
        self._emitted: Dict[int, int] = {}
        self._reported_done: set = set()
        self._die_after: Optional[int] = None
        self._steps = 0

    # ---- command handlers (sync) --------------------------------------
    def handle(self, kind: str, body) -> dict:
        fn = getattr(self, f"_cmd_{kind}", None)
        if fn is None:
            raise TransportError(f"unknown command {kind!r}")
        return fn(body or {})

    def _cmd_hello(self, body) -> dict:
        eng = self.engine
        return {"name": self.name, "paged": bool(eng.paged),
                "block_size": int(eng.scfg.block_size),
                "max_batch": int(eng.scfg.max_batch),
                "max_len": int(eng.scfg.max_len)}

    def _track(self, req: Request) -> None:
        self._reqs[req.rid] = req
        self._emitted[req.rid] = len(req.output)

    def _cmd_submit(self, body) -> dict:
        req = request_from_wire(body["req"])
        self.engine.add_request(req)
        self._track(req)
        return {"ok": True}

    def _cmd_adopt(self, body) -> dict:
        req = request_from_wire(body["req"])
        ok = self.engine.adopt_request(req, int(body["n_tokens"]),
                                       body["payload"])
        if ok:
            self._track(req)
        return {"ok": bool(ok)}

    def _cmd_abort(self, body) -> dict:
        req = self._reqs.get(int(body["rid"]))
        if req is None:
            return {"ok": False}
        ok = self.engine.abort(req, body.get("reason", "cancelled"))
        self._reported_done.add(req.rid)
        return {"ok": bool(ok)}

    def _cmd_step(self, body) -> dict:
        eng = self.engine
        before = eng.stats.forward_tokens
        progressed = eng.step()
        if progressed:
            self._steps += 1
            if self._die_after is not None and self._steps >= self._die_after:
                # crash BEFORE replying: the frontend sees ReplicaGone on
                # this RPC — the real-socket twin of kill_replica()
                os._exit(17)
        emitted = {}
        finished = []
        for rid, req in self._reqs.items():
            seen = self._emitted[rid]
            if len(req.output) > seen:
                emitted[str(rid)] = [int(t) for t in req.output[seen:]]
                self._emitted[rid] = len(req.output)
            if req.state == State.DONE and rid not in self._reported_done:
                self._reported_done.add(rid)
                finished.append({"rid": rid,
                                 "finish_reason": req.finish_reason})
        handoffs = []
        for h in eng.take_handoffs():
            handoffs.append(handoff_to_wire(h))
            self._reqs.pop(h.req.rid, None)
            self._emitted.pop(h.req.rid, None)
        st = eng.stats
        return {"progressed": bool(progressed),
                "d_tokens": int(st.forward_tokens - before),
                "emitted": emitted, "finished": finished,
                "handoffs": handoffs,
                "counters": {"steps": st.steps, "forwards": st.forwards,
                             "weave_forwards": st.weave_forwards,
                             "forward_tokens": st.forward_tokens,
                             "completed": st.completed,
                             "cancelled": st.cancelled}}

    def _cmd_prefix_hits(self, body) -> dict:
        mgr = self.engine.block_mgr
        if mgr is None or not mgr.prefix_caching:
            return {"hits": 0}
        return {"hits": len(mgr.prefix.match(list(body["hashes"])))}

    def _cmd_quiesce(self, body) -> dict:
        mgr = self.engine.block_mgr
        if mgr is None:
            return {"tables": [], "leaked": []}
        leaked = [b for b in range(mgr.alloc.num_blocks) if mgr.alloc.ref[b]]
        return {"tables": sorted(mgr.tables), "leaked": leaked}

    def _cmd_die_after(self, body) -> dict:
        self._die_after = self._steps + int(body["steps"])
        return {"ok": True}

    def _cmd_shutdown(self, body) -> dict:
        return {"ok": True, "_shutdown": True}

    # ---- asyncio server ------------------------------------------------
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                try:
                    kind, body = await read_frame_async(reader)
                except ReplicaGone:
                    break
                try:
                    reply = self.handle(kind, body)
                except (TransportError, ValueError, KeyError) as e:
                    await write_frame_async(writer, "error", str(e))
                    continue
                await write_frame_async(writer, f"re:{kind}", reply)
                if reply.get("_shutdown"):
                    asyncio.get_running_loop().call_soon(
                        self._server.close)
                    break
        finally:
            try:
                writer.close()
            except OSError:
                pass

    async def serve(self, host: str = "127.0.0.1", port: int = 0,
                    on_ready=None) -> None:
        self._server = await asyncio.start_server(self._handle_conn,
                                                  host, port)
        bound = self._server.sockets[0].getsockname()
        if on_ready is not None:
            on_ready(bound[0], bound[1])
        try:
            async with self._server:
                await self._server.serve_forever()
        except asyncio.CancelledError:
            pass


# --------------------------------------------------------------------------
# RemoteEngine: the frontend-side proxy a Replica drives like an Engine
# --------------------------------------------------------------------------

class _RemoteSched:
    """Client-side mirror of the remote scheduler: ``waiting`` holds every
    request the remote engine currently owns (the frontend keeps the
    authoritative Request objects), ``finished`` the terminal ones —
    exactly the surface ``Replica``/``ClusterServer`` read."""

    def __init__(self):
        self.waiting: List[Request] = []
        self.active: List[Optional[Request]] = []
        self.finished: List[Request] = []


class _RemoteStats:
    """Counters mirrored from the host's step replies (read-only view,
    same attribute names as ``EngineStats``)."""

    def __init__(self):
        self.steps = 0
        self.forwards = 0
        self.weave_forwards = 0
        self.forward_tokens = 0
        self.completed = 0
        self.cancelled = 0

    @property
    def weave_rate(self) -> float:
        return self.weave_forwards / self.forwards if self.forwards else 0.0

    @property
    def tokens_per_forward(self) -> float:
        return self.forward_tokens / self.forwards if self.forwards else 0.0


class RemoteEngine:
    """Engine proxy over a ``SocketChannel`` — implements the subset of
    the ``Engine`` surface that ``Replica`` and ``ClusterServer`` touch
    (add_request / step / take_handoffs / adopt_request / abort / sched /
    stats / paged / obs), so a remote replica is just
    ``Replica(name, RemoteEngine(host, port))``.

    Any socket failure marks the proxy dead and raises ``ReplicaGone``;
    the cluster's failure handling requeues this replica's requests from
    the client-side mirrors (``evacuate`` — no RPC: the machine is gone,
    which is the point)."""

    block_mgr = None        # pool lives host-side; prefix hits go via RPC
    obs = None
    obs_track = "remote"

    def __init__(self, host: str, port: int, name: str = "remote",
                 timeout: float = 120.0):
        self.chan = SocketChannel(host, port, timeout=timeout)
        self.name = name
        self.dead = False
        self.sched = _RemoteSched()
        self.stats = _RemoteStats()
        self._handoffs: List[Handoff] = []
        hello = self._rpc("hello", {})
        self.paged = bool(hello["paged"])
        self.remote_name = hello["name"]
        self.block_size = int(hello["block_size"])

    def _rpc(self, kind: str, body) -> dict:
        if self.dead:
            raise ReplicaGone(f"replica {self.name!r} is dead")
        try:
            return self.chan.request(kind, body)
        except ReplicaGone:
            self.dead = True
            raise

    def _mirror(self, rid: int) -> Optional[Request]:
        for r in self.sched.waiting:
            if r.rid == rid:
                return r
        return None

    # ---- Engine surface ------------------------------------------------
    def add_request(self, req: Request) -> None:
        self._rpc("submit", {"req": request_to_wire(req)})
        self.sched.waiting.append(req)

    def adopt_request(self, req: Request, n_tokens: int, payload) -> bool:
        ok = self._rpc("adopt", {"req": request_to_wire(req),
                                 "n_tokens": int(n_tokens),
                                 "payload": payload})["ok"]
        if ok:
            req.handoff_after_prefill = False
            req.migrations += 1
            self.sched.waiting.append(req)
        return bool(ok)

    def abort(self, req: Request, reason: str = "cancelled") -> bool:
        ok = self._rpc("abort", {"rid": int(req.rid), "reason": reason})
        self.sched.waiting = [r for r in self.sched.waiting if r is not req]
        req.state = State.DONE
        req.finish_reason = reason
        return bool(ok["ok"])

    def step(self) -> bool:
        reply = self._rpc("step", {})
        for rid_s, toks in reply["emitted"].items():
            req = self._mirror(int(rid_s))
            if req is not None:
                req.output.extend(int(t) for t in toks)
                if req.state == State.WAITING:
                    req.state = State.DECODE
        for h in reply["handoffs"]:
            wire_req = request_from_wire(h["req"])
            req = self._mirror(wire_req.rid)
            if req is None:
                req = wire_req
            else:
                # the host parked it: sync generation state onto the
                # frontend's authoritative object, drop local ownership
                req.output = wire_req.output
                req.state = wire_req.state
                req.prefill_pos = wire_req.prefill_pos
                self.sched.waiting = [r for r in self.sched.waiting
                                      if r is not req]
            self._handoffs.append(Handoff(req=req,
                                          n_tokens=int(h["n_tokens"]),
                                          payload=h["payload"]))
        for fin in reply["finished"]:
            req = self._mirror(int(fin["rid"]))
            if req is not None:
                req.state = State.DONE
                req.finish_reason = fin["finish_reason"]
                self.sched.waiting = [r for r in self.sched.waiting
                                      if r is not req]
                if req.finish_reason == "stop":
                    self.sched.finished.append(req)
        c = reply["counters"]
        st = self.stats
        st.steps, st.forwards = c["steps"], c["forwards"]
        st.weave_forwards = c["weave_forwards"]
        st.forward_tokens = c["forward_tokens"]
        st.completed, st.cancelled = c["completed"], c["cancelled"]
        return bool(reply["progressed"])

    def take_handoffs(self) -> List[Handoff]:
        out, self._handoffs = self._handoffs, []
        return out

    def prefix_hit_blocks(self, hashes) -> int:
        return int(self._rpc("prefix_hits",
                             {"hashes": [int(h) for h in hashes]})["hits"])

    def install_overlap_policy(self, policy) -> None:
        # remote hosts load their plan from their own spec at launch; the
        # frontend cannot ship a live policy object over the wire
        raise ValueError("install_overlap_policy is not supported on "
                         "RemoteEngine — pass plan_path in the host spec")

    def evacuate(self) -> List[Request]:
        """Dead-replica recovery (no RPC — the peer is gone): hand every
        live mirrored request back, reset for re-admission elsewhere."""
        self.dead = True
        out = [reset_for_requeue(r) for r in self.sched.waiting
               if r.state != State.DONE]
        self.sched.waiting = []
        self._handoffs = []
        return out

    def check_quiescent(self) -> None:
        if self.dead:
            return
        rep = self._rpc("quiesce", {})
        assert not rep["tables"], (self.name, rep["tables"])
        assert not rep["leaked"], (self.name, rep["leaked"])

    def die_after(self, steps: int) -> None:
        """Arm the host's fault-injection kill switch (tests)."""
        self._rpc("die_after", {"steps": int(steps)})

    def close(self) -> None:
        if not self.dead:
            try:
                self.chan.send("shutdown", {})
            except ReplicaGone:
                pass
        self.chan.close()


# --------------------------------------------------------------------------
# worker process entry (multi-process launch mode)
# --------------------------------------------------------------------------

DEFAULT_SPEC = {
    "model": {"name": "tiny", "family": "dense", "num_layers": 2,
              "d_model": 64, "num_heads": 4, "num_kv_heads": 2,
              "head_dim": 16, "d_ff": 128, "vocab_size": 128,
              "dtype": "float32"},
    "parallel": {"tokenweave": True, "comm_mode": "fused", "remat": False,
                 "split_unit": 16, "tokenweave_min_tokens": 32},
    "scheduler": {"max_batch": 4, "chunk_tokens": 48, "max_len": 96,
                  "prefill_bucket": 16, "paged": True, "block_size": 8},
    "seed": 0,
}


def build_engine_from_spec(spec: Optional[dict] = None) -> Engine:
    """Build a single-host engine from a JSON-able spec (section-wise
    merged over ``DEFAULT_SPEC``) — the worker-process twin of the test
    fixtures' tiny engine, shared by ``__main__`` here and the HTTP API
    server (runtime/http_api.py)."""
    import jax
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models.build import build_model
    from repro.runtime.scheduler import SchedulerConfig

    spec = spec or {}
    merged = {sec: {**DEFAULT_SPEC[sec], **spec.get(sec, {})}
              for sec in ("model", "parallel", "scheduler")}
    cfg = ModelConfig(**merged["model"])
    pcfg = ParallelConfig(**merged["parallel"])
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    return Engine(api, mesh, params, SchedulerConfig(**merged["scheduler"]),
                  seed=int(spec.get("seed", DEFAULT_SPEC["seed"])))


def main(argv: Optional[List[str]] = None) -> None:
    """``python -m repro.runtime.transport --port 0 [--spec JSON]`` —
    host one engine replica on a socket.  Prints ``LISTENING <host>
    <port>`` once bound (the launch harness parses it)."""
    import argparse
    import json

    p = argparse.ArgumentParser(description=main.__doc__)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0)
    p.add_argument("--name", default="host")
    p.add_argument("--spec", default="{}",
                   help="JSON engine spec merged over DEFAULT_SPEC")
    args = p.parse_args(argv)

    engine = build_engine_from_spec(json.loads(args.spec))
    host = EngineHost(engine, name=args.name)

    def ready(h, prt):
        print(f"LISTENING {h} {prt}", flush=True)

    asyncio.run(host.serve(args.host, args.port, on_ready=ready))


if __name__ == "__main__":
    main()
