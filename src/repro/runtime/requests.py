"""Request lifecycle + synthetic workload traces (fixed-length and
ShareGPT-like mixed-length conversations), plus the arrival processes
that drive online serving (DESIGN.md §10) and the shared-prefix group
traces the cluster router benchmarks use (DESIGN.md §11).

Trace generators never touch the global ``random`` module: they take an
explicit ``seed`` (int) or an already-constructed ``random.Random``
instance, so benchmark and test workloads are reproducible and callers can
thread one RNG through several generators without seed collisions.
"""
from __future__ import annotations

import dataclasses
import enum
import random
from typing import List, Optional, Union

Seed = Union[int, random.Random]


def _rng(seed: Seed) -> random.Random:
    return seed if isinstance(seed, random.Random) else random.Random(seed)


class State(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    state: State = State.WAITING
    slot: Optional[int] = None
    prefill_pos: int = 0              # context tokens already in cache
    output: List[int] = dataclasses.field(default_factory=list)
    arrival_step: int = 0
    first_token_step: Optional[int] = None
    done_step: Optional[int] = None
    # --- paged-cache / preemption bookkeeping ---
    resumed: bool = False             # re-prefilling after preemption
    preemptions: int = 0
    prompt_hit_tokens: int = 0        # prefix-cache hit at last admission
    # --- disaggregated serving (runtime/cluster.py, DESIGN.md §11) ---
    # park the request for KV handoff once its prefill completes (set by
    # the cluster when routing to a prefill-role replica; cleared at
    # adoption so a preemption on the decode replica re-prefills locally
    # instead of re-migrating)
    handoff_after_prefill: bool = False
    migrations: int = 0               # completed prefill->decode handoffs
    # re-admissions after a replica death (runtime/cluster.py failure
    # handling, DESIGN.md §15) — counted separately from ``preemptions``
    # because the trigger is a machine fault, not pool pressure
    requeues: int = 0
    # --- online serving (runtime/server.py, DESIGN.md §10) ---
    # all times are VIRTUAL (deterministic server clock), not wall clock
    arrival_time: float = 0.0         # when the request enters the system
    deadline: Optional[float] = None  # absolute e2e SLO deadline (None=none)
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    finish_reason: str = ""           # "" | "stop" | "cancelled" | "expired"

    @property
    def context_tokens(self) -> List[int]:
        """Tokens that must be in the cache before the next decode step.
        After a recompute-preemption the generated tokens are part of the
        context; the last output token is the pending (not yet inserted)
        decode input, so it is excluded."""
        if self.resumed and self.output:
            return self.prompt + self.output[:-1]
        return self.prompt

    @property
    def length(self) -> int:
        """Context length + pending sampled token (decode write position
        is ``length - 1``)."""
        if self.state == State.DECODE:
            return len(self.prompt) + len(self.output)
        return self.prefill_pos + len(self.output)

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= len(self.context_tokens)

    # --- SLO metrics (virtual time; populated by runtime/server.py) ---
    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token AFTER the first (None until finished or
        when only one token was produced)."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        if len(self.output) <= 1:
            return None
        return ((self.finish_time - self.first_token_time)
                / (len(self.output) - 1))

    @property
    def e2e_latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    @property
    def slo_ok(self) -> bool:
        """Completed (neither cancelled nor expired) within the deadline;
        a request without a deadline only needs to complete."""
        if self.finish_reason != "stop":
            return False
        if self.deadline is None:
            return True
        return self.finish_time is not None and \
            self.finish_time <= self.deadline


def reset_for_requeue(req: Request) -> Request:
    """Return a request to WAITING for re-admission on another replica
    after its owner died (runtime/cluster.py + runtime/transport.py,
    DESIGN.md §15).  Same recompute semantics as scheduler preemption:
    generated tokens fold into the context via ``resumed`` and prefill
    restarts from zero, so with greedy sampling the recovered output is
    token-identical to a never-failed run."""
    req.state = State.WAITING
    req.slot = None
    req.prefill_pos = 0
    req.prompt_hit_tokens = 0
    req.resumed = bool(req.output)
    req.requeues += 1
    return req


def fixed_trace(n_requests: int, input_len: int, output_len: int,
                vocab: int, seed: Seed = 0) -> List[Request]:
    rng = _rng(seed)
    return [Request(rid=i,
                    prompt=[rng.randrange(vocab) for _ in range(input_len)],
                    max_new_tokens=output_len)
            for i in range(n_requests)]


def repetitive_trace(n_requests: int, motif_len: int, repeats: int,
                     output_len: int, vocab: int,
                     seed: Seed = 0) -> List[Request]:
    """Prompts built by repeating a per-request random motif — the
    prompt-lookup-friendly structure (code, templated text) where n-gram
    drafting earns its acceptance rate."""
    rng = _rng(seed)
    reqs = []
    for i in range(n_requests):
        motif = [rng.randrange(vocab) for _ in range(motif_len)]
        reqs.append(Request(rid=i, prompt=motif * repeats,
                            max_new_tokens=output_len))
    return reqs


# --------------------------------------------------------------------------
# arrival processes (online serving, runtime/server.py / DESIGN.md §10):
# stamp ``Request.arrival_time`` on an existing trace.  All are driven by an
# explicit seed/Random, so a trace + arrival process is fully reproducible.
# --------------------------------------------------------------------------

def replay_arrivals(reqs: List[Request],
                    times: List[float]) -> List[Request]:
    """Replay recorded arrival times (e.g. from a production trace dump).
    Requests are re-ordered by arrival time (stable for ties)."""
    if len(times) != len(reqs):
        raise ValueError(f"{len(times)} arrival times for {len(reqs)} "
                         f"requests")
    for r, t in zip(reqs, times):
        r.arrival_time = float(t)
    reqs.sort(key=lambda r: (r.arrival_time, r.rid))
    return reqs


def poisson_arrivals(reqs: List[Request], rate: float,
                     seed: Seed = 0, start: float = 0.0) -> List[Request]:
    """Poisson process: i.i.d. exponential inter-arrival gaps with mean
    ``1/rate`` (arrivals per virtual-time unit)."""
    rng = _rng(seed)
    t = start
    times = []
    for _ in reqs:
        t += rng.expovariate(rate)
        times.append(t)
    return replay_arrivals(reqs, times)


def bursty_arrivals(reqs: List[Request], rate: float, burst: int,
                    off_time: float, seed: Seed = 0,
                    start: float = 0.0) -> List[Request]:
    """On-off (bursty) process: bursts of ``burst`` requests arriving at
    ``rate`` (Poisson within the burst) separated by idle gaps of mean
    ``off_time`` — the flash-crowd pattern that stresses admission and
    makes per-iteration token counts (and thus the weave rate) swing."""
    rng = _rng(seed)
    t = start
    times = []
    for i in range(len(reqs)):
        if i and i % max(burst, 1) == 0:
            t += rng.expovariate(1.0 / off_time) if off_time > 0 else 0.0
        t += rng.expovariate(rate)
        times.append(t)
    return replay_arrivals(reqs, times)


def grouped_prefix_trace(n_groups: int, per_group: int, prefix_len: int,
                         tail_len: int, output_len: int, vocab: int,
                         seed: Seed = 0) -> List[Request]:
    """Groups of requests sharing a long common prompt prefix (system
    prompt / few-shot header) with private tails — the workload where
    prefix-affinity routing (runtime/cluster.py, DESIGN.md §11) keeps a
    group's traffic on the replica whose prefix cache already holds its
    blocks.  Requests are interleaved round-robin across groups so a
    position-based router would scatter every group over the fleet."""
    rng = _rng(seed)
    prefixes = [[rng.randrange(vocab) for _ in range(prefix_len)]
                for _ in range(n_groups)]
    reqs = []
    for i in range(per_group):
        for g in range(n_groups):
            tail = [rng.randrange(vocab) for _ in range(tail_len)]
            reqs.append(Request(rid=i * n_groups + g,
                                prompt=prefixes[g] + tail,
                                max_new_tokens=output_len))
    return reqs


def sharegpt_like_trace(n_requests: int, vocab: int, seed: Seed = 0,
                        mean_in: int = 161, mean_out: int = 338,
                        max_in: int = 1024, max_out: int = 1024
                        ) -> List[Request]:
    """Log-normal-ish length mix matching the ShareGPT summary stats the
    serving literature reports (mean input ~161, mean output ~338)."""
    rng = _rng(seed)
    reqs = []
    for i in range(n_requests):
        ilen = min(max_in, max(1, int(rng.lognormvariate(4.4, 1.0))))
        olen = min(max_out, max(1, int(rng.lognormvariate(5.2, 0.9))))
        reqs.append(Request(
            rid=i, prompt=[rng.randrange(vocab) for _ in range(ilen)],
            max_new_tokens=olen))
    return reqs
