"""Request lifecycle + synthetic workload traces (fixed-length and
ShareGPT-like mixed-length conversations).

Trace generators never touch the global ``random`` module: they take an
explicit ``seed`` (int) or an already-constructed ``random.Random``
instance, so benchmark and test workloads are reproducible and callers can
thread one RNG through several generators without seed collisions.
"""
from __future__ import annotations

import dataclasses
import enum
import random
from typing import List, Optional, Union

Seed = Union[int, random.Random]


def _rng(seed: Seed) -> random.Random:
    return seed if isinstance(seed, random.Random) else random.Random(seed)


class State(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    state: State = State.WAITING
    slot: Optional[int] = None
    prefill_pos: int = 0              # context tokens already in cache
    output: List[int] = dataclasses.field(default_factory=list)
    arrival_step: int = 0
    first_token_step: Optional[int] = None
    done_step: Optional[int] = None
    # --- paged-cache / preemption bookkeeping ---
    resumed: bool = False             # re-prefilling after preemption
    preemptions: int = 0
    prompt_hit_tokens: int = 0        # prefix-cache hit at last admission

    @property
    def context_tokens(self) -> List[int]:
        """Tokens that must be in the cache before the next decode step.
        After a recompute-preemption the generated tokens are part of the
        context; the last output token is the pending (not yet inserted)
        decode input, so it is excluded."""
        if self.resumed and self.output:
            return self.prompt + self.output[:-1]
        return self.prompt

    @property
    def length(self) -> int:
        """Context length + pending sampled token (decode write position
        is ``length - 1``)."""
        if self.state == State.DECODE:
            return len(self.prompt) + len(self.output)
        return self.prefill_pos + len(self.output)

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= len(self.context_tokens)


def fixed_trace(n_requests: int, input_len: int, output_len: int,
                vocab: int, seed: Seed = 0) -> List[Request]:
    rng = _rng(seed)
    return [Request(rid=i,
                    prompt=[rng.randrange(vocab) for _ in range(input_len)],
                    max_new_tokens=output_len)
            for i in range(n_requests)]


def repetitive_trace(n_requests: int, motif_len: int, repeats: int,
                     output_len: int, vocab: int,
                     seed: Seed = 0) -> List[Request]:
    """Prompts built by repeating a per-request random motif — the
    prompt-lookup-friendly structure (code, templated text) where n-gram
    drafting earns its acceptance rate."""
    rng = _rng(seed)
    reqs = []
    for i in range(n_requests):
        motif = [rng.randrange(vocab) for _ in range(motif_len)]
        reqs.append(Request(rid=i, prompt=motif * repeats,
                            max_new_tokens=output_len))
    return reqs


def sharegpt_like_trace(n_requests: int, vocab: int, seed: Seed = 0,
                        mean_in: int = 161, mean_out: int = 338,
                        max_in: int = 1024, max_out: int = 1024
                        ) -> List[Request]:
    """Log-normal-ish length mix matching the ShareGPT summary stats the
    serving literature reports (mean input ~161, mean output ~338)."""
    rng = _rng(seed)
    reqs = []
    for i in range(n_requests):
        ilen = min(max_in, max(1, int(rng.lognormvariate(4.4, 1.0))))
        olen = min(max_out, max(1, int(rng.lognormvariate(5.2, 0.9))))
        reqs.append(Request(
            rid=i, prompt=[rng.randrange(vocab) for _ in range(ilen)],
            max_new_tokens=olen))
    return reqs
