"""Hash-chained prefix cache over paged KV blocks (vLLM-style;
DESIGN.md §7).  The same chain hashes double as the cluster layer's
prefix-affinity routing keys (DESIGN.md §11).

Only FULL blocks participate: a block's key is the chain hash of every
token in it plus the previous block's hash, so a hit on block *i* implies
the entire token prefix ``[0, (i+1) * block_size)`` is identical.  Partial
tail blocks are never shared — each request writes its tail into a private
block — which keeps copy-on-write a defensive invariant rather than a hot
path (see ``BlockManager.ensure_writable``).

The cache stores only the hash -> physical-block mapping plus the reverse
map; residency/eviction order is owned by the ``BlockAllocator`` (blocks
whose refcount drops to zero stay in the allocator's LRU "cached free"
list and remain hittable until evicted for a fresh allocation).
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence

import numpy as np

# hash of the empty prefix (chain seed); any fixed value works
_SEED = 0x9E3779B97F4A7C15


def _hash_block(prev: int, tokens: Sequence[int]) -> int:
    """128-bit keyed chain hash.  A non-cryptographic hash here would let
    a colliding block silently serve another request's KV (the flaw class
    behind vLLM's CVE-2025-25183); blake2b makes accidental or crafted
    collisions a non-issue and there is no token-comparison on hit."""
    h = hashlib.blake2b(digest_size=16)
    h.update(prev.to_bytes(16, "little"))
    h.update(np.asarray(tokens, np.int64).tobytes())
    return int.from_bytes(h.digest(), "little")


def chain_hashes(tokens: Sequence[int], block_size: int, *,
                 start_block: int = 0,
                 prev: Optional[int] = None) -> List[int]:
    """Chain hash per FULL block of ``tokens`` (len(tokens)//block_size).

    ``start_block``/``prev`` resume an existing chain (hashes for blocks
    [start_block, n_full) given block start_block-1's hash), letting
    callers amortize to O(1) per new block instead of re-hashing the
    whole context."""
    hashes: List[int] = []
    prev = _SEED if prev is None else prev
    for lo in range(start_block * block_size,
                    (len(tokens) // block_size) * block_size, block_size):
        prev = _hash_block(prev, tokens[lo:lo + block_size])
        hashes.append(prev)
    return hashes


class PrefixCache:
    """hash -> physical block id (full blocks only)."""

    def __init__(self):
        self.table: Dict[int, int] = {}
        self.block_hash: Dict[int, int] = {}   # reverse map

    def lookup(self, h: int) -> Optional[int]:
        return self.table.get(h)

    def match(self, hashes: Sequence[int]) -> List[int]:
        """Longest-prefix match: physical blocks for leading hash hits."""
        blocks = []
        for h in hashes:
            b = self.table.get(h)
            if b is None:
                break
            blocks.append(b)
        return blocks

    def register(self, h: int, block: int) -> bool:
        """Map ``h`` to ``block`` unless the hash is already cached (first
        writer wins — the existing block keeps serving hits)."""
        if h in self.table or block in self.block_hash:
            return False
        self.table[h] = block
        self.block_hash[block] = h
        return True

    def drop_block(self, block: int) -> None:
        """Forget a block (its storage is being reused for new content)."""
        h = self.block_hash.pop(block, None)
        if h is not None:
            del self.table[h]

    def is_cached(self, block: int) -> bool:
        return block in self.block_hash

    def __len__(self) -> int:
        return len(self.table)
