"""Speculative decoding: pluggable draft proposers + the device-side
multi-token verification math (DESIGN.md §8).

Plain decode carries one token per sequence per step — exactly the regime
where TokenWeave's overlap never activates (`tokenweave_min_tokens`).
Speculative decoding turns each decode iteration into a gamma+1-token
verify batch per sequence: a cheap *draft* proposes gamma tokens, the
target model scores the whole window in ONE forward (multi-token decode
attention over the KV cache), and standard rejection sampling commits the
longest correct prefix plus one corrected/bonus token.  Decode iterations
now carry ``B * (gamma+1)`` tokens, pushing the latency-critical path over
the weave threshold — "decode looks like small prefill".

Correctness contract (leniency-free):

* greedy (temperature == 0): a draft token is accepted iff it equals the
  target argmax at its position, and the emitted correction/bonus IS the
  target argmax — the committed stream is token-identical to plain greedy
  decoding by construction.
* stochastic: every draft token is treated as a *deterministic* proposal
  (q = a point mass at the drafted token).  Accept with probability
  p_target(d); on rejection sample from the renormalized leave-one-out
  distribution p(x)/(1-p(d)), x != d.  For ANY draft process this yields
  P(committed token = x) = p_target(x) exactly — the draft choice affects
  only the acceptance rate, never the output distribution — so n-gram
  drafts (no q available) and model drafts share one verification rule.

Both verification rules run inside ``jax.shard_map`` on vocab-SHARDED
logits: argmax/gather/residual-sampling compose pmax/psum/Gumbel-max
(runtime/sampler.py) and never materialize the full vocabulary.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.layers import embedding as E
from repro.layers.embedding import sharded_argmax
from repro.runtime.sampler import filtered_logits, gumbel_argmax

# ==========================================================================
# device side: rejection-sampling verification over vocab-sharded logits
# ==========================================================================


def _leading_accepts(accept) -> jnp.ndarray:
    """(B, gamma) bool -> (B,) length of the leading all-True run."""
    return jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)


def verify_greedy(local_logits, draft, *, vocab_size: int,
                  tp_axis: str = "model"):
    """Greedy verification. local_logits: (B, gamma+1, V_loc) target logits
    for the verify window; draft: (B, gamma) int32, -1 = no proposal.

    Returns (n_acc (B,), emit (B,)): the number of accepted draft tokens
    and the one extra committed token — the target argmax at the first
    mismatch (correction) or at the window end (bonus).  Identical to what
    plain greedy decode would emit, position for position.
    """
    gamma = draft.shape[1]
    tgt = sharded_argmax(local_logits, vocab_size=vocab_size,
                         tp_axis=tp_axis)                     # (B, gamma+1)
    match = (draft == tgt[:, :gamma]) & (draft >= 0)
    n_acc = _leading_accepts(match)
    emit = jnp.take_along_axis(tgt, n_acc[:, None], axis=1)[:, 0]
    return n_acc, emit


def verify_sample(local_logits, draft, key, *, vocab_size: int,
                  tp_axis: str = "model", temperature: float = 1.0,
                  top_k: int = 0, top_p: float = 1.0):
    """Stochastic rejection-sampling verification (deterministic-proposal
    rule, see module docstring).  The target distribution is the
    temperature/top-k/top-p-filtered softmax; ``key`` must be identical on
    every shard (acceptance decisions are replicated; only the Gumbel noise
    is shard-folded).  Returns (n_acc (B,), emit (B,))."""
    b, s, v_loc = local_logits.shape
    gamma = draft.shape[1]
    lg = filtered_logits(local_logits, vocab_size=vocab_size,
                         tp_axis=tp_axis, temperature=temperature,
                         top_k=top_k, top_p=top_p)            # (B, S, V_loc)

    # p_target(draft_i | window prefix): stable sharded softmax gather
    m = lax.pmax(jnp.max(lg, axis=-1), tp_axis)               # (B, S)
    z = lax.psum(jnp.sum(jnp.exp(lg - m[..., None]), axis=-1), tp_axis)
    lo = lax.axis_index(tp_axis) * v_loc
    d_loc = draft - lo                                        # (B, gamma)
    in_range = (d_loc >= 0) & (d_loc < v_loc) & (draft >= 0)
    picked = jnp.take_along_axis(
        lg[:, :gamma], jnp.clip(d_loc, 0, v_loc - 1)[..., None],
        axis=-1)[..., 0]
    p_draft = lax.psum(
        jnp.where(in_range,
                  jnp.exp(picked - m[:, :gamma]) / z[:, :gamma], 0.0),
        tp_axis)                                              # (B, gamma)

    k_u, k_res, k_bonus = jax.random.split(key, 3)
    u = jax.random.uniform(k_u, (b, gamma))                   # replicated
    accept = (u < p_draft) & (draft >= 0)
    n_acc = _leading_accepts(accept)

    # residual samples: at each window position, the drafted token's mass is
    # removed and the rest renormalized — Gumbel-max over the masked logits
    col = lo + jnp.arange(v_loc)
    drafted_here = (col[None, None, :] == draft[..., None]) & \
        (draft >= 0)[..., None]
    residual_lg = jnp.where(drafted_here, -jnp.inf, lg[:, :gamma])
    res = gumbel_argmax(residual_lg, k_res, vocab_size=vocab_size,
                        tp_axis=tp_axis)                      # (B, gamma)
    bonus = gumbel_argmax(lg[:, gamma:], k_bonus, vocab_size=vocab_size,
                          tp_axis=tp_axis)[:, 0]              # (B,)
    cand = jnp.concatenate([res, bonus[:, None]], axis=1)     # (B, gamma+1)
    emit = jnp.take_along_axis(cand, n_acc[:, None], axis=1)[:, 0]
    return n_acc, emit


def verify_tokens(local_logits, draft, key, *, vocab_size: int,
                  tp_axis: str = "model", temperature: float = 0.0,
                  top_k: int = 0, top_p: float = 1.0):
    """Dispatch greedy vs stochastic verification (trace-time branch)."""
    if temperature <= 0.0:
        return verify_greedy(local_logits, draft, vocab_size=vocab_size,
                             tp_axis=tp_axis)
    return verify_sample(local_logits, draft, key, vocab_size=vocab_size,
                         tp_axis=tp_axis, temperature=temperature,
                         top_k=top_k, top_p=top_p)


# ==========================================================================
# host side: draft proposers
# ==========================================================================


class DraftProposer:
    """Interface: ``propose(contexts)`` maps each request's full token
    context (prompt + generated so far, INCLUDING the pending decode input)
    to at most ``gamma`` proposed continuation tokens."""

    gamma: int

    def propose(self, contexts: Sequence[Sequence[int]]) -> List[List[int]]:
        raise NotImplementedError


class NgramDraft(DraftProposer):
    """Prompt-lookup / n-gram drafting: match the context's trailing n-gram
    against earlier context (most recent occurrence wins, longer n-grams
    tried first) and propose the tokens that followed it.  Zero model cost;
    acceptance comes from the repetitiveness real text actually has
    (code, multi-turn chat, retrieved documents)."""

    def __init__(self, gamma: int, n: int = 3, min_n: int = 1):
        if gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {gamma}")
        self.gamma = gamma
        self.n = n
        self.min_n = min_n

    def _propose_one(self, ctx: Sequence[int]) -> List[int]:
        ctx = list(ctx)
        for n in range(min(self.n, len(ctx) - 1), self.min_n - 1, -1):
            pat = ctx[-n:]
            # most recent earlier occurrence; the range start excludes the
            # trailing self-match, so the continuation is never empty
            for i in range(len(ctx) - n - 1, -1, -1):
                if ctx[i:i + n] == pat:
                    return ctx[i + n:i + n + self.gamma]
        return []

    def propose(self, contexts):
        return [self._propose_one(c) for c in contexts]


class ModelDraft(DraftProposer):
    """Small-draft-model proposer via ``models/build.ModelApi``: gamma
    greedy rollout steps, each a full-context forward of the draft model.

    Documented simplification: the draft keeps NO KV cache — every proposal
    token re-runs the whole context (lengths bucketed to bound
    recompilation).  That is O(gamma * ctx) per engine step, fine for the
    tiny CPU models this repo serves and it keeps the draft stateless
    (nothing to roll back on rejection); a production draft would run its
    own paged decode loop.  Draft greediness never affects output
    correctness — only the acceptance rate (see module docstring).
    """

    def __init__(self, api, mesh, params, gamma: int, *,
                 len_bucket: int = 64, max_batch: int = 8):
        if gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {gamma}")
        self.api = api
        self.mesh = mesh
        self.params = params
        self.gamma = gamma
        self.len_bucket = len_bucket
        self.max_batch = max_batch
        self._jit_cache: Dict[Tuple[int, int], object] = {}

    def _step_fn(self, b: int, s: int):
        key = (b, s)
        if key in self._jit_cache:
            return self._jit_cache[key]
        from jax.sharding import PartitionSpec as P
        api = self.api

        def fn(params, tokens, positions, last_idx):
            h, _, _ = api.mod.forward(params, tokens, cfg=api.cfg,
                                      pcfg=api.pcfg, positions=positions,
                                      return_kv=False)
            h_last = jnp.take_along_axis(h, last_idx[:, None, None], axis=1)
            lg = E.lm_head_logits(params["embedding"], h_last)
            return sharded_argmax(lg, vocab_size=api.cfg.vocab_size,
                                  tp_axis=api.pcfg.tp_axis)[:, 0]

        sm = jax.shard_map(fn, mesh=self.mesh,
                           in_specs=(api.specs(), P(), P(), P()),
                           out_specs=P(), check_vma=False)
        jfn = jax.jit(sm)
        self._jit_cache[key] = jfn
        return jfn

    def propose(self, contexts):
        ctxs = [list(c) for c in contexts]
        props: List[List[int]] = [[] for _ in ctxs]
        lb = self.len_bucket
        # batch padded to a max_batch multiple: bounds recompilation while
        # still serving engines whose decode batch exceeds the default
        b = self.max_batch * (-(-max(len(ctxs), 1) // self.max_batch))
        for _ in range(self.gamma):
            lens = [len(c) + len(p) for c, p in zip(ctxs, props)]
            s = max(lb, ((max(lens) + lb - 1) // lb) * lb)
            tokens = np.zeros((b, s), np.int32)
            positions = np.full((b, s), -1, np.int32)
            last_idx = np.zeros(b, np.int32)
            for i, (c, p) in enumerate(zip(ctxs, props)):
                row = c + p
                tokens[i, :len(row)] = row
                positions[i, :len(row)] = np.arange(len(row))
                last_idx[i] = len(row) - 1
            fn = self._step_fn(b, s)
            nxt = np.asarray(fn(self.params, jnp.asarray(tokens),
                                jnp.asarray(positions),
                                jnp.asarray(last_idx)))
            for i in range(len(ctxs)):
                props[i].append(int(nxt[i]))
        return props


def make_draft(kind: str, gamma: int, *, ngram: int = 3) -> DraftProposer:
    """Engine-default draft factory (model drafts are built by the caller,
    who owns the draft params)."""
    if kind == "ngram":
        return NgramDraft(gamma, n=ngram)
    raise ValueError(f"unknown draft kind {kind!r} "
                     "(pass a ModelDraft instance for model drafting)")


# ==========================================================================
# stats
# ==========================================================================


@dataclasses.dataclass
class SpecStats:
    verify_steps: int = 0        # engine iterations that ran a verify batch
    draft_proposed: int = 0      # draft tokens scored by the target model
    draft_accepted: int = 0      # draft tokens committed
    emitted: int = 0             # all committed tokens (accepted + 1/req)

    @property
    def acceptance_rate(self) -> float:
        return (self.draft_accepted / self.draft_proposed
                if self.draft_proposed else 0.0)

    @property
    def tokens_per_step(self) -> float:
        """Mean committed tokens per verified sequence per engine step
        (plain decode == 1.0 by definition)."""
        seqs = self.emitted - self.draft_accepted   # one bonus/correction each
        return self.emitted / seqs if seqs else 0.0
