"""Online serving frontend (DESIGN.md §10): continuous batching with
arrival-aware admission on top of the offline ``Engine``.

The offline engine drains a queue that exists in full before ``run()``;
live serving has none of that — requests ARRIVE, stream their tokens out,
get cancelled, and carry deadlines.  ``OnlineServer`` adds exactly that
layer while keeping every engine iteration bit-identical to the offline
path, so the paper-level guarantee transfers: on the same trace, online
greedy output is token-identical to offline greedy output (pinned by the
`serve/online` benchmark and tests/test_server.py).

Determinism: time is VIRTUAL.  The clock advances by a configurable cost
per engine step (``StepCost``: base + per-token), requests are admitted
when ``arrival_time <= clock``, and the clock jumps to the next arrival
when the engine goes idle.  No wall clock enters any metric, so TTFT /
TPOT / e2e percentiles and goodput (``EngineStats.latency``) are exact,
replayable counters — CI gates them like any other deterministic metric.

Lifecycle events between steps (engine steps are atomic):

* admission   — pending requests whose arrival_time has passed enter the
                engine's scheduler (policy-ordered: FCFS or EDF).
* streaming   — tokens committed by the step are pushed through the
                per-request ``on_token`` callback, stamped with the
                post-step virtual time (first token stamps TTFT).
* cancellation — ``cancel(rid, at=...)`` schedules a client disconnect;
                the engine releases the slot / paged blocks / prefix-cache
                refs via ``Engine.abort`` (mid-prefill and mid-verify
                cancels are exercised in tests/test_server.py).
* deadline    — a request past its ``deadline`` is expired (when
                ``expire_on_deadline``) or allowed to finish late; either
                way it counts against goodput, never as a server failure.

Scaling past one engine is the cluster layer's job: runtime/cluster.py
(DESIGN.md §11) runs N engines on the same virtual-clock axis behind a
router, reusing ``StepCost`` for per-replica step timing.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.runtime.engine import Engine
from repro.runtime.requests import Request, State

# on_token(request, token_id, virtual_time)
TokenCallback = Callable[[Request, int, float], None]


@dataclasses.dataclass
class StepCost:
    """Virtual duration of one engine iteration.  ``per_token`` makes the
    clock load-dependent (heavier packed iterations take longer), which is
    what shifts TTFT/TPOT and the weave rate with offered load in the
    `serve/online` figure; the default is one tick per step."""
    base: float = 1.0
    per_token: float = 0.0

    def of(self, n_forward_tokens: int) -> float:
        return self.base + self.per_token * n_forward_tokens

    @classmethod
    def from_calibration(cls, cal) -> "StepCost":
        """Measured-grounded virtual clock (DESIGN.md §13): ``base`` /
        ``per_token`` come from the dispatch-granularity linear fit of
        measured wall seconds vs real tokens in a ``CalibrationReport``
        (analysis/calibration.py) — one virtual tick per wall second."""
        def get(key):
            return cal[key] if isinstance(cal, dict) else getattr(cal, key)
        return cls(base=float(get("step_base")),
                   per_token=float(get("step_per_token")))


@dataclasses.dataclass
class ServerConfig:
    step_cost: StepCost = dataclasses.field(default_factory=StepCost)
    # abort past-deadline requests (releasing their resources) instead of
    # letting them finish late; both outcomes count against goodput
    expire_on_deadline: bool = False
    max_steps: int = 1_000_000
    # tuned overlap-plan cache to install on the engine at server startup
    # (core/policy.py, DESIGN.md §14); None keeps the engine's own policy
    plan_path: Optional[str] = None


class OnlineServer:
    """Arrival-aware serving loop over one ``Engine``.

    Usage::

        srv = OnlineServer(engine)
        for r in poisson_arrivals(trace, rate=0.5, seed=0):
            srv.submit(r, on_token=stream_fn)
        srv.cancel(rid=3, at=17.0)          # optional client disconnect
        done = srv.run()                     # completed requests
        stats = engine.stats.latency.summary()
    """

    def __init__(self, engine: Engine, cfg: Optional[ServerConfig] = None):
        self.engine = engine
        self.cfg = cfg or ServerConfig()
        if self.cfg.plan_path:
            # serving deployments ship a tuned per-site overlap plan
            # (DESIGN.md §14); installed before the first step so every
            # dispatch and the packed planner see it
            from repro.core.policy import load_policy
            engine.install_overlap_policy(load_policy(self.cfg.plan_path))
        self.clock = 0.0
        self.requests: List[Request] = []           # every submit, any fate
        self.completed: List[Request] = []
        self.aborted: List[Request] = []            # cancelled + expired
        self._pending: List[Tuple[float, int, Request]] = []  # sorted
        self._cancels: List[Tuple[float, int]] = []  # (time, rid), sorted
        self._by_rid: Dict[int, Request] = {}
        self._emitted: Dict[int, int] = {}          # rid -> tokens streamed
        self._callbacks: Dict[int, TokenCallback] = {}
        self._finished_cursor = 0   # scan sched.finished incrementally

    # ------------------------------------------------------------------
    # client API
    # ------------------------------------------------------------------
    def submit(self, req: Request,
               on_token: Optional[TokenCallback] = None) -> None:
        if req.rid in self._by_rid:
            raise ValueError(f"duplicate rid {req.rid}")
        if req.arrival_time < self.clock:
            raise ValueError(
                f"request {req.rid} arrives at {req.arrival_time} but the "
                f"clock is already at {self.clock}")
        self.requests.append(req)
        self._by_rid[req.rid] = req
        self._emitted[req.rid] = 0
        if on_token is not None:
            self._callbacks[req.rid] = on_token
        bisect.insort(self._pending, (req.arrival_time, req.rid, req))

    def cancel(self, rid: int, at: Optional[float] = None) -> None:
        """Schedule a client disconnect at virtual time ``at`` (default:
        the current clock — processed before the next step)."""
        if rid not in self._by_rid:
            raise ValueError(f"unknown rid {rid}")
        t = self.clock if at is None else at
        if t < self.clock:
            raise ValueError(f"cancel time {t} is in the past "
                             f"(clock {self.clock})")
        bisect.insort(self._cancels, (t, rid))

    # ------------------------------------------------------------------
    # event processing (between engine steps)
    # ------------------------------------------------------------------
    def _admit_arrivals(self) -> None:
        while self._pending and self._pending[0][0] <= self.clock:
            _, _, req = self._pending.pop(0)
            req.admit_time = self.clock   # entered the engine queue
            if self.engine.obs is not None:
                # stamped at the true arrival instant (<= clock), BEFORE
                # the engine's "queued" event for this rid; requests
                # cancelled pre-arrival never reach here, so their
                # lifecycle thread starts at the cancel itself
                self.engine.obs.request_event(
                    req.rid, "arrival", ts=req.arrival_time,
                    args={"deadline": req.deadline})
            self.engine.add_request(req)

    def _process_cancels(self) -> None:
        while self._cancels and self._cancels[0][0] <= self.clock:
            _, rid = self._cancels.pop(0)
            req = self._by_rid[rid]
            if req.state == State.DONE:
                continue                  # finished before the disconnect
            self._retire(req, "cancelled")

    def _expire_deadlines(self) -> None:
        if not self.cfg.expire_on_deadline:
            return    # let late requests finish; slo_ok still marks them
        for req in list(self.engine.sched.active) + self.engine.sched.waiting:
            if req is None or req.deadline is None:
                continue
            if req.state != State.DONE and self.clock >= req.deadline:
                self._retire(req, "expired")
        # not-yet-arrived requests cannot expire: deadlines are e2e SLOs
        # measured from arrival, so arrival_time < deadline by construction

    def _retire(self, req: Request, reason: str) -> None:
        if any(r is req for _, _, r in self._pending):
            # cancelled before it even arrived: never reaches the engine,
            # and never served — no latencies to record (its clock-now
            # "finish" precedes its arrival, which would poison the e2e
            # percentiles the CI gate consumes)
            self._pending = [(t, rid, r) for t, rid, r in self._pending
                             if r is not req]
            req.state = State.DONE
            req.finish_reason = reason
            if reason == "expired":
                self.engine.stats._expired.inc()
            else:
                self.engine.stats._cancelled.inc()
            if self.engine.obs is not None:
                # the engine never saw this request, so its abort-path
                # terminal event cannot fire — emit it here
                self.engine.obs.request_event(
                    req.rid, "expire" if reason == "expired" else "cancel",
                    args={"reason": reason, "pre_arrival": True})
            self.aborted.append(req)
            return
        self.engine.abort(req, reason)
        req.finish_time = self.clock
        self.aborted.append(req)
        self.engine.stats.latency.record(req)

    def _stream_new_tokens(self) -> None:
        """Push tokens committed by the last step (or, after an idle jump,
        nothing) through callbacks; stamp TTFT/finish on the way.  Only
        the active slots and requests finished SINCE the last step are
        scanned (a finished request never produces tokens again), keeping
        the per-step host work flat in trace length."""
        new_finished = self.engine.sched.finished[self._finished_cursor:]
        for req in self.engine.sched.active + new_finished:
            if req is None or req.rid not in self._emitted:
                continue
            seen = self._emitted[req.rid]
            new = req.output[seen:]
            if not new:
                continue
            if seen == 0 and req.first_token_time is None:
                req.first_token_time = self.clock
            cb = self._callbacks.get(req.rid)
            if cb is not None:
                for tok in new:
                    cb(req, tok, self.clock)
            self._emitted[req.rid] = len(req.output)

    def _collect_finished(self) -> None:
        fin = self.engine.sched.finished
        for req in fin[self._finished_cursor:]:
            if req.finish_time is None and req.rid in self._by_rid:
                req.finish_time = self.clock
                self.completed.append(req)
                self.engine.stats.latency.record(req)
        self._finished_cursor = len(fin)

    # ------------------------------------------------------------------
    # serving loop
    # ------------------------------------------------------------------
    def _next_event_time(self) -> Optional[float]:
        times = []
        if self._pending:
            times.append(self._pending[0][0])
        if self._cancels:
            times.append(self._cancels[0][0])
        return min(times) if times else None

    def pump(self, max_steps: Optional[int] = None) -> int:
        """Live-serving drain (runtime/http_api.py, DESIGN.md §15):
        process every due cancel/expiry/arrival and step the engine until
        it goes idle (or ``max_steps`` iterations), then RETURN instead of
        blocking — unlike ``run()``, new submissions may land between
        pumps, so going idle is not the end of the world.  Streaming,
        latency stamping and the unservable-request guard are identical
        to ``run()``; returns the number of engine steps taken."""
        eng = self.engine
        steps = 0
        while True:
            if eng.obs is not None:
                eng.obs.sync(self.clock)
            self._process_cancels()
            self._expire_deadlines()
            self._admit_arrivals()
            tokens_before = eng.stats.forward_tokens
            if not eng.step():
                nxt = self._next_event_time()
                if nxt is not None:
                    # a future-scheduled cancel/arrival: jump like run()
                    self.clock = max(self.clock, nxt)
                    continue
                if eng.sched.waiting:
                    rids = [r.rid for r in eng.sched.waiting]
                    raise RuntimeError(
                        f"server idle with unservable waiting request(s) "
                        f"{rids}: block pool too small for their context")
                return steps
            steps += 1
            self.clock += self.cfg.step_cost.of(
                eng.stats.forward_tokens - tokens_before)
            self._stream_new_tokens()
            self._collect_finished()
            if max_steps is not None and steps >= max_steps:
                return steps

    def run(self) -> List[Request]:
        """Serve until every submitted request reached a terminal state
        (completed, cancelled, or expired).  Returns completions in finish
        order; cancelled/expired requests are in ``self.aborted``."""
        eng = self.engine
        steps = 0
        while True:
            if eng.obs is not None:
                # the server owns the virtual clock: stamp the recorder
                # before any lifecycle event or step span of this tick
                eng.obs.sync(self.clock)
            self._process_cancels()
            self._expire_deadlines()
            self._admit_arrivals()
            tokens_before = eng.stats.forward_tokens
            progressed = eng.step()
            if progressed:
                steps += 1
                if steps > self.cfg.max_steps:
                    raise RuntimeError(
                        f"server exceeded max_steps={self.cfg.max_steps}")
                self.clock += self.cfg.step_cost.of(
                    eng.stats.forward_tokens - tokens_before)
                self._stream_new_tokens()
                self._collect_finished()
                continue
            # engine idle: jump to the next arrival/cancel, or stop
            nxt = self._next_event_time()
            if nxt is not None:
                self.clock = max(self.clock, nxt)
                continue
            if eng.sched.waiting:
                rids = [r.rid for r in eng.sched.waiting]
                raise RuntimeError(
                    f"server idle with unservable waiting request(s) "
                    f"{rids}: block pool too small for their context")
            break
        return self.completed
