"""KV-cache slot management (DESIGN.md §6): static-shape caches with
per-request slots and ring-buffer (sliding-window) insertion — the legacy
backend the paged pool (DESIGN.md §7) is the alternative to.

JAX requires static shapes, so instead of vLLM's dynamically allocated pages
we preallocate (L, B_slots, C, kvh, dh) and emulate the block-table
indirection with gathers over slot ids. Sliding-window layers allocate
C = window and wrap via modular slot arithmetic (the ring buffer IS the
window — see layers/attention.attn_decode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _stacked(cache) -> bool:
    """Stacked (L, B, ...) cache vs per-layer dict {layer_i: {...}}."""
    return "k" in cache


def insert_chunk(cache, kv_chunk, offsets, slot_ids=None):
    """Insert a prefill chunk's KV into the cache.

    stacked: cache {"k": (L,B,C,h,dh), ...}; kv_chunk (k,v,pos) with L axis.
    unrolled: cache {"layer_i": {"k": (B,C,h,dh), ...}}; kv_chunk
    {"layer_i": (k,v,pos)} without the L axis (ring-buffer windows differ
    per layer, so slots are computed per layer).
    """
    if not _stacked(cache):
        return {name: _insert_layer(cache[name], kv_chunk[name], offsets,
                                    slot_ids)
                for name in cache}
    k, v, pos = kv_chunk
    _, b_sel, s = pos.shape
    c = cache["k"].shape[2]
    if slot_ids is None:
        slot_ids = jnp.arange(b_sel)
    bidx = slot_ids[:, None]
    # segment-wise so a chunk longer than a ring window writes in order,
    # and pad entries (pos < 0, bucketing) are dropped instead of
    # clobbering live in-window slots
    for lo in range(0, s, c):
        ks, vs, ps = (k[:, :, lo:lo + c], v[:, :, lo:lo + c],
                      pos[:, :, lo:lo + c])
        seg = ps.shape[2]
        slots = (offsets[:, None] + lo + jnp.arange(seg)[None, :]) % c
        slots = jnp.where(ps[0] >= 0, slots, c)          # OOB -> dropped
        cache = {
            "k": cache["k"].at[:, bidx, slots].set(ks, mode="drop"),
            "v": cache["v"].at[:, bidx, slots].set(vs, mode="drop"),
            "pos": cache["pos"].at[:, bidx, slots].set(ps, mode="drop"),
        }
    return cache


def _insert_layer(layer, kv, offsets, slot_ids):
    k, v, pos = kv
    b_sel, s = pos.shape
    c = layer["k"].shape[1]
    if slot_ids is None:
        slot_ids = jnp.arange(b_sel)
    bidx = slot_ids[:, None]
    for lo in range(0, s, c):
        ks, vs, ps = k[:, lo:lo + c], v[:, lo:lo + c], pos[:, lo:lo + c]
        seg = ps.shape[1]
        slots = (offsets[:, None] + lo + jnp.arange(seg)[None, :]) % c
        slots = jnp.where(ps >= 0, slots, c)
        layer = {"k": layer["k"].at[bidx, slots].set(ks, mode="drop"),
                 "v": layer["v"].at[bidx, slots].set(vs, mode="drop"),
                 "pos": layer["pos"].at[bidx, slots].set(ps, mode="drop")}
    return layer


def gather_slots(cache, slot_ids):
    """View of the cache rows for the given slots (same tree structure)."""
    if not _stacked(cache):
        return jax.tree.map(lambda c: c[slot_ids], cache)
    return jax.tree.map(lambda c: c[:, slot_ids], cache)


def scatter_slots(cache, rows, slot_ids):
    """Write per-slot rows back into the full cache."""
    if not _stacked(cache):
        return jax.tree.map(lambda c, r: c.at[slot_ids].set(r), cache, rows)
    return jax.tree.map(lambda c, r: c.at[:, slot_ids].set(r), cache, rows)


def reset_slots(cache, slot_ids):
    """Invalidate slots (release finished requests): pos = -1."""
    if not _stacked(cache):
        return {name: dict(lyr, pos=lyr["pos"].at[slot_ids].set(-1))
                for name, lyr in cache.items()}
    new_p = cache["pos"].at[:, slot_ids].set(-1)
    return dict(cache, pos=new_p)
