"""Paged KV-cache subsystem (DESIGN.md §7): block pool, per-request block
tables, and the host-side block manager (allocation, refcounted prefix
sharing, LRU eviction, copy-on-write, preemption support, and the
cross-pool KV migration the cluster layer's disaggregated mode uses —
DESIGN.md §11).

JAX requires static shapes, so vLLM's paged attention is emulated the same
way the slot cache emulates contiguous caches: the pool is one preallocated
``(L, num_blocks, block_size, kvh, dh)`` array per k/v (plus an int32
``pos`` mirror whose -1 entries mark unwritten cells), and every request
carries a fixed-length block table (``max_blocks`` int32 entries, -1 =
unallocated).  Reads gather ``pool[table]`` into a rectangular
``(B, max_blocks*block_size, ...)`` view; writes scatter through
``table[pos // block_size]`` indirection with OOB-drop for masked tokens.

Host side, ``BlockManager`` composes:

* ``BlockAllocator`` — free list + refcounts + an LRU list of "cached free"
  blocks (refcount 0 but still registered in the prefix cache; they are
  evicted — hash dropped, contents recycled — only when the plain free list
  runs dry).
* ``PrefixCache`` (prefix_cache.py) — chain-hash -> block map; hits at
  admission shrink a request's prefill to its miss suffix, which is what
  the scheduler charges against ``chunk_tokens``.
* copy-on-write — any write path asks ``ensure_writable`` first; a shared
  (refcount > 1) target block is replaced by a private copy and the device
  copy is queued for the engine to apply.

Preemption policy lives in the engine (latest-arrival victim, recompute
readmission); the manager only provides alloc/free/reset primitives.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.prefix_cache import PrefixCache, chain_hashes


# ==========================================================================
# device side: pool construction + gather/scatter indirection
# ==========================================================================

def _stacked(pool) -> bool:
    """Stacked (L, nb, bs, ...) pool vs per-layer dict {layer_i: {...}}."""
    return "k" in pool


def init_paged_cache(num_blocks: int, block_size: int, cfg, tp: int,
                     pcfg=None):
    """Pool pytree. Stacked: {"k": (L, nb, bs, H, dh), "v": ..., "pos":
    (L, nb, bs)}; unrolled (non-uniform layer kinds): per-layer dicts
    without the L axis.  All layers use full-length paged storage —
    sliding windows are enforced by the attention mask, not a ring buffer
    (documented simplification, DESIGN.md §7)."""
    from repro.layers.attention import attention_layout
    from repro.models.transformer import layer_kinds, uniform_kinds

    lay = attention_layout(tp, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim)
    h_global = lay.kv_store * tp
    dt = jnp.dtype(cfg.dtype)
    scan = (pcfg is None or pcfg.scan_layers) and uniform_kinds(cfg)

    def one(lead):
        return {
            "k": jnp.zeros(lead + (num_blocks, block_size, h_global,
                                   cfg.head_dim), dt),
            "v": jnp.zeros(lead + (num_blocks, block_size, h_global,
                                   cfg.head_dim), dt),
            "pos": jnp.full(lead + (num_blocks, block_size), -1, jnp.int32),
        }

    if scan:
        return one((cfg.num_layers,))
    return {f"layer_{i}": one(()) for i in range(len(layer_kinds(cfg)))}


def paged_cache_specs(cfg, pcfg):
    """PartitionSpecs: the head axis shards over the model axis exactly like
    the slot cache; the block axis is shared across all requests so it can
    never shard over data — the paged path is the single-host serving path
    (multi-pod serving keeps legacy slots, DESIGN.md §7)."""
    from jax.sharding import PartitionSpec as P
    from repro.models.transformer import layer_kinds, uniform_kinds

    def one(lead):
        return {"k": P(*lead, None, None, "model", None),
                "v": P(*lead, None, None, "model", None),
                "pos": P(*lead, None, None)}

    if pcfg.scan_layers and uniform_kinds(cfg):
        return one((None,))
    return {f"layer_{i}": one(()) for i in range(len(layer_kinds(cfg)))}


def gather_block_rows(pool, block_tables):
    """Rectangular per-request view of the pool.

    block_tables: (B, max_blocks) int32, -1 = unallocated.
    Returns rows with the SAME tree structure as the slot cache's gathered
    rows — {"k": (L, B, max_blocks*bs, H, dh), ...} — so the model prefill
    path consumes paged and slot caches identically.
    """
    bt = jnp.maximum(block_tables, 0)
    valid = block_tables >= 0  # (B, nblk)

    def gather_layer(layer, lead_l: bool):
        if lead_l:
            nl, nb, bs = layer["pos"].shape
            k = layer["k"][:, bt]                       # (L, B, nblk, bs, H, dh)
            v = layer["v"][:, bt]
            p = layer["pos"][:, bt]                     # (L, B, nblk, bs)
            p = jnp.where(valid[None, :, :, None], p, -1)
            b, nblk = bt.shape
            return {"k": k.reshape(nl, b, nblk * bs, *k.shape[4:]),
                    "v": v.reshape(nl, b, nblk * bs, *v.shape[4:]),
                    "pos": p.reshape(nl, b, nblk * bs)}
        nb, bs = layer["pos"].shape
        k = layer["k"][bt]
        v = layer["v"][bt]
        p = jnp.where(valid[:, :, None], layer["pos"][bt], -1)
        b, nblk = bt.shape
        return {"k": k.reshape(b, nblk * bs, *k.shape[3:]),
                "v": v.reshape(b, nblk * bs, *v.shape[3:]),
                "pos": p.reshape(b, nblk * bs)}

    if _stacked(pool):
        return gather_layer(pool, lead_l=True)
    return {name: gather_layer(layer, lead_l=False)
            for name, layer in pool.items()}


def insert_chunk_paged(pool, kv_chunk, block_tables):
    """Scatter a prefill chunk's KV through the block-table indirection.

    stacked: kv_chunk = (k, v, pos) with leading L axis, pos (L, B, S);
    unrolled: {"layer_i": (k, v, pos)} with pos (B, S).  Tokens with
    pos < 0 (padding) are dropped via an OOB physical index.
    """
    if not _stacked(pool):
        return {name: _insert_layer(pool[name], kv_chunk[name], block_tables)
                for name in pool}
    k, v, pos = kv_chunk
    nb, bs = pool["pos"].shape[1:3]
    p = pos[0]                                    # (B, S) — same across L
    blk = jnp.where(p >= 0, p // bs, 0)
    phys = jnp.take_along_axis(block_tables, blk, axis=1)      # (B, S)
    phys = jnp.where((p >= 0) & (phys >= 0), phys, nb)         # OOB -> drop
    off = jnp.where(p >= 0, p % bs, 0)
    return {"k": pool["k"].at[:, phys, off].set(k, mode="drop"),
            "v": pool["v"].at[:, phys, off].set(v, mode="drop"),
            "pos": pool["pos"].at[:, phys, off].set(pos, mode="drop")}


def _insert_layer(layer, kv, block_tables):
    k, v, pos = kv                                # pos (B, S)
    nb, bs = layer["pos"].shape
    blk = jnp.where(pos >= 0, pos // bs, 0)
    phys = jnp.take_along_axis(block_tables, blk, axis=1)
    phys = jnp.where((pos >= 0) & (phys >= 0), phys, nb)
    off = jnp.where(pos >= 0, pos % bs, 0)
    return {"k": layer["k"].at[phys, off].set(k, mode="drop"),
            "v": layer["v"].at[phys, off].set(v, mode="drop"),
            "pos": layer["pos"].at[phys, off].set(pos, mode="drop")}


def reset_blocks(pool, block_ids):
    """Invalidate recycled blocks (pos = -1) so stale entries from a prior
    owner can never be attended by the next request."""
    ids = jnp.asarray(block_ids, jnp.int32)
    if not _stacked(pool):
        return {name: dict(lyr, pos=lyr["pos"].at[ids].set(-1))
                for name, lyr in pool.items()}
    return dict(pool, pos=pool["pos"].at[:, ids].set(-1))


def copy_blocks(pool, copies: Sequence[Tuple[int, int]]):
    """Apply queued copy-on-write copies [(src, dst), ...] to the pool."""
    if not copies:
        return pool
    src = jnp.asarray([s for s, _ in copies], jnp.int32)
    dst = jnp.asarray([d for _, d in copies], jnp.int32)
    if not _stacked(pool):
        return {name: jax.tree.map(lambda a: a.at[dst].set(a[src]), layer)
                for name, layer in pool.items()}
    return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), pool)


def extract_blocks(pool, block_ids: Sequence[int]):
    """Pull the (k, v, pos) payload of the given physical blocks out of the
    pool — the device half of a KV-migration export (DESIGN.md §11).  The
    payload has the pool's tree structure with the block axis shrunk to
    ``len(block_ids)``; ``pos`` rides along so the importer's blocks are
    fully initialized (unwritten cells stay -1, no reset needed)."""
    ids = jnp.asarray(block_ids, jnp.int32)
    if not _stacked(pool):
        return {name: {k: a[ids] for k, a in layer.items()}
                for name, layer in pool.items()}
    return {k: a[:, ids] for k, a in pool.items()}


def implant_blocks(pool, payload, block_ids: Sequence[int]):
    """Write an extracted payload into the pool at ``block_ids`` — the
    device half of a KV-migration import (DESIGN.md §11).  Overwrites every
    cell (k, v, AND pos) of each target block, so the importer needs no
    separate reset for them."""
    ids = jnp.asarray(block_ids, jnp.int32)
    if not _stacked(pool):
        return {name: {k: a.at[ids].set(jnp.asarray(payload[name][k]))
                       for k, a in layer.items()}
                for name, layer in pool.items()}
    return {k: a.at[:, ids].set(jnp.asarray(payload[k]))
            for k, a in pool.items()}


def select_payload(payload, idx: Sequence[int]):
    """Subset an extracted payload along its block axis (the importer only
    implants blocks it could not share from its own prefix cache)."""
    import numpy as np
    sel = np.asarray(idx, np.int32)
    if not _stacked(payload):
        return {name: {k: a[sel] for k, a in layer.items()}
                for name, layer in payload.items()}
    return {k: a[:, sel] for k, a in payload.items()}


def payload_nbytes(payload) -> int:
    """Raw KV bytes of an extracted migration payload tree (stacked or
    per-layer) — the size the wire transport prices and the per-transport
    byte histograms account (runtime/transport.py, DESIGN.md §15)."""
    if not _stacked(payload):
        return sum(int(a.nbytes) for layer in payload.values()
                   for a in layer.values())
    return sum(int(a.nbytes) for a in payload.values())


# ==========================================================================
# host side: allocator + manager
# ==========================================================================

class BlockAllocator:
    """Refcounted physical-block allocator with an LRU of evictable
    prefix-cached blocks.

    Invariants (exercised by tests/test_paging.py):
      * a block is in exactly one of {free, cached_free, referenced}
      * refcount > 0 blocks are NEVER evicted or handed out by alloc()
      * eviction only recycles cached_free blocks (refcount 0), oldest
        first, and drops their prefix-cache hash via the on_evict hook
    """

    def __init__(self, num_blocks: int, on_evict=None):
        self.num_blocks = num_blocks
        self.free: deque = deque(range(num_blocks))
        self.cached_free: "OrderedDict[int, None]" = OrderedDict()
        self.ref = [0] * num_blocks
        self.on_evict = on_evict or (lambda b: None)

    # ---- queries ---------------------------------------------------------
    def num_available(self) -> int:
        return len(self.free) + len(self.cached_free)

    def refcount(self, b: int) -> int:
        return self.ref[b]

    # ---- alloc/free ------------------------------------------------------
    def alloc(self) -> Optional[int]:
        """A fresh block for new content; returns None when exhausted.
        The caller must reset the block's pos if it came from eviction —
        alloc reports this by leaving the block's hash dropped."""
        if self.free:
            b = self.free.popleft()
        elif self.cached_free:
            b, _ = self.cached_free.popitem(last=False)   # LRU
            self.on_evict(b)      # eviction accounting lives in the hook
        else:
            return None
        assert self.ref[b] == 0
        self.ref[b] = 1
        return b

    def share(self, b: int) -> None:
        """Take a reference on a prefix-cache hit. Revives a cached_free
        block (contents intact) or adds a reader to a live one."""
        if b in self.cached_free:
            del self.cached_free[b]
        self.ref[b] += 1

    def decref(self, b: int, cached: bool) -> bool:
        """Drop a reference; returns True when the block became free.
        ``cached``: block is registered in the prefix cache, so park it in
        the LRU (still hittable) instead of the plain free list."""
        assert self.ref[b] > 0, f"double free of block {b}"
        self.ref[b] -= 1
        if self.ref[b] > 0:
            return False
        if cached:
            self.cached_free[b] = None        # lands at the MRU end
        else:
            self.free.append(b)
        return True


@dataclasses.dataclass
class PagingStats:
    hit_tokens: int = 0          # prefill tokens skipped via prefix cache
    miss_tokens: int = 0         # prefill tokens actually computed
    evictions: int = 0
    preemptions: int = 0
    cow_copies: int = 0
    registered_blocks: int = 0
    # --- disaggregated KV migration (runtime/cluster.py, DESIGN.md §11) ---
    migrations_out: int = 0      # requests exported off this manager
    migrations_in: int = 0       # requests adopted by this manager
    import_shared_blocks: int = 0  # import hits served from the prefix cache
    import_copied_blocks: int = 0  # import blocks filled by payload copy

    @property
    def hit_rate(self) -> float:
        tot = self.hit_tokens + self.miss_tokens
        return self.hit_tokens / tot if tot else 0.0


class BlockManager:
    """Per-engine paging state: block tables keyed by request id, the
    allocator, the prefix cache, and the queues of device-side fixups
    (block resets, COW copies) the engine drains each step."""

    def __init__(self, num_blocks: int, block_size: int,
                 max_blocks_per_req: int, prefix_caching: bool = True):
        self.block_size = block_size
        self.max_blocks_per_req = max_blocks_per_req
        self.prefix = PrefixCache()
        self.alloc = BlockAllocator(num_blocks,
                                    on_evict=self._on_evict)
        self.tables: Dict[int, List[int]] = {}     # rid -> physical blocks
        # rid -> (blocks hashed so far, last chain hash): registration
        # resumes the chain instead of re-hashing the whole context
        self._reg_cursor: Dict[int, Tuple[int, Optional[int]]] = {}
        self.stats = PagingStats()
        self._pending_resets: List[int] = []
        self._pending_copies: List[Tuple[int, int]] = []
        self.prefix_caching = prefix_caching

    # ---- device fixup queues --------------------------------------------
    def _on_evict(self, b: int) -> None:
        self.prefix.drop_block(b)
        self._pending_resets.append(b)
        self.stats.evictions += 1

    def take_pending_resets(self) -> List[int]:
        out, self._pending_resets = self._pending_resets, []
        return out

    def take_pending_copies(self) -> List[Tuple[int, int]]:
        out, self._pending_copies = self._pending_copies, []
        return out

    # ---- admission -------------------------------------------------------
    def blocks_needed(self, n_tokens: int) -> int:
        bs = self.block_size
        return (n_tokens + bs - 1) // bs

    def _build_table(self, hit_blocks: Sequence[int], n_total: int,
                     headroom: int
                     ) -> Optional[Tuple[List[int], List[int]]]:
        """Shared admission/adoption core: take a reference on every
        prefix-hit block, allocate private blocks up to ``n_total``, and
        require ``headroom`` spares to remain.  Returns ``(table,
        fresh_idx)`` (the table positions that were freshly allocated), or
        ``None`` with every acquired reference rolled back — ONE
        implementation so admission (``allocate_prompt``) and migration
        (``import_blocks``) can never diverge on rollback or headroom
        semantics."""
        table: List[int] = []
        fresh: List[int] = []
        for b in hit_blocks:
            self.alloc.share(b)
            table.append(b)
        ok = True
        for i in range(len(hit_blocks), n_total):
            b = self.alloc.alloc()
            if b is None:
                ok = False
                break
            table.append(b)
            fresh.append(i)
        if ok and self.alloc.num_available() < headroom:
            ok = False
        if not ok:
            for b in table:
                self.alloc.decref(b, cached=self.prefix.is_cached(b))
            return None
        return table, fresh

    def allocate_prompt(self, rid: int, context: Sequence[int], *,
                        headroom: int = 1) -> int:
        """Build the request's block table: share prefix-hit blocks, then
        allocate private blocks for the miss suffix, requiring ``headroom``
        spare blocks to remain (decode growth).  Returns hit tokens
        (always < len(context): at least one token is recomputed so the
        engine has logits to sample the first output from).  Returns -1
        and rolls back when the pool cannot cover it (caller defers or
        preempts)."""
        assert rid not in self.tables
        bs = self.block_size
        hit_blocks: List[int] = []
        if self.prefix_caching:
            hit_blocks = self.prefix.match(chain_hashes(context, bs))
        if len(hit_blocks) * bs >= len(context):   # leave >= 1 miss token
            hit_blocks = hit_blocks[:-1]
        built = self._build_table(hit_blocks, self.blocks_needed(
            len(context)), headroom)
        if built is None:
            return -1
        self.tables[rid] = built[0]
        hit = len(hit_blocks) * bs
        self.stats.hit_tokens += hit
        self.stats.miss_tokens += len(context) - hit
        return hit

    # ---- decode growth + COW --------------------------------------------
    def ensure_writable(self, rid: int, position: int) -> bool:
        """Guarantee the block holding ``position`` exists and is private.
        Grows the table (alloc) and copy-on-writes a shared target.
        Returns False when a needed allocation fails (caller preempts)."""
        table = self.tables[rid]
        idx = position // self.block_size
        assert idx <= len(table), (rid, position, len(table))
        if idx == len(table):
            if idx >= self.max_blocks_per_req:
                return False   # context at the cache ceiling; caller stops
            b = self.alloc.alloc()
            if b is None:
                return False
            table.append(b)
            return True
        b = table[idx]
        if self.alloc.refcount(b) > 1:            # shared -> copy-on-write
            nb = self.alloc.alloc()
            if nb is None:
                return False
            self._pending_copies.append((b, nb))
            self.alloc.decref(b, cached=self.prefix.is_cached(b))
            table[idx] = nb
            self.stats.cow_copies += 1
        return True

    # ---- prefix-cache registration --------------------------------------
    def register_filled(self, rid: int, context: Sequence[int],
                        n_written: int) -> None:
        """Register every full block covered by the first ``n_written``
        context tokens.  First writer wins; an already-cached hash leaves
        the request's private block unregistered."""
        if not self.prefix_caching:
            return
        table = self.tables[rid]
        bs = self.block_size
        done, prev = self._reg_cursor.get(rid, (0, None))
        n_full = n_written // bs
        if n_full <= done:
            return
        new_hashes = chain_hashes(context[:n_full * bs], bs,
                                  start_block=done, prev=prev)
        for j, h in enumerate(new_hashes):
            i = done + j
            existing = self.prefix.lookup(h)
            if existing is not None:
                continue
            if self.prefix.is_cached(table[i]):   # already holds a hash
                continue
            if self.prefix.register(h, table[i]):
                self.stats.registered_blocks += 1
        self._reg_cursor[rid] = (n_full, new_hashes[-1])

    # ---- speculative-decode rollback ------------------------------------
    def truncate(self, rid: int, n_tokens: int) -> None:
        """Shrink the request's block table to cover exactly the first
        ``n_tokens`` context positions — the KV rollback after a partially
        accepted verify window.  Blocks are append-only within a step, so
        rejected draft tokens can only live in tail blocks that were grown
        for the window: no copies, just decrefs.  Tail blocks are always
        private (prefix-cache registration covers only committed full
        blocks, and ``n_tokens`` never shrinks below the committed
        context), so freed uncached blocks queue a pos reset exactly like
        ``free_request``.  Stale cells left in the KEPT partial block are
        harmless: the next write window starts at ``n_tokens`` and always
        covers any queried position before it is attended (DESIGN.md §8).
        """
        table = self.tables[rid]
        keep = self.blocks_needed(n_tokens)
        assert keep >= 1, (rid, n_tokens)
        done, _ = self._reg_cursor.get(rid, (0, None))
        assert keep >= done, ("truncate below registered blocks",
                              rid, keep, done)
        while len(table) > keep:
            b = table.pop()
            cached = self.prefix.is_cached(b)
            freed = self.alloc.decref(b, cached=cached)
            if freed and not cached:
                self._pending_resets.append(b)

    # ---- disaggregated KV migration (DESIGN.md §11) ----------------------
    def export_blocks(self, rid: int, n_tokens: int) -> List[int]:
        """Begin a KV migration: the physical blocks covering the request's
        first ``n_tokens`` committed positions, in table order.  The table
        stays intact — the caller extracts the payload from these blocks
        (``extract_blocks``) and then releases the exporter's references
        with ``free_request``, at which point every exporter-side refcount
        this request held is back where it started (shared prefix blocks
        keep their other readers, private blocks recycle)."""
        table = self.tables[rid]
        keep = self.blocks_needed(n_tokens)
        assert keep <= len(table), (rid, n_tokens, len(table))
        self.stats.migrations_out += 1
        return list(table[:keep])

    def import_blocks(self, rid: int, context: Sequence[int],
                      n_tokens: int, *, headroom: int = 1
                      ) -> Optional[Tuple[List[int], List[int]]]:
        """Adopt a migrated request: build its block table on THIS manager.
        Full blocks whose chain hash already lives in the importer's prefix
        cache are shared (refcount++, no payload copy needed — the hash
        chain guarantees identical content); the rest are allocated
        private.  Unlike ``allocate_prompt`` a 100% full-block match is
        fine: a migrated request needs no miss token, its next input token
        was already sampled by the exporter.

        Returns ``(table, copy_idx)`` where ``copy_idx`` are the table
        positions that need a payload implant (``implant_blocks``), or
        ``None`` with every acquired reference rolled back when the pool
        cannot cover ``blocks_needed(n_tokens)`` plus ``headroom``.  The
        caller re-registers the prefix-cache entries afterwards via
        ``register_filled`` (fresh blocks become hittable on the importer,
        shared ones already are)."""
        assert rid not in self.tables, rid
        bs = self.block_size
        n_full = n_tokens // bs
        hit_blocks: List[int] = []
        if self.prefix_caching:
            hit_blocks = self.prefix.match(
                chain_hashes(context[:n_full * bs], bs))
        built = self._build_table(hit_blocks, self.blocks_needed(n_tokens),
                                  headroom)
        if built is None:
            return None
        table, copy_idx = built
        self.tables[rid] = table
        self.stats.migrations_in += 1
        self.stats.import_shared_blocks += len(hit_blocks)
        self.stats.import_copied_blocks += len(copy_idx)
        return table, copy_idx

    # ---- release ---------------------------------------------------------
    def free_request(self, rid: int) -> None:
        """Drop all references; uncached blocks are queued for a pos reset
        so their stale entries can never leak into the next owner."""
        table = self.tables.pop(rid, None)
        self._reg_cursor.pop(rid, None)
        if table is None:
            return
        for b in table:
            cached = self.prefix.is_cached(b)
            freed = self.alloc.decref(b, cached=cached)
            if freed and not cached:
                self._pending_resets.append(b)

    # ---- block-table export ---------------------------------------------
    def table_array(self, rid: int):
        """Static-shape int32 table row (-1 padded) for device use."""
        import numpy as np
        row = np.full(self.max_blocks_per_req, -1, np.int32)
        t = self.tables.get(rid, ())
        row[:len(t)] = t
        return row
