"""Continuous-batching inference engine (single-host execution; the
multi-pod serve path is exercised via launch/dryrun.py's serve_step).

Supports the transformer families (dense/moe/vlm) and ssm; hybrid and encdec
are served via direct serve-step calls (see launch/dryrun.py) — documented
in DESIGN.md §6.

Per iteration: one decode step over ALL cache slots (inactive slots are
masked via position -1) and/or one rectangular prefill chunk for a group of
admitted requests (Sarathi-style chunked prefill, lengths bucketed to bound
recompilation). TokenWeave activates inside the model whenever the batch
crosses ``tokenweave_min_tokens``.

Two KV-cache backends (SchedulerConfig.paged):

* legacy slots — fixed (L, max_batch, max_len) rows per request; slots are
  invalidated on finish so stale positions never leak into a reused slot.
* paged (runtime/paging.py) — block pool + per-request block tables with
  prefix-cache sharing, LRU eviction, copy-on-write, and recompute
  preemption (DECODE -> WAITING) when the pool runs dry.  Admission and
  chunk accounting charge only prefix-MISS tokens, so the TokenWeave
  min-token threshold sees true compute size.  Transformer families only
  (ssm state is not paged), single host (the shared pool cannot shard over
  the data axis) — DESIGN.md §7.

Speculative decoding (``SchedulerConfig.spec_gamma > 0``, runtime/spec.py):
decode iterations become gamma+1-token verify batches — a pluggable draft
proposes, ONE multi-token forward scores the window, rejection sampling
commits the longest accepted prefix + 1 token, and rejected KV is rolled
back by block-table truncation (paged) or left to the
overwrite-before-query invariant (legacy slots) — DESIGN.md §8.  Greedy
spec output is token-identical to plain greedy decoding.

Packed hybrid batching (``SchedulerConfig.packed``, DESIGN.md §6): the
two dispatches above collapse into ONE forward per iteration — prefill
segments, decode slots, and verify windows ride a single packed token
axis through ``ModelApi.packed_step``, so the TokenWeave threshold sees
the true combined iteration size (mixed iterations whose halves are each
sub-threshold now weave).  Token-identical to the two-dispatch engine
under greedy sampling; transformer families only, and sliding-window
models need the paged backend (mask-enforced windows).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.splitting import pad_to_multiple
from repro.models import transformer as TRX
from repro.models.build import ModelApi
from repro.obs.attribution import Attributor, WeaveAttribution
from repro.obs.metrics import MetricsRegistry, percentile as _percentile
from repro.obs.profiler import WallClockProfiler
from repro.obs.trace import TraceRecorder
from repro.runtime import kv_cache as KC
from repro.runtime import paging as PG
from repro.runtime import spec as SP
from repro.runtime.paging import BlockManager
from repro.runtime.requests import Request, State, reset_for_requeue
from repro.runtime.sampler import sample
from repro.runtime.scheduler import (PackedPlan, Scheduler, SchedulerConfig)


class LatencyStats:
    """Per-request serving latencies in VIRTUAL time (runtime/server.py's
    deterministic clock, DESIGN.md §10) plus SLO attainment.

    ``slo_total`` counts every request whose outcome the service is
    accountable for: completions and deadline expiries.  User-initiated
    cancellations are excluded — the client walked away, the server did
    not fail it.  ``goodput`` is the SLO-attainment fraction the paper's
    serving sections report (requests served within their deadline /
    accountable requests).

    Thin view over ``latency/*`` instruments in a MetricsRegistry
    (DESIGN.md §12): every mutation lands in the registry, so a
    ``snapshot()`` and this object can never disagree.  The list/int
    attributes the old dataclass exposed are preserved as live views."""

    def __init__(self, registry: MetricsRegistry | None = None):
        self.registry = (registry if registry is not None
                         else MetricsRegistry())
        self._ttft = self.registry.histogram("latency/ttft")
        self._tpot = self.registry.histogram("latency/tpot")
        self._e2e = self.registry.histogram("latency/e2e")
        self._slo_total = self.registry.counter("latency/slo_total")
        self._slo_met = self.registry.counter("latency/slo_met")

    @property
    def ttft(self) -> List[float]:
        return self._ttft.values

    @property
    def tpot(self) -> List[float]:
        return self._tpot.values

    @property
    def e2e(self) -> List[float]:
        return self._e2e.values

    @property
    def slo_total(self) -> int:
        return self._slo_total.value

    @property
    def slo_met(self) -> int:
        return self._slo_met.value

    def record(self, r) -> None:
        if r.finish_reason != "cancelled":
            self._slo_total.inc()
            self._slo_met.inc(int(r.slo_ok))
        if r.ttft is not None:
            self._ttft.observe(r.ttft)
        if r.tpot is not None:
            self._tpot.observe(r.tpot)
        if r.e2e_latency is not None:
            self._e2e.observe(r.e2e_latency)

    @property
    def goodput(self) -> float:
        return self.slo_met / self.slo_total if self.slo_total else 0.0

    def percentile(self, metric: str, q: float) -> float:
        return _percentile(getattr(self, metric), q)

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {"goodput": self.goodput,
                                 "requests": float(self.slo_total)}
        for m in ("ttft", "tpot", "e2e"):
            for q in (0.5, 0.9, 0.99):
                out[f"{m}_p{int(q * 100)}"] = self.percentile(m, q)
        return out


class SpecStatsView:
    """Registry view with the ``SpecStats`` API (runtime/spec.py,
    DESIGN.md §8) over ``spec/*`` counters."""

    def __init__(self, registry: MetricsRegistry):
        self._verify_steps = registry.counter("spec/verify_steps")
        self._draft_proposed = registry.counter("spec/draft_proposed")
        self._draft_accepted = registry.counter("spec/draft_accepted")
        self._emitted = registry.counter("spec/emitted")

    @property
    def verify_steps(self) -> int:
        return self._verify_steps.value

    @property
    def draft_proposed(self) -> int:
        return self._draft_proposed.value

    @property
    def draft_accepted(self) -> int:
        return self._draft_accepted.value

    @property
    def emitted(self) -> int:
        return self._emitted.value

    @property
    def acceptance_rate(self) -> float:
        return (self.draft_accepted / self.draft_proposed
                if self.draft_proposed else 0.0)

    @property
    def tokens_per_step(self) -> float:
        """Mean committed tokens per verified sequence per engine step
        (plain decode == 1.0 by definition)."""
        seqs = self.emitted - self.draft_accepted
        return self.emitted / seqs if seqs else 0.0


@dataclasses.dataclass
class Handoff:
    """A request parked for disaggregated prefill->decode migration
    (runtime/cluster.py, DESIGN.md §11): the request object plus the KV it
    computed — ``n_tokens`` committed context positions whose block payload
    was extracted before the exporter released its references."""
    req: Request
    n_tokens: int
    payload: dict


class EngineStats:
    """Thin read view over the engine's MetricsRegistry (DESIGN.md §12).

    Every counter the old dataclass carried is now an ``engine/*``
    instrument mutated by the engine through the registry; the attribute
    names here are unchanged, read-only, and always equal to what
    ``Engine.metrics_snapshot()`` exports."""

    def __init__(self, registry: MetricsRegistry | None = None):
        self.registry = (registry if registry is not None
                         else MetricsRegistry())
        r = self.registry
        self._steps = r.counter("engine/steps")
        self._prefill_tokens = r.counter("engine/prefill_tokens")
        self._decode_tokens = r.counter("engine/decode_tokens")
        self._completed = r.counter("engine/completed")
        self._cancelled = r.counter("engine/cancelled")
        self._expired = r.counter("engine/expired")
        self._forwards = r.counter("engine/forwards")
        self._weave_forwards = r.counter("engine/weave_forwards")
        self._forward_tokens = r.counter("engine/forward_tokens")
        self._max_forward_tokens = r.gauge("engine/max_forward_tokens")
        self.spec = SpecStatsView(r)
        self.latency = LatencyStats(r)

    @property
    def steps(self) -> int:
        return self._steps.value

    @property
    def prefill_tokens(self) -> int:
        return self._prefill_tokens.value

    @property
    def decode_tokens(self) -> int:
        return self._decode_tokens.value

    @property
    def completed(self) -> int:
        return self._completed.value

    @property
    def cancelled(self) -> int:
        """User-initiated aborts (online serving)."""
        return self._cancelled.value

    @property
    def expired(self) -> int:
        """Deadline-expiry aborts (online serving)."""
        return self._expired.value

    @property
    def forwards(self) -> int:
        """Model dispatches (2/iter two-dispatch peak)."""
        return self._forwards.value

    @property
    def weave_forwards(self) -> int:
        """Dispatches whose static shape fires the weave."""
        return self._weave_forwards.value

    @property
    def forward_tokens(self) -> int:
        """Real (non-padding) tokens across dispatches."""
        return self._forward_tokens.value

    @property
    def max_forward_tokens(self) -> int:
        """Largest REAL token count in one dispatch."""
        return int(self._max_forward_tokens.value)

    @property
    def weave_rate(self) -> float:
        """Fraction of model dispatches that ran the TokenWeave split —
        the §6 packed-batching payoff metric: mixed iterations that
        two-dispatch judges as two sub-threshold halves count as weave
        misses there and (usually) one weave hit when packed."""
        return self.weave_forwards / self.forwards if self.forwards else 0.0

    @property
    def tokens_per_forward(self) -> float:
        return self.forward_tokens / self.forwards if self.forwards else 0.0


class Engine:
    def __init__(self, api: ModelApi, mesh, params, scfg: SchedulerConfig,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
                 draft: SP.DraftProposer | None = None, seed: int = 0,
                 jit_cache: Dict | None = None,
                 obs: TraceRecorder | None = None,
                 obs_track: str = "engine",
                 profiler: "WallClockProfiler | None" = None):
        if scfg.plan_path:
            # tuned overlap-plan cache (core/policy.py, DESIGN.md §14):
            # install the policy on the model's ParallelConfig BEFORE any
            # jit cache or attributor is built, so every consumer —
            # forward dispatch, packed planner, attribution — sees it
            from repro.core.policy import load_policy
            api = dataclasses.replace(
                api, pcfg=dataclasses.replace(
                    api.pcfg, overlap_policy=load_policy(scfg.plan_path)))
        self.api = api
        self.mesh = mesh
        self.params = params
        self.scfg = scfg
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.metrics = MetricsRegistry()
        self.stats = EngineStats(self.metrics)
        self.metrics.gauge("engine/plan_id").set(
            getattr(api.pcfg.overlap_policy, "plan_id", 0)
            if api.pcfg.overlap_policy is not None else 0)
        # tracing (DESIGN.md §12): obs is None by default — every obs code
        # path is behind an ``is not None`` guard, so tracing off costs
        # nothing and (invariant) tracing on changes no tokens or steps
        self.obs = obs
        self.obs_track = obs_track
        # measured time (DESIGN.md §13): like obs, the profiler is None by
        # default and every hook is behind an ``is not None`` guard; when
        # set it only observes (fenced timing around dispatches), so
        # profiled runs are token- and step-identical to unprofiled ones
        self.profiler = profiler
        if profiler is not None:
            profiler.attach(self.metrics, trace=obs, track=obs_track)
        self._attributor = (Attributor(api.cfg, api.pcfg, api.tp)
                            if obs is not None or profiler is not None
                            else None)
        self._step_forwards: List[WeaveAttribution] = []
        self._step_count = 0
        # jit_cache may be SHARED across engines built with the same
        # (api, mesh, scfg shapes, sampling params) — e.g. the differential
        # harness replaying many short traces — to skip recompilation
        self._jit_cache: Dict = {} if jit_cache is None else jit_cache
        self._pspec = api.specs()
        self._is_ssm = api.cfg.family == "ssm"
        self.paged = bool(scfg.paged)

        self.packed = bool(scfg.packed)
        if self.packed:
            if self._is_ssm:
                raise ValueError("packed hybrid batching scatters per-token "
                                 "KV; ssm state has no token axis — use the "
                                 "two-dispatch path")
            if not hasattr(api.mod, "packed_step"):
                raise ValueError(
                    f"packed batching needs a packed hybrid step; family "
                    f"{api.cfg.family!r} has none")
            if api.pcfg.seq_shard_kv:
                raise ValueError("packed steps gather full cache rows "
                                 "locally; disable seq_shard_kv")
            if not scfg.paged and api.cfg.sliding_window:
                raise ValueError(
                    "packed scatter into a sliding-window ring buffer could "
                    "evict keys earlier packed queries still need; use the "
                    "paged backend (full-length storage, mask-enforced "
                    "windows)")

        self.spec_gamma = int(scfg.spec_gamma)
        self.draft = None
        if self.spec_gamma:
            if self._is_ssm:
                raise ValueError("speculative decoding rolls back KV "
                                 "positions; ssm state has no token axis")
            if not hasattr(api.mod, "verify_step"):
                raise ValueError(
                    f"speculative decoding needs a multi-token verify path; "
                    f"family {api.cfg.family!r} has none")
            if api.pcfg.seq_shard_kv:
                raise ValueError("speculative verify writes full KV rows "
                                 "locally; disable seq_shard_kv")
            if not self.paged and api.cfg.sliding_window:
                raise ValueError(
                    "legacy-slot sliding-window ring buffers cannot hold a "
                    "multi-token verify window (a later write could evict a "
                    "key an earlier query needs); use the paged backend")
            self.draft = draft if draft is not None else SP.make_draft(
                "ngram", self.spec_gamma, ngram=scfg.spec_ngram)
            if self.draft.gamma < self.spec_gamma:
                raise ValueError(
                    f"draft gamma {self.draft.gamma} < scheduler "
                    f"spec_gamma {self.spec_gamma}")
        self._rng_key = jax.random.PRNGKey(seed)

        if self.paged:
            if self._is_ssm:
                raise ValueError("paged KV cache requires attention layers; "
                                 "ssm state caches stay on the slot path")
            self.block_mgr = BlockManager(
                scfg.effective_num_blocks, scfg.block_size,
                scfg.max_blocks_per_req,
                prefix_caching=scfg.prefix_caching)
            cache = PG.init_paged_cache(scfg.effective_num_blocks,
                                        scfg.block_size, api.cfg, api.tp,
                                        api.pcfg)
            cspec = PG.paged_cache_specs(api.cfg, api.pcfg)
        else:
            self.block_mgr = None
            cache = api.init_cache(scfg.max_batch, scfg.max_len)
            cspec = api.cache_specs()
        self.sched = Scheduler(
            scfg, block_mgr=self.block_mgr,
            on_admit=self._obs_admit if obs is not None else None,
            # the packed planner consumes the SAME per-site overlap plan
            # as the forward dispatch (DESIGN.md §14): a late-binding
            # closure over self.api, so install_overlap_policy() swaps
            # the planner's view too
            overlap_hint=self._overlap_hint if self.packed else None)
        # disaggregated serving (DESIGN.md §11): requests parked by
        # ``_park_for_handoff`` wait here for the cluster to migrate them
        self.handoff_ready: List[Handoff] = []
        self.cache = jax.device_put(
            cache, jax.tree.map(lambda s: NamedSharding(mesh, s), cspec,
                                is_leaf=lambda s: isinstance(s, P)))
        self._cspec = cspec

    def _next_key(self):
        """Per-dispatch PRNG key: one deterministic stream (seeded at
        construction) feeds prefill, decode, and verify sampling alike, so
        stochastic runs are reproducible for a fixed request order."""
        self._rng_key, k = jax.random.split(self._rng_key)
        return k

    # ------------------------------------------------------------------
    # per-site overlap policy (core/policy.py, DESIGN.md §14)
    # ------------------------------------------------------------------
    def _overlap_hint(self, tokens: int) -> TRX.WeaveInfo:
        """The packed planner's view of the active overlap policy: the
        same ``weave_decision_info`` the packed forward dispatch will run
        for ``tokens``, stamped on ``PackedPlan.overlap`` — one plan
        format everywhere."""
        return TRX.weave_decision_info(
            1, tokens, tp=self.api.tp, pcfg=self.api.pcfg, packed=True,
            family=self.api.cfg.family)

    def install_overlap_policy(self, policy) -> None:
        """Swap the active ``OverlapPolicy`` (e.g. a freshly loaded tuned
        plan).  The policy lives on the model's ``ParallelConfig``, which
        is baked into jitted step closures and the attributor — so both
        are rebuilt; in-flight requests and caches are untouched (the
        policy only picks split points, never shapes semantics)."""
        self.api = dataclasses.replace(
            self.api, pcfg=dataclasses.replace(self.api.pcfg,
                                               overlap_policy=policy))
        self._jit_cache = {}
        if self._attributor is not None:
            self._attributor = Attributor(self.api.cfg, self.api.pcfg,
                                          self.api.tp)
        self.metrics.gauge("engine/plan_id").set(
            getattr(policy, "plan_id", 0) if policy is not None else 0)

    # ------------------------------------------------------------------
    # jitted step functions
    # ------------------------------------------------------------------
    def _prefill_fn(self, b_sel: int, chunk: int):
        key = ("prefill", b_sel, chunk)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, cache, tokens, positions, slot_ids, offsets,
               last_idx, rng):
            if self._is_ssm:
                rows = jax.tree.map(lambda c: c[:, slot_ids], cache)
                # fresh requests (offset 0) start from zero state
                fresh = offsets == 0

                def zero_fresh(c):
                    m = fresh.reshape((1, -1) + (1,) * (c.ndim - 2))
                    return jnp.where(m, jnp.zeros_like(c), c)
                rows = jax.tree.map(zero_fresh, rows)
                logits, new_rows, _ = api.mod.prefill(
                    params, tokens, rows, cfg=api.cfg, pcfg=api.pcfg,
                    positions=positions, last_idx=last_idx)
                new_cache = jax.tree.map(
                    lambda c, r: c.at[:, slot_ids].set(r), cache, new_rows)
                # SSM: logits of last *valid* token need a re-run on unpadded
                # length; we instead require ssm chunks to be unpadded
                tok = sample(logits, vocab_size=api.cfg.vocab_size,
                             tp_axis=api.pcfg.tp_axis,
                             temperature=self.temperature,
                             top_k=self.top_k, top_p=self.top_p, key=rng)
                return tok, new_cache
            rows = KC.gather_slots(cache, slot_ids)
            logits, kv, _ = api.mod.prefill(
                params, tokens, rows, cfg=api.cfg, pcfg=api.pcfg,
                positions=positions, last_idx=last_idx)
            new_cache = KC.insert_chunk(cache, kv, offsets, slot_ids)
            tok = sample(logits, vocab_size=api.cfg.vocab_size,
                         tp_axis=api.pcfg.tp_axis,
                         temperature=self.temperature,
                         top_k=self.top_k, top_p=self.top_p, key=rng)
            return tok, new_cache

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P(), P(),
                      P()),
            out_specs=(P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _paged_prefill_fn(self, b_sel: int, chunk: int):
        key = ("pprefill", b_sel, chunk)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, pool, tokens, positions, block_tables, last_idx,
               rng):
            # rectangular context view through the block-table indirection;
            # the model's prefill path is backend-agnostic (rows look
            # exactly like gathered slot rows)
            rows = PG.gather_block_rows(pool, block_tables)
            logits, kv, _ = api.mod.prefill(
                params, tokens, rows, cfg=api.cfg, pcfg=api.pcfg,
                positions=positions, last_idx=last_idx)
            new_pool = PG.insert_chunk_paged(pool, kv, block_tables)
            tok = sample(logits, vocab_size=api.cfg.vocab_size,
                         tp_axis=api.pcfg.tp_axis,
                         temperature=self.temperature,
                         top_k=self.top_k, top_p=self.top_p, key=rng)
            return tok, new_pool

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P(), P()),
            out_specs=(P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _decode_fn(self):
        key = ("decode",)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, cache, tokens, positions, rng):
            logits, new_cache = api.mod.decode_step(
                params, tokens, cache, cfg=api.cfg, pcfg=api.pcfg,
                positions=positions)
            tok = sample(logits, vocab_size=api.cfg.vocab_size,
                         tp_axis=api.pcfg.tp_axis,
                         temperature=self.temperature,
                         top_k=self.top_k, top_p=self.top_p, key=rng)
            return tok, new_cache

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P()),
            out_specs=(P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _paged_decode_fn(self):
        key = ("pdecode",)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, pool, tokens, positions, block_tables, rng):
            logits, new_pool = api.mod.decode_step(
                params, tokens, pool, cfg=api.cfg, pcfg=api.pcfg,
                positions=positions, block_tables=block_tables)
            tok = sample(logits, vocab_size=api.cfg.vocab_size,
                         tp_axis=api.pcfg.tp_axis,
                         temperature=self.temperature,
                         top_k=self.top_k, top_p=self.top_p, key=rng)
            return tok, new_pool

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P()),
            out_specs=(P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _verify_fn(self, s_v: int):
        """Jitted speculative verify over the legacy slot cache: one
        multi-token decode forward + on-device rejection sampling."""
        key = ("verify", s_v)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, cache, tokens, positions, draft, rng):
            logits, new_cache = api.verify_step(params, tokens, cache,
                                                positions)
            n_acc, emit = SP.verify_tokens(
                logits, draft, rng, vocab_size=api.cfg.vocab_size,
                tp_axis=api.pcfg.tp_axis, temperature=self.temperature,
                top_k=self.top_k, top_p=self.top_p)
            return n_acc, emit, new_cache

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P()),
            out_specs=(P(), P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _paged_verify_fn(self, s_v: int):
        key = ("pverify", s_v)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, pool, tokens, positions, block_tables, draft, rng):
            logits, new_pool = api.verify_step(params, tokens, pool,
                                               positions,
                                               block_tables=block_tables)
            n_acc, emit = SP.verify_tokens(
                logits, draft, rng, vocab_size=api.cfg.vocab_size,
                tp_axis=api.pcfg.tp_axis, temperature=self.temperature,
                top_k=self.top_k, top_p=self.top_p)
            return n_acc, emit, new_pool

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P(), P()),
            out_specs=(P(), P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _packed_fn(self, t: int, w: int):
        """Jitted packed hybrid step (DESIGN.md §6): ONE forward over the
        (1, t) packed token axis, then unified sampling over per-segment
        windows — ``w == 1`` plain sampling at each segment's last valid
        token, ``w == gamma+1`` speculative rejection sampling (segments
        without a draft have all-(-1) draft rows, for which verification
        degenerates to exactly the plain sample of window row 0)."""
        key = ("packed", t, w)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api
        paged = self.paged

        def fn(params, cache, tokens, positions, seg_slots, sample_idx,
               *rest):
            rest = list(rest)
            bt = rest.pop(0) if paged else None
            draft = rest.pop(0) if w > 1 else None
            rng = rest.pop(0)
            logits, new_cache = api.packed_step(
                params, tokens, cache, positions, seg_slots=seg_slots,
                sample_idx=sample_idx, block_tables=bt)
            if w > 1:
                n_acc, emit = SP.verify_tokens(
                    logits, draft, rng, vocab_size=api.cfg.vocab_size,
                    tp_axis=api.pcfg.tp_axis, temperature=self.temperature,
                    top_k=self.top_k, top_p=self.top_p)
            else:
                n_acc = jnp.zeros(logits.shape[0], jnp.int32)
                emit = sample(logits, vocab_size=api.cfg.vocab_size,
                              tp_axis=api.pcfg.tp_axis,
                              temperature=self.temperature,
                              top_k=self.top_k, top_p=self.top_p, key=rng)
            return n_acc, emit, new_cache

        n_plain = 5 + (1 if paged else 0) + (1 if w > 1 else 0)
        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec) + (P(),) * n_plain,
            out_specs=(P(), P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def add_request(self, req: Request):
        if len(req.prompt) + 1 > self.scfg.max_len:
            # the prompt plus at least one decode slot must fit the cache;
            # legacy slots would silently ring-wrap, paged tables would
            # overflow — reject loudly instead
            raise ValueError(
                f"prompt length {len(req.prompt)} + 1 exceeds max_len "
                f"{self.scfg.max_len} (rid={req.rid})")
        if self.paged:
            need = self.block_mgr.blocks_needed(len(req.prompt)) + 1
            if need > self.scfg.effective_num_blocks:
                # even an otherwise-empty pool could never admit it
                raise ValueError(
                    f"prompt needs {need} blocks but the pool has only "
                    f"{self.scfg.effective_num_blocks} (rid={req.rid})")
        req.arrival_step = self._step_count
        self.sched.add(req)
        if self.obs is not None:
            self.obs.request_event(req.rid, "queued")

    def _obs_admit(self, req: Request) -> None:
        """Scheduler admission hook (only wired when tracing is on)."""
        self.obs.request_event(req.rid, "admit", args={"slot": req.slot})

    def metrics_snapshot(self) -> Dict[str, float]:
        """Sync derived gauges, then flatten the registry — the
        provenance-checked source for gated benchmark metrics
        (benchmarks/run.py, scripts/check_bench.py; DESIGN.md §12)."""
        m = self.metrics
        st = self.stats
        m.gauge("engine/weave_rate").set(st.weave_rate)
        m.gauge("engine/tokens_per_forward").set(st.tokens_per_forward)
        # per-site weave rates (overlap policy attribution, DESIGN.md §14)
        for (name, lk), inst in list(m._instruments.items()):
            if name != "engine/site_forwards" or not inst.value:
                continue
            labels = dict(lk)
            w = m.get("engine/site_weave", **labels)
            m.gauge("engine/site_weave_rate", **labels).set(
                (w.value if w is not None else 0) / inst.value)
            fz = m.get("engine/site_fused", **labels)
            m.gauge("engine/site_fused_rate", **labels).set(
                (fz.value if fz is not None else 0) / inst.value)
        m.gauge("spec/acceptance_rate").set(st.spec.acceptance_rate)
        m.gauge("spec/tokens_per_step").set(st.spec.tokens_per_step)
        m.gauge("latency/goodput").set(st.latency.goodput)
        if self.block_mgr is not None:
            bs = self.block_mgr.stats
            m.gauge("paging/hit_rate").set(bs.hit_rate)
            m.gauge("paging/preemptions").set(bs.preemptions)
            m.gauge("paging/evictions").set(bs.evictions)
        return m.snapshot()

    def abort(self, req: Request, reason: str = "cancelled") -> bool:
        """Cancel a live request at ANY lifecycle point (waiting, mid-
        prefill, mid-decode/verify), releasing every resource it holds:
        paged blocks (including prefix-cache shared refs), the legacy cache
        slot (stale-position reset), and its scheduler entry.  Safe only
        BETWEEN engine steps (steps are atomic).  Returns False when the
        request was already finished."""
        if req.state == State.DONE:
            return False
        req.finish_reason = reason
        if reason == "expired":
            self.stats._expired.inc()
        else:
            self.stats._cancelled.inc()
        if self.obs is not None:
            self.obs.request_event(
                req.rid, "expire" if reason == "expired" else "cancel",
                args={"reason": reason})
        if req.state == State.WAITING:
            # not admitted: no slot, and (paged) no blocks — allocation
            # happens at admission; a preempted request already freed its
            self.sched.remove_waiting(req)
            req.state = State.DONE
            return True
        if req.slot is None:
            # parked for handoff (DESIGN.md §11): the exporter already
            # released blocks and slot; just drop it from the handoff queue
            self.handoff_ready = [h for h in self.handoff_ready
                                  if h.req is not req]
            req.state = State.DONE
            return True
        if self.paged:
            # drops private AND prefix-shared refs; cached blocks park in
            # the LRU (still hittable), so cancelling never poisons the
            # prefix cache — only releases this request's references
            self.block_mgr.free_request(req.rid)
        elif not self._is_ssm:
            self.cache = KC.reset_slots(self.cache, np.asarray([req.slot]))
        self.sched.active[req.slot] = None
        req.slot = None
        req.state = State.DONE
        return True

    # ------------------------------------------------------------------
    # disaggregated prefill/decode KV handoff (runtime/cluster.py,
    # DESIGN.md §11)
    # ------------------------------------------------------------------
    def _park_for_handoff(self, r: Request):
        """Export the request's KV and detach it from this engine: the
        payload is pulled off the device (the migration's network copy),
        then every exporter-side reference is released — shared prefix
        blocks keep their other readers, private ones recycle — and the
        slot frees for the next prefill.  The request (state DECODE, its
        first token already committed) waits in ``handoff_ready`` for the
        cluster to adopt it on a decode replica."""
        if not self.paged:
            raise ValueError("KV handoff requires the paged backend "
                             "(legacy slot rows cannot be exported)")
        n_tokens = r.prefill_pos          # committed context now in cache
        blocks = self.block_mgr.export_blocks(r.rid, n_tokens)
        payload = jax.device_get(PG.extract_blocks(self.cache, blocks))
        self.block_mgr.free_request(r.rid)
        self.sched.active[r.slot] = None
        r.slot = None
        self.handoff_ready.append(Handoff(req=r, n_tokens=n_tokens,
                                          payload=payload))
        if self.obs is not None:
            self.obs.request_event(r.rid, "handoff_export",
                                   args={"n_tokens": n_tokens})

    def take_handoffs(self) -> List[Handoff]:
        out, self.handoff_ready = self.handoff_ready, []
        return out

    def evacuate(self) -> List[Request]:
        """Dead-replica recovery (runtime/cluster.py, DESIGN.md §15):
        release every resource of every live request this engine owns —
        paged blocks (prefix-shared refs included), legacy slots, parked
        handoffs, scheduler entries — and return the requests reset for
        re-admission elsewhere (WAITING, recompute semantics like
        preemption).  In a real deployment the dead machine's memory is
        simply gone; the deterministic twin models that by sweeping the
        pool back to empty, which is exactly what makes a requeue that
        SKIPS the release visible to ``ClusterServer.check_quiescent``
        (the fault-injection tests monkeypatch this to leak)."""
        out: List[Request] = []
        for h in self.take_handoffs():
            # exporter-side refs were already released at park
            out.append(reset_for_requeue(h.req))
        for r in list(self.sched.waiting):
            self.sched.remove_waiting(r)
            out.append(reset_for_requeue(r))
        for slot, r in enumerate(self.sched.active):
            if r is None:
                continue
            if self.paged:
                self.block_mgr.free_request(r.rid)
            elif not self._is_ssm:
                self.cache = KC.reset_slots(self.cache,
                                            np.asarray([r.slot]))
            self.sched.active[slot] = None
            out.append(reset_for_requeue(r))
        return out

    def adopt_request(self, req: Request, n_tokens: int, payload) -> bool:
        """Adopt a migrated DECODE request: rebuild its block table on this
        engine (sharing importer-side prefix-cache hits, implanting the
        payload into the rest), re-register its prefix-cache entries, and
        place it straight into a free slot — no re-prefill, decode resumes
        from the migrated KV.  Returns False (no state changed, retry
        later) when no slot is free or the pool cannot cover it."""
        if not self.paged:
            raise ValueError("adopt_request requires the paged backend")
        if req.state != State.DECODE:
            raise ValueError(f"rid={req.rid} is {req.state}, not DECODE")
        free = [i for i, r in enumerate(self.sched.active) if r is None]
        if not free:
            return False
        ctx = req.prompt + req.output[:-1]
        assert n_tokens <= len(ctx), (req.rid, n_tokens, len(ctx))
        imported = self.block_mgr.import_blocks(req.rid, ctx[:n_tokens],
                                                n_tokens)
        if imported is None:
            return False
        table, copy_idx = imported
        # drain queued pool maintenance first: a freshly allocated table
        # entry may still carry a pending pos reset from its previous
        # owner, which would clobber the implant if applied after it
        self._apply_fixups()
        if copy_idx:
            self.cache = PG.implant_blocks(
                self.cache, PG.select_payload(payload, copy_idx),
                [table[i] for i in copy_idx])
        self.block_mgr.register_filled(req.rid, ctx, n_tokens)
        req.handoff_after_prefill = False
        req.migrations += 1
        req.slot = free[0]
        req.arrival_step = self._step_count
        self.sched.active[req.slot] = req
        if self.obs is not None:
            self.obs.request_event(req.rid, "handoff_adopt",
                                   args={"slot": req.slot})
        return True

    def step(self) -> bool:
        """Run one engine iteration. Returns False when idle."""
        obs = self.obs
        if obs is not None:
            # offline engines self-clock one tick per step; a no-op once
            # an external owner (server/replica) has synced.  Stamped
            # BEFORE next_step() so admission events land at step time.
            obs.auto(float(self._step_count))
            self._step_forwards = []
        plan = self.sched.next_step()
        if plan is None:
            return False
        self._step_count += 1
        self.stats._steps.inc()

        if isinstance(plan, PackedPlan):
            self._run_packed(plan)
            if obs is not None:
                self._obs_emit_step(packed=True)
            return True
        if plan.prefill is not None:
            self._run_prefill(*plan.prefill)
        if plan.decode_slots:
            if self.spec_gamma:
                self._run_verify()
            else:
                self._run_decode()
        if obs is not None:
            self._obs_emit_step(packed=False)
        return True

    def _obs_emit_step(self, packed: bool) -> None:
        """Emit this iteration's step span plus one nested forward span
        per model dispatch, carrying the weave attribution record
        (DESIGN.md §12).  All spans start at the step's clock stamp with
        §9 sim-roofline durations; the step span covers its longest
        forward, so nesting holds however far the owner clock advances."""
        obs = self.obs
        fwds = self._step_forwards
        t0 = obs.now
        durs = [max(a.est_makespan, 1e-9) for a in fwds]
        obs.complete(self.obs_track,
                     "step/packed" if packed else "step/two_dispatch",
                     t0, max(durs, default=1e-9), cat="step",
                     args={"step": self._step_count, "forwards": len(fwds)})
        for a, d in zip(fwds, durs):
            args = a.args()
            args["step"] = self._step_count
            obs.complete(self.obs_track, f"forward/{a.kind}", t0, d,
                         cat="forward", args=args)
        self._step_forwards = []

    def _prof_wrap(self, jfn):
        """Fenced wall-clock timing around one dispatch when a
        ``WallClockProfiler`` is attached (DESIGN.md §13); identity
        otherwise.  Applied at call sites, not in the jit cache, so a
        SHARED cache never leaks one engine's profiler into another."""
        return jfn if self.profiler is None else self.profiler.wrap(jfn)

    def _note_forward(self, b: int, s: int, n_real: int, *,
                      decode: bool = False, packed: bool = False,
                      kind: str = "prefill"):
        """Record one model dispatch: its static (b, s) shape decides the
        weave (host-side mirror of the trace-time split decision), its
        real token count feeds tokens/forward.  The SAME decision object
        feeds the counter and (when tracing) the trace attribution record,
        so trace-derived weave rates match ``EngineStats.weave_rate``
        exactly (DESIGN.md §12)."""
        st = self.stats
        st._forwards.inc()
        st._forward_tokens.inc(n_real)
        st._max_forward_tokens.set_max(n_real)
        info = TRX.weave_decision_info(b, s, tp=self.api.tp,
                                       pcfg=self.api.pcfg, decode=decode,
                                       packed=packed,
                                       paged_pool=self.paged and decode,
                                       family=self.api.cfg.family)
        # per-site weave attribution (DESIGN.md §14): which policy site
        # decided, and whether the weave fired there
        site = info.site or kind
        self.metrics.counter("engine/site_forwards", site=site).inc()
        if info.weave:
            st._weave_forwards.inc()
            self.metrics.counter("engine/site_weave", site=site).inc()
        if info.comm_mode == "ring":
            # a tuned plan routed this site onto the REAL fused ring
            # AllReduce-RMSNorm kernel (method fused / fused-unsplit)
            self.metrics.counter("engine/site_fused", site=site).inc()
        if self._attributor is not None:
            att = self._attributor.attribute(info, b=b, s=s, n_real=n_real,
                                             kind=kind)
            if self.obs is not None:
                self._step_forwards.append(att)
            if self.profiler is not None:
                # join the fenced timing _prof_wrap stashed for this very
                # dispatch to its attribution record (DESIGN.md §13)
                self.profiler.commit(att)

    def run(self, max_steps: int = 100000) -> List[Request]:
        while not self.sched.all_done() and max_steps > 0:
            max_steps -= 1
            if not self.step():
                if self.sched.waiting:
                    # nothing active and the queue head cannot be admitted:
                    # permanently stuck (e.g. a preempted request whose
                    # regrown context outgrew the pool) — surface it rather
                    # than silently dropping the request
                    rids = [r.rid for r in self.sched.waiting]
                    raise RuntimeError(
                        f"engine idle with unservable waiting request(s) "
                        f"{rids}: block pool too small for their context")
                break
        return self.sched.finished

    # ------------------------------------------------------------------
    # paged-cache plumbing
    # ------------------------------------------------------------------
    def _apply_fixups(self):
        """Drain queued device-side pool maintenance: pos resets of
        recycled blocks FIRST (a reset target may since have been handed
        out again — its new owner writes later, and a COW destination is
        overwritten entirely by its copy), then copy-on-write copies."""
        resets = self.block_mgr.take_pending_resets()
        copies = self.block_mgr.take_pending_copies()
        if resets:
            self.cache = PG.reset_blocks(self.cache, resets)
        if copies:
            self.cache = PG.copy_blocks(self.cache, copies)

    def _preempt(self, victim: Request):
        self.block_mgr.free_request(victim.rid)
        self.block_mgr.stats.preemptions += 1
        self.sched.preempt(victim)
        if self.obs is not None:
            self.obs.request_event(victim.rid, "preempt")

    def _ensure_decode_blocks(self) -> List[Request]:
        """Grow/COW the write-target block of every DECODE request; on
        pool exhaustion preempt the youngest DECODE request (recompute
        mode) and retry.  Returns the surviving decode batch."""
        def decoding():
            return [r for r in self.sched.active
                    if r is not None and r.state == State.DECODE]
        for r in decoding():
            if r.length - 1 >= self.scfg.max_len:
                # context hit the cache ceiling: stop generating early
                # (truncated output) rather than overflow the block table
                self._finish(r)
        for r in sorted(decoding(), key=lambda r: (r.arrival_step, r.rid)):
            while r.state == State.DECODE:
                if self.block_mgr.ensure_writable(r.rid, r.length - 1):
                    break
                victims = decoding()
                victim = max(victims, key=lambda v: (v.arrival_step, v.rid))
                self._preempt(victim)   # may be r itself -> loop exits
        return decoding()

    # ------------------------------------------------------------------
    # per-request commit helpers — ONE implementation shared by the
    # two-dispatch and packed paths, so cache-invalidation / registration
    # fixes can never diverge between them
    # ------------------------------------------------------------------
    def _commit_prefill(self, r: Request, tok: int):
        """After a prefill chunk advanced ``r.prefill_pos``: register the
        filled blocks and, when the context completed, commit the first
        sampled token (dropped for recompute-readmissions, whose pending
        decode input was already emitted) and move to DECODE."""
        if self.paged:
            self.block_mgr.register_filled(r.rid, r.context_tokens,
                                           r.prefill_pos)
        if r.prefill_done:
            if r.resumed:
                r.resumed = False
            else:
                r.output.append(tok)
                r.first_token_step = self._step_count
            r.state = State.DECODE
            if self.obs is not None:
                self.obs.request_event(r.rid, "prefill_done",
                                       args={"tokens": r.prefill_pos})
            self._maybe_finish(r)
            if r.state != State.DONE and r.handoff_after_prefill:
                self._park_for_handoff(r)

    def _commit_decode(self, r: Request, tok: int):
        n_written = r.length  # positions [0, length-1] now in cache
        r.output.append(tok)
        self.stats._decode_tokens.inc()
        if self.paged and n_written % self.scfg.block_size == 0:
            # a block just filled: make it hittable for future prompts
            self.block_mgr.register_filled(
                r.rid, r.prompt + r.output[:-1], n_written)
        self._maybe_finish(r)

    def _commit_verify(self, r: Request, prop: List[int], n_acc: int,
                       emit: int):
        """Commit the longest accepted draft prefix + the corrected/bonus
        token and roll back rejected KV (paged: block-table truncation;
        legacy slots need none by the overwrite-before-query invariant)."""
        n = min(n_acc, len(prop))
        base_len = r.length          # L: window wrote L-1 .. L-1+|prop|
        r.output.extend(prop[:n] + [emit])
        st = self.stats.spec
        st._draft_proposed.inc(len(prop))
        st._draft_accepted.inc(n)
        st._emitted.inc(n + 1)
        self.stats._decode_tokens.inc(n + 1)
        if self.paged:
            # rollback: keep exactly the blocks covering the committed
            # context (positions 0 .. L-1+n); rejected draft KV beyond
            # them is dropped with the tail blocks, never copied
            self.block_mgr.truncate(r.rid, base_len + n)
            self.block_mgr.register_filled(
                r.rid, r.prompt + r.output[:-1], base_len + n)
        self._maybe_finish(r)

    # ------------------------------------------------------------------
    def _run_prefill(self, group: List[Request], chunk: int):
        b_sel = len(group)
        if self._is_ssm:
            # ssm chunks must be exact (no pads): shrink to min remainder
            chunk = min(min(len(r.context_tokens) - r.prefill_pos
                            for r in group), chunk)
        tokens = np.zeros((b_sel, chunk), np.int32)
        positions = np.full((b_sel, chunk), -1, np.int32)
        offsets = np.zeros(b_sel, np.int32)
        last_idx = np.zeros(b_sel, np.int32)
        for i, r in enumerate(group):
            ctx = r.context_tokens
            take = min(chunk, len(ctx) - r.prefill_pos)
            tokens[i, :take] = ctx[r.prefill_pos:r.prefill_pos + take]
            positions[i, :take] = np.arange(r.prefill_pos,
                                            r.prefill_pos + take)
            offsets[i] = r.prefill_pos
            last_idx[i] = take - 1
            r.prefill_pos += take

        if self.paged:
            self._apply_fixups()
            bt = np.stack([self.block_mgr.table_array(r.rid) for r in group])
            fn = self._prof_wrap(self._paged_prefill_fn(b_sel, chunk))
            tok, self.cache = fn(self.params, self.cache,
                                 jnp.asarray(tokens), jnp.asarray(positions),
                                 jnp.asarray(bt), jnp.asarray(last_idx),
                                 self._next_key())
        else:
            slot_ids = np.array([r.slot for r in group], np.int32)
            fn = self._prof_wrap(self._prefill_fn(b_sel, chunk))
            tok, self.cache = fn(self.params, self.cache,
                                 jnp.asarray(tokens), jnp.asarray(positions),
                                 jnp.asarray(slot_ids), jnp.asarray(offsets),
                                 jnp.asarray(last_idx), self._next_key())
        tok = np.asarray(tok)
        n_real = int((positions >= 0).sum())
        self.stats._prefill_tokens.inc(n_real)
        self._note_forward(b_sel, chunk, n_real, kind="prefill")
        for i, r in enumerate(group):
            self._commit_prefill(r, int(tok[i]))

    def _run_decode(self):
        if self.paged:
            reqs = self._ensure_decode_blocks()
            if not reqs:
                return
            self._apply_fixups()
        else:
            reqs = [r for r in self.sched.active
                    if r is not None and r.state == State.DECODE]
        bmax = self.scfg.max_batch
        tokens = np.zeros((bmax, 1), np.int32)
        positions = np.full((bmax, 1), -1, np.int32)
        for r in reqs:
            tokens[r.slot, 0] = r.output[-1]
            positions[r.slot, 0] = r.length - 1

        if self.paged:
            bt = np.full((bmax, self.scfg.max_blocks_per_req), -1, np.int32)
            for r in reqs:
                bt[r.slot] = self.block_mgr.table_array(r.rid)
            fn = self._prof_wrap(self._paged_decode_fn())
            tok, self.cache = fn(self.params, self.cache,
                                 jnp.asarray(tokens), jnp.asarray(positions),
                                 jnp.asarray(bt), self._next_key())
        else:
            fn = self._prof_wrap(self._decode_fn())
            tok, self.cache = fn(self.params, self.cache,
                                 jnp.asarray(tokens), jnp.asarray(positions),
                                 self._next_key())
        tok = np.asarray(tok)
        self._note_forward(bmax, 1, len(reqs), decode=True, kind="decode")
        for r in list(reqs):
            self._commit_decode(r, int(tok[r.slot]))

    # ------------------------------------------------------------------
    # speculative decoding (runtime/spec.py, DESIGN.md §8)
    # ------------------------------------------------------------------
    def _grow_for_draft(self, r: Request, want: int) -> int:
        """Best-effort paged-block growth for the draft positions
        ``length .. length-1+want``; on allocation failure the draft is
        SHRUNK (draft tokens are optional) instead of preempting a peer.
        Returns the number of draft tokens whose KV cell is writable."""
        for j in range(1, want + 1):
            if not self.block_mgr.ensure_writable(r.rid, r.length - 1 + j):
                return j - 1
        return want

    def _capped_drafts(self, reqs: List[Request]) -> Dict[int, List[int]]:
        """Draft proposals for the given DECODE requests, capped so the
        verify window never overshoots max_new_tokens (the verify always
        commits >= 1 extra token) or the cache ceiling, and shrunk — never
        preempting a peer — to the paged blocks that can actually grow.
        ONE implementation shared by the two-dispatch and packed paths."""
        gamma = self.spec_gamma
        props = self.draft.propose([r.prompt + r.output for r in reqs])
        capped: Dict[int, List[int]] = {}
        for r, prop in zip(reqs, props):
            cap = min(gamma, r.max_new_tokens - len(r.output) - 1,
                      self.scfg.max_len - r.length)
            prop = list(prop[:max(cap, 0)])
            if self.paged and prop:
                prop = prop[:self._grow_for_draft(r, len(prop))]
            capped[r.rid] = prop
        return capped

    def _run_verify(self):
        """One speculative iteration over every DECODE request: draft
        gamma tokens, run ONE gamma+1-token verify forward, commit the
        longest accepted prefix + 1 corrected/bonus token, and roll back
        the rejected suffix (paged: block-table truncation)."""
        gamma = self.spec_gamma
        if self.paged:
            reqs = self._ensure_decode_blocks()   # input cell is mandatory
            if not reqs:
                return
        else:
            reqs = [r for r in self.sched.active
                    if r is not None and r.state == State.DECODE]
            if not reqs:
                return

        capped = self._capped_drafts(reqs)
        if not any(capped.values()):
            # nothing drafted anywhere: a gamma+1-wide verify would pay
            # (gamma+1)x decode compute to commit one token per request —
            # take the plain single-token decode step instead
            self._run_decode()
            return
        if self.paged:
            self._apply_fixups()

        bmax = self.scfg.max_batch
        s_v = gamma + 1
        tokens = np.zeros((bmax, s_v), np.int32)
        positions = np.full((bmax, s_v), -1, np.int32)
        draft = np.full((bmax, gamma), -1, np.int32)
        for r in reqs:
            prop = capped[r.rid]
            tokens[r.slot, 0] = r.output[-1]
            positions[r.slot, 0] = r.length - 1
            for j, d in enumerate(prop):
                tokens[r.slot, 1 + j] = d
                positions[r.slot, 1 + j] = r.length + j
                draft[r.slot, j] = d

        rng = self._next_key()
        if self.paged:
            bt = np.full((bmax, self.scfg.max_blocks_per_req), -1, np.int32)
            for r in reqs:
                bt[r.slot] = self.block_mgr.table_array(r.rid)
            fn = self._prof_wrap(self._paged_verify_fn(s_v))
            n_acc, emit, self.cache = fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(positions), jnp.asarray(bt), jnp.asarray(draft),
                rng)
        else:
            fn = self._prof_wrap(self._verify_fn(s_v))
            n_acc, emit, self.cache = fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(positions), jnp.asarray(draft), rng)
        n_acc = np.asarray(n_acc)
        emit = np.asarray(emit)
        self._note_forward(bmax, s_v,
                           sum(1 + len(capped[r.rid]) for r in reqs),
                           decode=True, kind="verify")

        self.stats.spec._verify_steps.inc()
        for r in list(reqs):
            self._commit_verify(r, capped[r.rid], int(n_acc[r.slot]),
                                int(emit[r.slot]))

    # ------------------------------------------------------------------
    # packed hybrid batching (DESIGN.md §6)
    # ------------------------------------------------------------------
    def _run_packed(self, plan: PackedPlan):
        """ONE forward for the whole iteration: prefill-chunk segments,
        single-token decode slots, and speculative verify windows are
        concatenated along a single (1, T) token axis (T padded to a
        recompilation bucket) and dispatched through
        ``ModelApi.packed_step``; unified sampling/verification then
        commits every segment kind from the same (n_acc, emit) pair."""
        gamma = self.spec_gamma
        w = gamma + 1 if gamma else 1
        if self.paged:
            # grow/COW the decode write cells first; this can preempt or
            # ceiling-finish DECODE requests, so re-filter the plan
            self._ensure_decode_blocks()
        segs = [s for s in plan.segments
                if s.req.state == (State.PREFILL if s.kind == "prefill"
                                   else State.DECODE)]
        if not segs:
            return

        props: Dict[int, List[int]] = {}
        if gamma:
            vreqs = [s.req for s in segs if s.kind == "verify"]
            if vreqs:
                props = self._capped_drafts(vreqs)
        if self.paged:
            self._apply_fixups()

        def seg_len(s):
            if s.kind == "prefill":
                return s.n_tokens
            if s.kind == "verify":
                return 1 + len(props.get(s.req.rid, []))
            return 1

        t_real = sum(seg_len(s) for s in segs)
        pad_mult = math.lcm(self.scfg.prefill_bucket, self.api.tp)
        t = pad_to_multiple(t_real, pad_mult)
        bmax = self.scfg.max_batch
        tokens = np.zeros((1, t), np.int32)
        positions = np.full((1, t), -1, np.int32)
        seg_slots = np.full(t, -1, np.int32)
        sample_idx = np.full((bmax, w), -1, np.int32)
        draft = np.full((bmax, gamma), -1, np.int32) if gamma else None
        bt = (np.full((bmax, self.scfg.max_blocks_per_req), -1, np.int32)
              if self.paged else None)

        cur = 0
        for s in segs:
            r = s.req
            m = r.slot
            if self.paged:
                bt[m] = self.block_mgr.table_array(r.rid)
            if s.kind == "prefill":
                ctx = r.context_tokens
                take = s.n_tokens
                tokens[0, cur:cur + take] = \
                    ctx[r.prefill_pos:r.prefill_pos + take]
                positions[0, cur:cur + take] = np.arange(
                    r.prefill_pos, r.prefill_pos + take)
                seg_slots[cur:cur + take] = m
                sample_idx[m, 0] = cur + take - 1
                r.prefill_pos += take
                cur += take
            else:
                prop = props.get(r.rid, []) if s.kind == "verify" else []
                tokens[0, cur] = r.output[-1]
                positions[0, cur] = r.length - 1
                seg_slots[cur:cur + 1 + len(prop)] = m
                sample_idx[m, 0] = cur
                for j, d in enumerate(prop):
                    tokens[0, cur + 1 + j] = d
                    positions[0, cur + 1 + j] = r.length + j
                    draft[m, j] = d
                    sample_idx[m, 1 + j] = cur + 1 + j
                cur += 1 + len(prop)

        args = [self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(positions), jnp.asarray(seg_slots),
                jnp.asarray(sample_idx)]
        if self.paged:
            args.append(jnp.asarray(bt))
        if w > 1:
            args.append(jnp.asarray(draft))
        args.append(self._next_key())
        fn = self._prof_wrap(self._packed_fn(t, w))
        n_acc, emit, self.cache = fn(*args)
        n_acc = np.asarray(n_acc)
        emit = np.asarray(emit)
        self._note_forward(1, t, t_real, packed=True, kind="packed")

        if any(s.kind == "verify" for s in segs):
            self.stats.spec._verify_steps.inc()
        for s in segs:
            r = s.req
            m = r.slot
            if s.kind == "prefill":
                self.stats._prefill_tokens.inc(s.n_tokens)
                self._commit_prefill(r, int(emit[m]))
            elif s.kind == "decode":
                self._commit_decode(r, int(emit[m]))
            else:
                self._commit_verify(r, props.get(r.rid, []),
                                    int(n_acc[m]), int(emit[m]))

    def _maybe_finish(self, r: Request):
        if len(r.output) >= r.max_new_tokens:
            self._finish(r)

    def _finish(self, r: Request):
        if self.paged:
            # final registration, then drop refs: cached blocks park in
            # the LRU (still prefix-hittable), private ones recycle
            self.block_mgr.register_filled(
                r.rid, r.prompt + r.output[:-1], r.length - 1)
            self.block_mgr.free_request(r.rid)
        elif not self._is_ssm:
            # release slot state: stale ring-buffer positions from a
            # finished request must not leak into the slot's next owner
            self.cache = KC.reset_slots(self.cache, np.asarray([r.slot]))
        r.finish_reason = r.finish_reason or "stop"
        self.sched.finish(r, self._step_count)
        self.stats._completed.inc()
        if self.obs is not None:
            self.obs.request_event(r.rid, "finish",
                                   args={"reason": r.finish_reason,
                                         "tokens": len(r.output)})
