"""Continuous-batching inference engine (single-host execution; the
multi-pod serve path is exercised via launch/dryrun.py's serve_step).

Supports the transformer families (dense/moe/vlm) and ssm; hybrid and encdec
are served via direct serve-step calls (see launch/dryrun.py) — documented
in DESIGN.md §6.

Per iteration: one decode step over ALL cache slots (inactive slots are
masked via position -1) and/or one rectangular prefill chunk for a group of
admitted requests (Sarathi-style chunked prefill, lengths bucketed to bound
recompilation). TokenWeave activates inside the model whenever the batch
crosses ``tokenweave_min_tokens``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.build import ModelApi
from repro.runtime import kv_cache as KC
from repro.runtime.requests import Request, State
from repro.runtime.sampler import sample
from repro.runtime.scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    completed: int = 0


class Engine:
    def __init__(self, api: ModelApi, mesh, params, scfg: SchedulerConfig,
                 temperature: float = 0.0):
        self.api = api
        self.mesh = mesh
        self.params = params
        self.scfg = scfg
        self.temperature = temperature
        self.sched = Scheduler(scfg)
        self.stats = EngineStats()
        self._step_count = 0
        self._lengths = np.zeros(scfg.max_batch, np.int64)
        self._jit_cache: Dict = {}

        cache = api.init_cache(scfg.max_batch, scfg.max_len)
        cspec = api.cache_specs()
        self.cache = jax.device_put(
            cache, jax.tree.map(lambda s: NamedSharding(mesh, s), cspec,
                                is_leaf=lambda s: isinstance(s, P)))
        self._cspec = cspec
        self._pspec = api.specs()
        self._is_ssm = api.cfg.family == "ssm"

    # ------------------------------------------------------------------
    # jitted step functions
    # ------------------------------------------------------------------
    def _prefill_fn(self, b_sel: int, chunk: int):
        key = ("prefill", b_sel, chunk)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, cache, tokens, positions, slot_ids, offsets,
               last_idx):
            if self._is_ssm:
                rows = jax.tree.map(lambda c: c[:, slot_ids], cache)
                # fresh requests (offset 0) start from zero state
                fresh = offsets == 0

                def zero_fresh(c):
                    m = fresh.reshape((1, -1) + (1,) * (c.ndim - 2))
                    return jnp.where(m, jnp.zeros_like(c), c)
                rows = jax.tree.map(zero_fresh, rows)
                logits, new_rows, _ = api.mod.prefill(
                    params, tokens, rows, cfg=api.cfg, pcfg=api.pcfg,
                    positions=positions, last_idx=last_idx)
                new_cache = jax.tree.map(
                    lambda c, r: c.at[:, slot_ids].set(r), cache, new_rows)
                # SSM: logits of last *valid* token need a re-run on unpadded
                # length; we instead require ssm chunks to be unpadded
                tok = sample(logits, vocab_size=api.cfg.vocab_size,
                             tp_axis=api.pcfg.tp_axis,
                             temperature=self.temperature)
                return tok, new_cache
            rows = KC.gather_slots(cache, slot_ids)
            logits, kv, _ = api.mod.prefill(
                params, tokens, rows, cfg=api.cfg, pcfg=api.pcfg,
                positions=positions, last_idx=last_idx)
            new_cache = KC.insert_chunk(cache, kv, offsets, slot_ids)
            tok = sample(logits, vocab_size=api.cfg.vocab_size,
                         tp_axis=api.pcfg.tp_axis,
                         temperature=self.temperature)
            return tok, new_cache

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P(), P()),
            out_specs=(P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _decode_fn(self):
        key = ("decode",)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, cache, tokens, positions):
            logits, new_cache = api.mod.decode_step(
                params, tokens, cache, cfg=api.cfg, pcfg=api.pcfg,
                positions=positions)
            tok = sample(logits, vocab_size=api.cfg.vocab_size,
                         tp_axis=api.pcfg.tp_axis,
                         temperature=self.temperature)
            return tok, new_cache

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P()),
            out_specs=(P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def add_request(self, req: Request):
        req.arrival_step = self._step_count
        self.sched.add(req)

    def step(self) -> bool:
        """Run one engine iteration. Returns False when idle."""
        plan = self.sched.next_step()
        if plan is None:
            return False
        self._step_count += 1
        self.stats.steps += 1

        if plan.prefill is not None:
            self._run_prefill(*plan.prefill)
        if plan.decode_slots:
            self._run_decode(plan.decode_slots)
        return True

    def run(self, max_steps: int = 100000) -> List[Request]:
        while not self.sched.all_done() and max_steps > 0:
            max_steps -= 1
            if not self.step():
                break
        return self.sched.finished

    # ------------------------------------------------------------------
    def _run_prefill(self, group: List[Request], chunk: int):
        b_sel = len(group)
        if self._is_ssm:
            # ssm chunks must be exact (no pads): shrink to min remainder
            chunk = min(min(len(r.prompt) - r.prefill_pos for r in group),
                        chunk)
        tokens = np.zeros((b_sel, chunk), np.int32)
        positions = np.full((b_sel, chunk), -1, np.int32)
        offsets = np.zeros(b_sel, np.int32)
        last_idx = np.zeros(b_sel, np.int32)
        for i, r in enumerate(group):
            take = min(chunk, len(r.prompt) - r.prefill_pos)
            tokens[i, :take] = r.prompt[r.prefill_pos:r.prefill_pos + take]
            positions[i, :take] = np.arange(r.prefill_pos,
                                            r.prefill_pos + take)
            offsets[i] = r.prefill_pos
            last_idx[i] = take - 1
            r.prefill_pos += take
        slot_ids = np.array([r.slot for r in group], np.int32)

        fn = self._prefill_fn(b_sel, chunk)
        tok, self.cache = fn(self.params, self.cache, jnp.asarray(tokens),
                             jnp.asarray(positions), jnp.asarray(slot_ids),
                             jnp.asarray(offsets), jnp.asarray(last_idx))
        tok = np.asarray(tok)
        self.stats.prefill_tokens += int((positions >= 0).sum())
        for i, r in enumerate(group):
            self._lengths[r.slot] = r.prefill_pos
            if r.prefill_done:
                r.output.append(int(tok[i]))
                r.first_token_step = self._step_count
                r.state = State.DECODE
                self._lengths[r.slot] += 0  # first output not yet in cache
                self._maybe_finish(r)

    def _run_decode(self, slots: List[int]):
        bmax = self.scfg.max_batch
        tokens = np.zeros((bmax, 1), np.int32)
        positions = np.full((bmax, 1), -1, np.int32)
        for r in self.sched.active:
            if r is not None and r.state == State.DECODE:
                tokens[r.slot, 0] = r.output[-1]
                positions[r.slot, 0] = r.length - 1
        fn = self._decode_fn()
        tok, self.cache = fn(self.params, self.cache, jnp.asarray(tokens),
                             jnp.asarray(positions))
        tok = np.asarray(tok)
        self.stats.decode_tokens += len(slots)
        for r in list(self.sched.active):
            if r is not None and r.state == State.DECODE:
                r.output.append(int(tok[r.slot]))
                self._maybe_finish(r)

    def _maybe_finish(self, r: Request):
        if len(r.output) >= r.max_new_tokens:
            self.sched.finish(r, self._step_count)
            self.stats.completed += 1
