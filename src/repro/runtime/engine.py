"""Continuous-batching inference engine (single-host execution; the
multi-pod serve path is exercised via launch/dryrun.py's serve_step).

Supports the transformer families (dense/moe/vlm) and ssm; hybrid and encdec
are served via direct serve-step calls (see launch/dryrun.py) — documented
in DESIGN.md §6.

Per iteration: one decode step over ALL cache slots (inactive slots are
masked via position -1) and/or one rectangular prefill chunk for a group of
admitted requests (Sarathi-style chunked prefill, lengths bucketed to bound
recompilation). TokenWeave activates inside the model whenever the batch
crosses ``tokenweave_min_tokens``.

Two KV-cache backends (SchedulerConfig.paged):

* legacy slots — fixed (L, max_batch, max_len) rows per request; slots are
  invalidated on finish so stale positions never leak into a reused slot.
* paged (runtime/paging.py) — block pool + per-request block tables with
  prefix-cache sharing, LRU eviction, copy-on-write, and recompute
  preemption (DECODE -> WAITING) when the pool runs dry.  Admission and
  chunk accounting charge only prefix-MISS tokens, so the TokenWeave
  min-token threshold sees true compute size.  Transformer families only
  (ssm state is not paged), single host (the shared pool cannot shard over
  the data axis) — DESIGN.md §7.

Speculative decoding (``SchedulerConfig.spec_gamma > 0``, runtime/spec.py):
decode iterations become gamma+1-token verify batches — a pluggable draft
proposes, ONE multi-token forward scores the window, rejection sampling
commits the longest accepted prefix + 1 token, and rejected KV is rolled
back by block-table truncation (paged) or left to the
overwrite-before-query invariant (legacy slots) — DESIGN.md §8.  Greedy
spec output is token-identical to plain greedy decoding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.build import ModelApi
from repro.runtime import kv_cache as KC
from repro.runtime import paging as PG
from repro.runtime import spec as SP
from repro.runtime.paging import BlockManager
from repro.runtime.requests import Request, State
from repro.runtime.sampler import sample
from repro.runtime.scheduler import Scheduler, SchedulerConfig
from repro.runtime.spec import SpecStats


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    completed: int = 0
    spec: SpecStats = dataclasses.field(default_factory=SpecStats)


class Engine:
    def __init__(self, api: ModelApi, mesh, params, scfg: SchedulerConfig,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
                 draft: SP.DraftProposer | None = None, seed: int = 0):
        self.api = api
        self.mesh = mesh
        self.params = params
        self.scfg = scfg
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.stats = EngineStats()
        self._step_count = 0
        self._jit_cache: Dict = {}
        self._pspec = api.specs()
        self._is_ssm = api.cfg.family == "ssm"
        self.paged = bool(scfg.paged)

        self.spec_gamma = int(scfg.spec_gamma)
        self.draft = None
        if self.spec_gamma:
            if self._is_ssm:
                raise ValueError("speculative decoding rolls back KV "
                                 "positions; ssm state has no token axis")
            if not hasattr(api.mod, "verify_step"):
                raise ValueError(
                    f"speculative decoding needs a multi-token verify path; "
                    f"family {api.cfg.family!r} has none")
            if api.pcfg.seq_shard_kv:
                raise ValueError("speculative verify writes full KV rows "
                                 "locally; disable seq_shard_kv")
            if not self.paged and api.cfg.sliding_window:
                raise ValueError(
                    "legacy-slot sliding-window ring buffers cannot hold a "
                    "multi-token verify window (a later write could evict a "
                    "key an earlier query needs); use the paged backend")
            self.draft = draft if draft is not None else SP.make_draft(
                "ngram", self.spec_gamma, ngram=scfg.spec_ngram)
            if self.draft.gamma < self.spec_gamma:
                raise ValueError(
                    f"draft gamma {self.draft.gamma} < scheduler "
                    f"spec_gamma {self.spec_gamma}")
        self._rng_key = jax.random.PRNGKey(seed)

        if self.paged:
            if self._is_ssm:
                raise ValueError("paged KV cache requires attention layers; "
                                 "ssm state caches stay on the slot path")
            self.block_mgr = BlockManager(
                scfg.effective_num_blocks, scfg.block_size,
                scfg.max_blocks_per_req,
                prefix_caching=scfg.prefix_caching)
            cache = PG.init_paged_cache(scfg.effective_num_blocks,
                                        scfg.block_size, api.cfg, api.tp,
                                        api.pcfg)
            cspec = PG.paged_cache_specs(api.cfg, api.pcfg)
        else:
            self.block_mgr = None
            cache = api.init_cache(scfg.max_batch, scfg.max_len)
            cspec = api.cache_specs()
        self.sched = Scheduler(scfg, block_mgr=self.block_mgr)
        self.cache = jax.device_put(
            cache, jax.tree.map(lambda s: NamedSharding(mesh, s), cspec,
                                is_leaf=lambda s: isinstance(s, P)))
        self._cspec = cspec

    def _next_key(self):
        """Per-dispatch PRNG key: one deterministic stream (seeded at
        construction) feeds prefill, decode, and verify sampling alike, so
        stochastic runs are reproducible for a fixed request order."""
        self._rng_key, k = jax.random.split(self._rng_key)
        return k

    # ------------------------------------------------------------------
    # jitted step functions
    # ------------------------------------------------------------------
    def _prefill_fn(self, b_sel: int, chunk: int):
        key = ("prefill", b_sel, chunk)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, cache, tokens, positions, slot_ids, offsets,
               last_idx, rng):
            if self._is_ssm:
                rows = jax.tree.map(lambda c: c[:, slot_ids], cache)
                # fresh requests (offset 0) start from zero state
                fresh = offsets == 0

                def zero_fresh(c):
                    m = fresh.reshape((1, -1) + (1,) * (c.ndim - 2))
                    return jnp.where(m, jnp.zeros_like(c), c)
                rows = jax.tree.map(zero_fresh, rows)
                logits, new_rows, _ = api.mod.prefill(
                    params, tokens, rows, cfg=api.cfg, pcfg=api.pcfg,
                    positions=positions, last_idx=last_idx)
                new_cache = jax.tree.map(
                    lambda c, r: c.at[:, slot_ids].set(r), cache, new_rows)
                # SSM: logits of last *valid* token need a re-run on unpadded
                # length; we instead require ssm chunks to be unpadded
                tok = sample(logits, vocab_size=api.cfg.vocab_size,
                             tp_axis=api.pcfg.tp_axis,
                             temperature=self.temperature,
                             top_k=self.top_k, top_p=self.top_p, key=rng)
                return tok, new_cache
            rows = KC.gather_slots(cache, slot_ids)
            logits, kv, _ = api.mod.prefill(
                params, tokens, rows, cfg=api.cfg, pcfg=api.pcfg,
                positions=positions, last_idx=last_idx)
            new_cache = KC.insert_chunk(cache, kv, offsets, slot_ids)
            tok = sample(logits, vocab_size=api.cfg.vocab_size,
                         tp_axis=api.pcfg.tp_axis,
                         temperature=self.temperature,
                         top_k=self.top_k, top_p=self.top_p, key=rng)
            return tok, new_cache

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P(), P(),
                      P()),
            out_specs=(P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _paged_prefill_fn(self, b_sel: int, chunk: int):
        key = ("pprefill", b_sel, chunk)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, pool, tokens, positions, block_tables, last_idx,
               rng):
            # rectangular context view through the block-table indirection;
            # the model's prefill path is backend-agnostic (rows look
            # exactly like gathered slot rows)
            rows = PG.gather_block_rows(pool, block_tables)
            logits, kv, _ = api.mod.prefill(
                params, tokens, rows, cfg=api.cfg, pcfg=api.pcfg,
                positions=positions, last_idx=last_idx)
            new_pool = PG.insert_chunk_paged(pool, kv, block_tables)
            tok = sample(logits, vocab_size=api.cfg.vocab_size,
                         tp_axis=api.pcfg.tp_axis,
                         temperature=self.temperature,
                         top_k=self.top_k, top_p=self.top_p, key=rng)
            return tok, new_pool

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P(), P()),
            out_specs=(P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _decode_fn(self):
        key = ("decode",)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, cache, tokens, positions, rng):
            logits, new_cache = api.mod.decode_step(
                params, tokens, cache, cfg=api.cfg, pcfg=api.pcfg,
                positions=positions)
            tok = sample(logits, vocab_size=api.cfg.vocab_size,
                         tp_axis=api.pcfg.tp_axis,
                         temperature=self.temperature,
                         top_k=self.top_k, top_p=self.top_p, key=rng)
            return tok, new_cache

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P()),
            out_specs=(P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _paged_decode_fn(self):
        key = ("pdecode",)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, pool, tokens, positions, block_tables, rng):
            logits, new_pool = api.mod.decode_step(
                params, tokens, pool, cfg=api.cfg, pcfg=api.pcfg,
                positions=positions, block_tables=block_tables)
            tok = sample(logits, vocab_size=api.cfg.vocab_size,
                         tp_axis=api.pcfg.tp_axis,
                         temperature=self.temperature,
                         top_k=self.top_k, top_p=self.top_p, key=rng)
            return tok, new_pool

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P()),
            out_specs=(P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _verify_fn(self, s_v: int):
        """Jitted speculative verify over the legacy slot cache: one
        multi-token decode forward + on-device rejection sampling."""
        key = ("verify", s_v)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, cache, tokens, positions, draft, rng):
            logits, new_cache = api.verify_step(params, tokens, cache,
                                                positions)
            n_acc, emit = SP.verify_tokens(
                logits, draft, rng, vocab_size=api.cfg.vocab_size,
                tp_axis=api.pcfg.tp_axis, temperature=self.temperature,
                top_k=self.top_k, top_p=self.top_p)
            return n_acc, emit, new_cache

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P()),
            out_specs=(P(), P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    def _paged_verify_fn(self, s_v: int):
        key = ("pverify", s_v)
        if key in self._jit_cache:
            return self._jit_cache[key]
        api = self.api

        def fn(params, pool, tokens, positions, block_tables, draft, rng):
            logits, new_pool = api.verify_step(params, tokens, pool,
                                               positions,
                                               block_tables=block_tables)
            n_acc, emit = SP.verify_tokens(
                logits, draft, rng, vocab_size=api.cfg.vocab_size,
                tp_axis=api.pcfg.tp_axis, temperature=self.temperature,
                top_k=self.top_k, top_p=self.top_p)
            return n_acc, emit, new_pool

        sm = jax.shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec, self._cspec, P(), P(), P(), P(), P()),
            out_specs=(P(), P(), self._cspec), check_vma=False)
        jfn = jax.jit(sm, donate_argnums=(1,))
        self._jit_cache[key] = jfn
        return jfn

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def add_request(self, req: Request):
        if len(req.prompt) + 1 > self.scfg.max_len:
            # the prompt plus at least one decode slot must fit the cache;
            # legacy slots would silently ring-wrap, paged tables would
            # overflow — reject loudly instead
            raise ValueError(
                f"prompt length {len(req.prompt)} + 1 exceeds max_len "
                f"{self.scfg.max_len} (rid={req.rid})")
        if self.paged:
            need = self.block_mgr.blocks_needed(len(req.prompt)) + 1
            if need > self.scfg.effective_num_blocks:
                # even an otherwise-empty pool could never admit it
                raise ValueError(
                    f"prompt needs {need} blocks but the pool has only "
                    f"{self.scfg.effective_num_blocks} (rid={req.rid})")
        req.arrival_step = self._step_count
        self.sched.add(req)

    def step(self) -> bool:
        """Run one engine iteration. Returns False when idle."""
        plan = self.sched.next_step()
        if plan is None:
            return False
        self._step_count += 1
        self.stats.steps += 1

        if plan.prefill is not None:
            self._run_prefill(*plan.prefill)
        if plan.decode_slots:
            if self.spec_gamma:
                self._run_verify()
            else:
                self._run_decode()
        return True

    def run(self, max_steps: int = 100000) -> List[Request]:
        while not self.sched.all_done() and max_steps > 0:
            max_steps -= 1
            if not self.step():
                if self.sched.waiting:
                    # nothing active and the queue head cannot be admitted:
                    # permanently stuck (e.g. a preempted request whose
                    # regrown context outgrew the pool) — surface it rather
                    # than silently dropping the request
                    rids = [r.rid for r in self.sched.waiting]
                    raise RuntimeError(
                        f"engine idle with unservable waiting request(s) "
                        f"{rids}: block pool too small for their context")
                break
        return self.sched.finished

    # ------------------------------------------------------------------
    # paged-cache plumbing
    # ------------------------------------------------------------------
    def _apply_fixups(self):
        """Drain queued device-side pool maintenance: pos resets of
        recycled blocks FIRST (a reset target may since have been handed
        out again — its new owner writes later, and a COW destination is
        overwritten entirely by its copy), then copy-on-write copies."""
        resets = self.block_mgr.take_pending_resets()
        copies = self.block_mgr.take_pending_copies()
        if resets:
            self.cache = PG.reset_blocks(self.cache, resets)
        if copies:
            self.cache = PG.copy_blocks(self.cache, copies)

    def _preempt(self, victim: Request):
        self.block_mgr.free_request(victim.rid)
        self.block_mgr.stats.preemptions += 1
        self.sched.preempt(victim)

    def _ensure_decode_blocks(self) -> List[Request]:
        """Grow/COW the write-target block of every DECODE request; on
        pool exhaustion preempt the youngest DECODE request (recompute
        mode) and retry.  Returns the surviving decode batch."""
        def decoding():
            return [r for r in self.sched.active
                    if r is not None and r.state == State.DECODE]
        for r in decoding():
            if r.length - 1 >= self.scfg.max_len:
                # context hit the cache ceiling: stop generating early
                # (truncated output) rather than overflow the block table
                self._finish(r)
        for r in sorted(decoding(), key=lambda r: (r.arrival_step, r.rid)):
            while r.state == State.DECODE:
                if self.block_mgr.ensure_writable(r.rid, r.length - 1):
                    break
                victims = decoding()
                victim = max(victims, key=lambda v: (v.arrival_step, v.rid))
                self._preempt(victim)   # may be r itself -> loop exits
        return decoding()

    # ------------------------------------------------------------------
    def _run_prefill(self, group: List[Request], chunk: int):
        b_sel = len(group)
        if self._is_ssm:
            # ssm chunks must be exact (no pads): shrink to min remainder
            chunk = min(min(len(r.context_tokens) - r.prefill_pos
                            for r in group), chunk)
        tokens = np.zeros((b_sel, chunk), np.int32)
        positions = np.full((b_sel, chunk), -1, np.int32)
        offsets = np.zeros(b_sel, np.int32)
        last_idx = np.zeros(b_sel, np.int32)
        for i, r in enumerate(group):
            ctx = r.context_tokens
            take = min(chunk, len(ctx) - r.prefill_pos)
            tokens[i, :take] = ctx[r.prefill_pos:r.prefill_pos + take]
            positions[i, :take] = np.arange(r.prefill_pos,
                                            r.prefill_pos + take)
            offsets[i] = r.prefill_pos
            last_idx[i] = take - 1
            r.prefill_pos += take

        if self.paged:
            self._apply_fixups()
            bt = np.stack([self.block_mgr.table_array(r.rid) for r in group])
            fn = self._paged_prefill_fn(b_sel, chunk)
            tok, self.cache = fn(self.params, self.cache,
                                 jnp.asarray(tokens), jnp.asarray(positions),
                                 jnp.asarray(bt), jnp.asarray(last_idx),
                                 self._next_key())
        else:
            slot_ids = np.array([r.slot for r in group], np.int32)
            fn = self._prefill_fn(b_sel, chunk)
            tok, self.cache = fn(self.params, self.cache,
                                 jnp.asarray(tokens), jnp.asarray(positions),
                                 jnp.asarray(slot_ids), jnp.asarray(offsets),
                                 jnp.asarray(last_idx), self._next_key())
        tok = np.asarray(tok)
        self.stats.prefill_tokens += int((positions >= 0).sum())
        for i, r in enumerate(group):
            if self.paged:
                self.block_mgr.register_filled(r.rid, r.context_tokens,
                                               r.prefill_pos)
            if r.prefill_done:
                if r.resumed:
                    # recompute-readmission: output[-1] is still the
                    # pending decode input; the chunk's sample duplicates
                    # a token we already emitted — drop it
                    r.resumed = False
                else:
                    r.output.append(int(tok[i]))
                    r.first_token_step = self._step_count
                r.state = State.DECODE
                self._maybe_finish(r)

    def _run_decode(self):
        if self.paged:
            reqs = self._ensure_decode_blocks()
            if not reqs:
                return
            self._apply_fixups()
        else:
            reqs = [r for r in self.sched.active
                    if r is not None and r.state == State.DECODE]
        bmax = self.scfg.max_batch
        tokens = np.zeros((bmax, 1), np.int32)
        positions = np.full((bmax, 1), -1, np.int32)
        for r in reqs:
            tokens[r.slot, 0] = r.output[-1]
            positions[r.slot, 0] = r.length - 1

        if self.paged:
            bt = np.full((bmax, self.scfg.max_blocks_per_req), -1, np.int32)
            for r in reqs:
                bt[r.slot] = self.block_mgr.table_array(r.rid)
            fn = self._paged_decode_fn()
            tok, self.cache = fn(self.params, self.cache,
                                 jnp.asarray(tokens), jnp.asarray(positions),
                                 jnp.asarray(bt), self._next_key())
        else:
            fn = self._decode_fn()
            tok, self.cache = fn(self.params, self.cache,
                                 jnp.asarray(tokens), jnp.asarray(positions),
                                 self._next_key())
        tok = np.asarray(tok)
        self.stats.decode_tokens += len(reqs)
        for r in list(reqs):
            n_written = r.length  # positions [0, length-1] now in cache
            r.output.append(int(tok[r.slot]))
            if self.paged and n_written % self.scfg.block_size == 0:
                # a block just filled: make it hittable for future prompts
                self.block_mgr.register_filled(
                    r.rid, r.prompt + r.output[:-1], n_written)
            self._maybe_finish(r)

    # ------------------------------------------------------------------
    # speculative decoding (runtime/spec.py, DESIGN.md §8)
    # ------------------------------------------------------------------
    def _grow_for_draft(self, r: Request, want: int) -> int:
        """Best-effort paged-block growth for the draft positions
        ``length .. length-1+want``; on allocation failure the draft is
        SHRUNK (draft tokens are optional) instead of preempting a peer.
        Returns the number of draft tokens whose KV cell is writable."""
        for j in range(1, want + 1):
            if not self.block_mgr.ensure_writable(r.rid, r.length - 1 + j):
                return j - 1
        return want

    def _run_verify(self):
        """One speculative iteration over every DECODE request: draft
        gamma tokens, run ONE gamma+1-token verify forward, commit the
        longest accepted prefix + 1 corrected/bonus token, and roll back
        the rejected suffix (paged: block-table truncation)."""
        gamma = self.spec_gamma
        if self.paged:
            reqs = self._ensure_decode_blocks()   # input cell is mandatory
            if not reqs:
                return
        else:
            reqs = [r for r in self.sched.active
                    if r is not None and r.state == State.DECODE]
            if not reqs:
                return

        props = self.draft.propose(
            [r.prompt + r.output for r in reqs])
        capped: Dict[int, List[int]] = {}
        for r, prop in zip(reqs, props):
            # never draft past max_new_tokens (the verify always commits
            # >= 1 extra token) or the cache ceiling
            cap = min(gamma, r.max_new_tokens - len(r.output) - 1,
                      self.scfg.max_len - r.length)
            prop = list(prop[:max(cap, 0)])
            if self.paged and prop:
                prop = prop[:self._grow_for_draft(r, len(prop))]
            capped[r.rid] = prop
        if not any(capped.values()):
            # nothing drafted anywhere: a gamma+1-wide verify would pay
            # (gamma+1)x decode compute to commit one token per request —
            # take the plain single-token decode step instead
            self._run_decode()
            return
        if self.paged:
            self._apply_fixups()

        bmax = self.scfg.max_batch
        s_v = gamma + 1
        tokens = np.zeros((bmax, s_v), np.int32)
        positions = np.full((bmax, s_v), -1, np.int32)
        draft = np.full((bmax, gamma), -1, np.int32)
        for r in reqs:
            prop = capped[r.rid]
            tokens[r.slot, 0] = r.output[-1]
            positions[r.slot, 0] = r.length - 1
            for j, d in enumerate(prop):
                tokens[r.slot, 1 + j] = d
                positions[r.slot, 1 + j] = r.length + j
                draft[r.slot, j] = d

        rng = self._next_key()
        if self.paged:
            bt = np.full((bmax, self.scfg.max_blocks_per_req), -1, np.int32)
            for r in reqs:
                bt[r.slot] = self.block_mgr.table_array(r.rid)
            fn = self._paged_verify_fn(s_v)
            n_acc, emit, self.cache = fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(positions), jnp.asarray(bt), jnp.asarray(draft),
                rng)
        else:
            fn = self._verify_fn(s_v)
            n_acc, emit, self.cache = fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(positions), jnp.asarray(draft), rng)
        n_acc = np.asarray(n_acc)
        emit = np.asarray(emit)

        st = self.stats.spec
        st.verify_steps += 1
        for r in list(reqs):
            prop = capped[r.rid]
            n = min(int(n_acc[r.slot]), len(prop))
            base_len = r.length          # L: window wrote L-1 .. L-1+|prop|
            r.output.extend(prop[:n] + [int(emit[r.slot])])
            st.draft_proposed += len(prop)
            st.draft_accepted += n
            st.emitted += n + 1
            self.stats.decode_tokens += n + 1
            if self.paged:
                # rollback: keep exactly the blocks covering the committed
                # context (positions 0 .. L-1+n); rejected draft KV beyond
                # them is dropped with the tail blocks, never copied
                self.block_mgr.truncate(r.rid, base_len + n)
                self.block_mgr.register_filled(
                    r.rid, r.prompt + r.output[:-1], base_len + n)
            self._maybe_finish(r)

    def _maybe_finish(self, r: Request):
        if len(r.output) >= r.max_new_tokens:
            self._finish(r)

    def _finish(self, r: Request):
        if self.paged:
            # final registration, then drop refs: cached blocks park in
            # the LRU (still prefix-hittable), private ones recycle
            self.block_mgr.register_filled(
                r.rid, r.prompt + r.output[:-1], r.length - 1)
            self.block_mgr.free_request(r.rid)
        elif not self._is_ssm:
            # release slot state: stale ring-buffer positions from a
            # finished request must not leak into the slot's next owner
            self.cache = KC.reset_slots(self.cache, np.asarray([r.slot]))
        self.sched.finish(r, self._step_count)
        self.stats.completed += 1
