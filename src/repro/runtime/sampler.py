"""Token sampling over vocab-sharded logits, inside shard_map
(DESIGN.md §6 shared machinery; top-k/top-p feed the speculative-decoding
target distribution, DESIGN.md §8).

Everything here operates on the LOCAL vocab shard ``(B, S, V_loc)`` and
composes cross-shard collectives (pmax/psum/all_gather) instead of ever
materializing the full vocabulary on one shard:

* greedy / Gumbel-max sampling -> ``sharded_argmax`` (tie-break to the
  smallest id, deterministic across shards);
* top-k -> the global k-th largest logit is found by all_gathering only the
  per-shard top-k candidates (k*tp scalars, not V);
* top-p (nucleus) -> the probability threshold is found by a fixed-depth
  bisection on psum'd kept-mass (the nucleus set equals {p >= t*} where t*
  is the probability of the token that crosses the cumulative target, so
  thresholding reproduces the sorted-cumsum definition without a global
  sort; the bisection resolves t* to ~2^-30 of the max probability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.embedding import sharded_argmax

NEG_INF = jnp.float32(-1e30)


def _mask_vocab_pad(local_logits, *, vocab_size: int, tp_axis: str):
    """f32 local logits with the padded vocab tail forced to -inf."""
    v_loc = local_logits.shape[-1]
    lo = lax.axis_index(tp_axis) * v_loc
    col = lo + jnp.arange(v_loc)
    shape = (1,) * (local_logits.ndim - 1) + (v_loc,)
    return jnp.where((col < vocab_size).reshape(shape),
                     local_logits.astype(jnp.float32), NEG_INF)


def apply_top_k(local_logits, k: int, *, tp_axis: str = "model"):
    """Mask local logits below the global k-th largest value to -inf.

    Cross-shard cost: one all_gather of min(k, V_loc) candidates per shard.
    Ties at the threshold are all kept (the set may exceed k on exact ties).
    """
    if k <= 0:
        return local_logits
    v_loc = local_logits.shape[-1]
    k_loc = min(k, v_loc)
    cand, _ = lax.top_k(local_logits, k_loc)          # (..., k_loc)
    cand = lax.all_gather(cand, tp_axis, axis=-1, tiled=True)
    k_eff = min(k, cand.shape[-1])
    thresh = lax.top_k(cand, k_eff)[0][..., -1:]      # global k-th value
    return jnp.where(local_logits >= thresh, local_logits, NEG_INF)


def apply_top_p(local_logits, p: float, *, tp_axis: str = "model",
                iters: int = 30):
    """Nucleus filtering: keep the smallest set of tokens whose probability
    mass reaches ``p`` (the crossing token included), masked to -inf
    elsewhere.  Implemented as a bisection for the largest probability
    threshold t with mass{prob >= t} >= p — one psum per iteration, no
    full-vocab materialization or global sort.
    """
    if p >= 1.0:
        return local_logits
    lg = local_logits.astype(jnp.float32)
    m = lax.pmax(jnp.max(lg, axis=-1), tp_axis)               # (...,)
    e = jnp.exp(lg - m[..., None])
    z = lax.psum(jnp.sum(e, axis=-1), tp_axis)
    prob = e / z[..., None]
    pmax = lax.pmax(jnp.max(prob, axis=-1), tp_axis)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        mass = lax.psum(
            jnp.sum(jnp.where(prob >= mid[..., None], prob, 0.0), axis=-1),
            tp_axis)
        ok = mass >= p                 # threshold still admissible -> raise
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo0 = jnp.zeros_like(pmax)
    lo, _ = lax.fori_loop(0, iters, body, (lo0, pmax))
    return jnp.where(prob >= lo[..., None], local_logits, NEG_INF)


def filtered_logits(local_logits, *, vocab_size: int, tp_axis: str = "model",
                    temperature: float = 1.0, top_k: int = 0,
                    top_p: float = 1.0):
    """Target-distribution logits: temperature scaling, then top-k, then
    top-p, with the padded vocab tail masked throughout.  f32 output."""
    lg = _mask_vocab_pad(local_logits, vocab_size=vocab_size, tp_axis=tp_axis)
    if temperature > 0.0:
        lg = lg / temperature
    lg = apply_top_k(lg, top_k, tp_axis=tp_axis)
    lg = apply_top_p(lg, top_p, tp_axis=tp_axis)
    return lg


def gumbel_argmax(local_logits, key, *, vocab_size: int,
                  tp_axis: str = "model"):
    """One Gumbel-max draw per row from (already filtered) local logits.
    ``key`` must be identical on every shard; it is folded per shard so the
    noise stays iid across the global vocab."""
    shard_key = jax.random.fold_in(key, lax.axis_index(tp_axis))
    g = jax.random.gumbel(shard_key, local_logits.shape, jnp.float32)
    return sharded_argmax(local_logits.astype(jnp.float32) + g,
                          vocab_size=vocab_size, tp_axis=tp_axis)


def sample(local_logits, *, vocab_size: int, tp_axis: str = "model",
           temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
           key=None):
    """local_logits: (B, 1, V_loc) -> token ids (B,).

    temperature == 0 -> greedy (deterministic tie-break). Stochastic sampling
    applies temperature/top-k/top-p filtering and then the Gumbel-max trick,
    so it composes with the sharded argmax without materializing full logits
    on any shard.
    """
    if temperature <= 0.0:
        return sharded_argmax(local_logits, vocab_size=vocab_size,
                              tp_axis=tp_axis)[:, 0]
    lg = filtered_logits(local_logits, vocab_size=vocab_size, tp_axis=tp_axis,
                         temperature=temperature, top_k=top_k, top_p=top_p)
    return gumbel_argmax(lg, key, vocab_size=vocab_size,
                         tp_axis=tp_axis)[:, 0]
