"""Token sampling over vocab-sharded logits (inside shard_map)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.embedding import sharded_argmax


def sample(local_logits, *, vocab_size: int, tp_axis: str = "model",
           temperature: float = 0.0, key=None):
    """local_logits: (B, 1, V_loc) -> token ids (B,).

    temperature == 0 -> greedy (deterministic tie-break). Stochastic sampling
    uses the Gumbel-max trick so it composes with the sharded argmax without
    materializing full logits on any shard.
    """
    if temperature <= 0.0:
        return sharded_argmax(local_logits, vocab_size=vocab_size,
                              tp_axis=tp_axis)[:, 0]
    v_loc = local_logits.shape[-1]
    lo = lax.axis_index(tp_axis) * v_loc
    # per-shard fold of the key keeps gumbels iid across the global vocab
    shard_key = jax.random.fold_in(key, lax.axis_index(tp_axis))
    g = jax.random.gumbel(shard_key, local_logits.shape, jnp.float32)
    perturbed = local_logits.astype(jnp.float32) / temperature + g
    return sharded_argmax(perturbed, vocab_size=vocab_size,
                          tp_axis=tp_axis)[:, 0]
