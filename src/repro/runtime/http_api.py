"""OpenAI-style streaming API over ``OnlineServer`` (DESIGN.md §15).

A stdlib-only asyncio frontend that turns the deterministic serving loop
into a live network service:

* ``POST /v1/completions`` — submit a prompt; ``"stream": true`` answers
  with server-sent events (one ``data:`` line per token as the engine
  commits it, terminated by ``data: [DONE]``), otherwise a single JSON
  body once the request finishes.
* ``GET /v1/stream`` — the same token feed over a minimal RFC6455
  websocket (one JSON text frame per token event).
* ``GET /v1/health`` / ``GET /v1/stats`` — liveness and the counters the
  end-to-end tests poll (completed / cancelled / block-pool quiescence).

Token events ride the ``on_token`` callbacks ``OnlineServer.pump``
already fires: the pump task interleaves single engine steps with the
event loop, so streaming writes happen between steps and every
connection sees tokens in commit order.  A client disconnect (EOF on the
connection) cancels its request through ``OnlineServer.cancel`` →
``Engine.abort``, releasing the slot and paged blocks — the mid-stream
disconnect test asserts the pool sweeps clean afterwards.

Everything engine-side stays virtual-time deterministic: wall time only
decides WHEN the pump runs, never what any step computes, so streamed
tokens are identical to the offline engine on the same prompts (pinned
by tests/test_server.py).
"""
from __future__ import annotations

import asyncio
import base64
import hashlib
import itertools
import json
from typing import Dict, List, Optional, Tuple

from repro.runtime.requests import Request, State
from repro.runtime.server import OnlineServer, ServerConfig

_WS_GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"


def _ws_accept(key: str) -> str:
    digest = hashlib.sha1((key + _WS_GUID).encode("ascii")).digest()
    return base64.b64encode(digest).decode("ascii")


def ws_frame(opcode: int, payload: bytes) -> bytes:
    """One unmasked (server->client) websocket frame, FIN set."""
    head = bytes([0x80 | opcode])
    n = len(payload)
    if n < 126:
        head += bytes([n])
    elif n < (1 << 16):
        head += bytes([126]) + n.to_bytes(2, "big")
    else:
        head += bytes([127]) + n.to_bytes(8, "big")
    return head + payload


async def ws_read(reader: asyncio.StreamReader) -> Tuple[int, bytes]:
    """Read one frame; client->server frames are masked per RFC6455."""
    head = await reader.readexactly(2)
    opcode = head[0] & 0x0F
    masked = bool(head[1] & 0x80)
    n = head[1] & 0x7F
    if n == 126:
        n = int.from_bytes(await reader.readexactly(2), "big")
    elif n == 127:
        n = int.from_bytes(await reader.readexactly(8), "big")
    mask = await reader.readexactly(4) if masked else b""
    payload = await reader.readexactly(n)
    if masked:
        payload = bytes(b ^ mask[i % 4] for i, b in enumerate(payload))
    return opcode, payload


class ApiServer:
    """One engine, one event loop: HTTP/websocket handlers and the pump
    task share the loop thread, so no locking guards the engine — handler
    code runs only between pump iterations (engine steps are atomic).

    ``step_delay`` (wall seconds slept after each engine step) paces the
    pump so tests can connect, observe partial streams, and disconnect
    mid-generation deterministically-enough; 0 serves at full speed."""

    def __init__(self, engine, cfg: Optional[ServerConfig] = None,
                 step_delay: float = 0.0):
        self.engine = engine
        self.srv = OnlineServer(engine, cfg)
        self.step_delay = step_delay
        self._rids = itertools.count()
        self._live: Dict[int, asyncio.Queue] = {}   # rid -> event queue
        self._server: Optional[asyncio.base_events.Server] = None

    # ------------------------------------------------------------------
    # pump task: the serving loop, one step per loop visit
    # ------------------------------------------------------------------
    def _on_token(self, rid: int):
        def cb(req: Request, tok: int, t: float) -> None:
            q = self._live.get(rid)
            if q is not None:
                q.put_nowait(("token", int(tok), float(t)))
        return cb

    def _notify_done(self) -> None:
        for rid in list(self._live):
            req = self.srv._by_rid.get(rid)
            if req is not None and req.state == State.DONE:
                self._live[rid].put_nowait(
                    ("done", req.finish_reason or "stop", self.srv.clock))
                del self._live[rid]

    async def _pump_loop(self) -> None:
        while True:
            stepped = self.srv.pump(max_steps=1)
            self._notify_done()
            # yield to connection handlers; idle-poll a little slower
            await asyncio.sleep(self.step_delay if stepped
                                else max(self.step_delay, 0.002))

    # ------------------------------------------------------------------
    # request admission / teardown (handlers call these between pumps)
    # ------------------------------------------------------------------
    def _submit(self, body: dict) -> Tuple[Request, asyncio.Queue]:
        prompt = body.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) for t in prompt)):
            raise ValueError("'prompt' must be a non-empty list of ints")
        max_new = int(body.get("max_new_tokens", 16))
        req = Request(rid=next(self._rids), prompt=list(prompt),
                      max_new_tokens=max_new,
                      arrival_time=self.srv.clock)
        if body.get("deadline") is not None:
            req.deadline = float(body["deadline"])
        q: asyncio.Queue = asyncio.Queue()
        self.srv.submit(req, on_token=self._on_token(req.rid))
        self._live[req.rid] = q
        return req, q

    def _disconnect(self, req: Request) -> None:
        """Client went away mid-stream: abort the request (releasing its
        slot and paged blocks) unless it already finished."""
        self._live.pop(req.rid, None)
        if req.state != State.DONE:
            self.srv.cancel(req.rid)

    # ------------------------------------------------------------------
    # HTTP plumbing
    # ------------------------------------------------------------------
    async def _read_request(self, reader) -> Optional[Tuple[str, str, dict,
                                                            bytes]]:
        line = await reader.readline()
        if not line:
            return None
        try:
            method, path, _ = line.decode("ascii").split(None, 2)
        except ValueError:
            return None
        headers: Dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, val = h.decode("latin-1").partition(":")
            headers[name.strip().lower()] = val.strip()
        body = b""
        n = int(headers.get("content-length", 0) or 0)
        if n:
            body = await reader.readexactly(n)
        return method, path, headers, body

    @staticmethod
    def _http(status: str, ctype: str, payload: bytes,
              extra: str = "") -> bytes:
        return (f"HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n"
                f"Content-Length: {len(payload)}\r\n{extra}"
                f"Connection: close\r\n\r\n").encode("ascii") + payload

    def _json(self, obj, status: str = "200 OK") -> bytes:
        return self._http(status, "application/json",
                          json.dumps(obj).encode("utf-8"))

    def _stats(self) -> dict:
        eng = self.engine
        mgr = eng.block_mgr
        leaked = ([b for b in range(mgr.alloc.num_blocks) if mgr.alloc.ref[b]]
                  if mgr is not None else [])
        return {"clock": self.srv.clock,
                "submitted": len(self.srv.requests),
                "completed": len(self.srv.completed),
                "aborted": len(self.srv.aborted),
                "cancelled": int(eng.stats.cancelled),
                "live_streams": len(self._live),
                "tables": (len(mgr.tables) if mgr is not None else 0),
                "leaked_blocks": len(leaked)}

    # ------------------------------------------------------------------
    # handlers
    # ------------------------------------------------------------------
    async def _stream_events(self, reader, writer, req: Request,
                             q: asyncio.Queue, send) -> None:
        """Drain the request's event queue through ``send`` (SSE or
        websocket framing), racing against connection EOF; EOF or a write
        failure cancels the request."""
        eof = asyncio.ensure_future(reader.read(1))
        try:
            while True:
                get = asyncio.ensure_future(q.get())
                done, _ = await asyncio.wait(
                    {get, eof}, return_when=asyncio.FIRST_COMPLETED)
                if eof in done:
                    get.cancel()
                    self._disconnect(req)
                    return
                ev = get.result()
                try:
                    await send(ev)
                except (ConnectionError, OSError):
                    self._disconnect(req)
                    return
                if ev[0] == "done":
                    return
        finally:
            if not eof.done():
                eof.cancel()

    async def _handle_completions(self, reader, writer, body: bytes) -> None:
        try:
            obj = json.loads(body.decode("utf-8"))
            req, q = self._submit(obj)
        except (ValueError, KeyError) as e:
            writer.write(self._json({"error": str(e)}, "400 Bad Request"))
            await writer.drain()
            return
        if not obj.get("stream"):
            # block until the pump finishes the request, then answer once
            while True:
                ev = await q.get()
                if ev[0] == "done":
                    break
            writer.write(self._json(
                {"rid": req.rid, "tokens": list(req.output),
                 "finish_reason": req.finish_reason,
                 "ttft": req.ttft, "e2e": req.e2e_latency}))
            await writer.drain()
            return
        writer.write(("HTTP/1.1 200 OK\r\n"
                      "Content-Type: text/event-stream\r\n"
                      "Cache-Control: no-cache\r\n"
                      "Connection: close\r\n\r\n").encode("ascii"))
        await writer.drain()

        async def send(ev):
            if ev[0] == "token":
                data = json.dumps({"rid": req.rid, "token": ev[1],
                                   "t": ev[2]})
            else:
                data = json.dumps({"rid": req.rid, "done": True,
                                   "finish_reason": ev[1]})
            writer.write(f"data: {data}\n\n".encode("utf-8"))
            if ev[0] == "done":
                writer.write(b"data: [DONE]\n\n")
            await writer.drain()

        await self._stream_events(reader, writer, req, q, send)

    async def _handle_websocket(self, reader, writer,
                                headers: Dict[str, str]) -> None:
        key = headers.get("sec-websocket-key", "")
        writer.write((f"HTTP/1.1 101 Switching Protocols\r\n"
                      f"Upgrade: websocket\r\nConnection: Upgrade\r\n"
                      f"Sec-WebSocket-Accept: {_ws_accept(key)}\r\n\r\n"
                      ).encode("ascii"))
        await writer.drain()
        try:
            opcode, payload = await ws_read(reader)
        except (asyncio.IncompleteReadError, ConnectionError, OSError):
            return
        if opcode != 0x1:          # expect one text frame with the request
            writer.write(ws_frame(0x8, b""))
            await writer.drain()
            return
        try:
            req, q = self._submit(json.loads(payload.decode("utf-8")))
        except (ValueError, KeyError) as e:
            writer.write(ws_frame(
                0x1, json.dumps({"error": str(e)}).encode("utf-8")))
            writer.write(ws_frame(0x8, b""))
            await writer.drain()
            return

        async def send(ev):
            if ev[0] == "token":
                data = {"rid": req.rid, "token": ev[1], "t": ev[2]}
            else:
                data = {"rid": req.rid, "done": True,
                        "finish_reason": ev[1]}
            writer.write(ws_frame(0x1, json.dumps(data).encode("utf-8")))
            if ev[0] == "done":
                writer.write(ws_frame(0x8, b""))
            await writer.drain()

        await self._stream_events(reader, writer, req, q, send)

    async def _handle_conn(self, reader, writer) -> None:
        try:
            parsed = await self._read_request(reader)
            if parsed is None:
                return
            method, path, headers, body = parsed
            if (method, path) == ("POST", "/v1/completions"):
                await self._handle_completions(reader, writer, body)
            elif (method, path) == ("GET", "/v1/stream") and \
                    "websocket" in headers.get("upgrade", "").lower():
                await self._handle_websocket(reader, writer, headers)
            elif (method, path) == ("GET", "/v1/health"):
                writer.write(self._json({"ok": True,
                                         "clock": self.srv.clock}))
                await writer.drain()
            elif (method, path) == ("GET", "/v1/stats"):
                writer.write(self._json(self._stats()))
                await writer.drain()
            else:
                writer.write(self._json({"error": f"no route "
                                         f"{method} {path}"},
                                        "404 Not Found"))
                await writer.drain()
        except (asyncio.IncompleteReadError, ConnectionError, OSError):
            pass
        finally:
            try:
                writer.close()
            except OSError:
                pass

    # ------------------------------------------------------------------
    async def serve(self, host: str = "127.0.0.1", port: int = 0,
                    on_ready=None) -> None:
        pump = asyncio.ensure_future(self._pump_loop())
        self._server = await asyncio.start_server(self._handle_conn,
                                                  host, port)
        bound = self._server.sockets[0].getsockname()
        if on_ready is not None:
            on_ready(bound[0], bound[1])
        try:
            async with self._server:
                await self._server.serve_forever()
        except asyncio.CancelledError:
            pass
        finally:
            pump.cancel()


def main(argv: Optional[List[str]] = None) -> None:
    """``python -m repro.runtime.http_api --port 0 [--spec JSON]`` —
    serve one engine over the streaming API.  Prints ``LISTENING <host>
    <port>`` once bound (the e2e test harness parses it)."""
    import argparse

    from repro.runtime.transport import build_engine_from_spec

    p = argparse.ArgumentParser(description=main.__doc__)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0)
    p.add_argument("--spec", default="{}",
                   help="JSON engine spec merged over transport.DEFAULT_SPEC")
    p.add_argument("--step-delay", type=float, default=0.0,
                   help="wall seconds slept after each engine step (lets "
                        "tests observe and interrupt partial streams)")
    args = p.parse_args(argv)

    api = ApiServer(build_engine_from_spec(json.loads(args.spec)),
                    step_delay=args.step_delay)

    def ready(h, prt):
        print(f"LISTENING {h} {prt}", flush=True)

    asyncio.run(api.serve(args.host, args.port, on_ready=ready))


if __name__ == "__main__":
    main()
