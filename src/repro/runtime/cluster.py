"""Cluster serving layer (DESIGN.md §11): multi-engine routing with
prefix-affinity and disaggregated prefill/decode KV handoff.

``ClusterServer`` fronts N independent ``Engine`` replicas — each with its
own block pool, prefix cache, and scheduler — behind a pluggable router:

* ``round_robin``       — cycle through the fleet (the stateless baseline).
* ``least_loaded``      — fewest queued tokens on the virtual clock
                          (remaining prefill + remaining decode budget of
                          everything the replica owns).
* ``prefix_affinity``   — route by the same blake2b chain keys the prefix
                          cache computes (prefix_cache.py), so shared-
                          prompt traffic lands on the replica whose blocks
                          are already hot; ties fall back to least-loaded.

A router is any callable ``route(cluster, req, candidates, t) -> Replica``
that depends only on replica state at virtual time ``t`` (the contract
DESIGN.md §11 documents); the names above resolve through ``ROUTERS``.

**Disaggregated mode** (replicas carry roles): external arrivals are
routed over the *prefill* fleet; once a request's chunked prefill
completes (its first token sampled), the engine parks it
(``Engine._park_for_handoff``) and the cluster migrates its KV to a
*decode* replica — ``BlockManager.export_blocks`` / ``import_blocks`` move
the block table and payload with refcounts correct on both sides, and the
prefix-cache entries are re-registered on the importer (full-block hits on
the importer are shared instead of copied).  The handoff takes virtual
time (``MigrationCost``), modeled as an internal arrival at the decode
replica.  The payoff: the decode fleet concentrates the whole load's
decode traffic on a few replicas, so its merged batches cross the
TokenWeave weave floor (``tokenweave_min_tokens``) at offered loads where
each engine of an equal-size monolithic fleet sits below it — quantified
analytically by ``sim/overlap_sim.cluster_summary`` and CPU-real by the
`serve/cluster` benchmark.

**Determinism.**  Time is the same virtual clock as runtime/server.py
(§10): per-replica clocks advance by ``StepCost`` per engine step, and the
cluster executes one global event order — the earliest of (cancel, kill,
dead-replica detection, route, replica step), replicas tied on time by
index.  Routing at time t happens only once no replica has work strictly
before t, so router inputs are replayable state; with greedy sampling the
emitted tokens are batch-composition-invariant, so cluster outputs are
token-identical to a single engine on the same trace for EVERY router
(pinned by tests/test_cluster.py and the `serve/cluster` benchmark).

**Wire transport** (DESIGN.md §15): ``ClusterConfig.wire="loopback"``
routes every arrival envelope and KV-migration payload through the
versioned frame codec (runtime/transport.py) — a real encode→decode round
trip with frame/byte accounting and payload-proportional virtual latency
(``wire_per_byte``), deterministic because no socket is involved.  Real
replicas plug in the same way: ``Replica(name, RemoteEngine(host, port))``
drives an engine hosted in another process over TCP with the same codec.

**Failure handling** (DESIGN.md §15): replicas heartbeat by ticking;
``kill_replica(name, at)`` models a machine crash on the virtual clock
(the replica stops heartbeating and ticking at ``at``), and the detector
declares it dead once ``heartbeat_timeout`` passes without a heartbeat —
on real sockets a failed RPC (``ReplicaGone``) is the missed heartbeat.
Detection requeues every request the dead replica owned (queued, parked,
in-flight adoption, waiting, active) onto surviving replicas with
recompute semantics (``Engine.evacuate`` + ``reset_for_requeue``) —
refcount-correct, which ``check_quiescent`` still verifies over the dead
replica's pool (fault-injection-pinned by tests/test_cluster.py).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import MetricsRegistry
from repro.runtime.engine import Engine, Handoff
from repro.runtime.prefix_cache import chain_hashes
from repro.runtime.requests import Request, State, reset_for_requeue
from repro.runtime.server import StepCost
from repro.runtime.transport import (LoopbackTransport, ReplicaGone,
                                     handoff_from_wire, handoff_to_wire,
                                     request_from_wire, request_to_wire)


@dataclasses.dataclass
class MigrationCost:
    """Virtual duration of one prefill->decode KV handoff.  The default is
    one tick flat; ``per_token`` models payload-proportional transfer time
    (NVLink/ICI copy in a real deployment).  A documented simplification
    (DESIGN.md §11): the cost is pure latency — it never occupies either
    replica's compute stream."""
    base: float = 1.0
    per_token: float = 0.0

    def of(self, n_tokens: int) -> float:
        return self.base + self.per_token * n_tokens


@dataclasses.dataclass
class ClusterConfig:
    router: object = "round_robin"    # name in ROUTERS, or a callable
    step_cost: StepCost = dataclasses.field(default_factory=StepCost)
    migration_cost: MigrationCost = dataclasses.field(
        default_factory=MigrationCost)
    max_steps: int = 1_000_000        # total engine steps across the fleet
    # tuned overlap-plan cache installed on EVERY replica engine at
    # cluster startup (core/policy.py, DESIGN.md §14); None keeps each
    # engine's own policy
    plan_path: Optional[str] = None
    # --- wire transport (DESIGN.md §15) ---
    # None: in-process object passing (the §11 default).  "loopback":
    # every arrival envelope and migration payload round-trips the frame
    # codec (runtime/transport.py) with byte accounting — deterministic,
    # no sockets.  Socket replicas need no cluster flag: RemoteEngine
    # carries its own channel.
    wire: Optional[str] = None
    wire_per_byte: float = 0.0        # virtual secs/byte added to handoffs
    # --- failure handling (DESIGN.md §15) ---
    # a replica is declared dead this long (virtual) after its last
    # heartbeat (= last completed tick, or the kill time)
    heartbeat_timeout: float = 3.0


class ClusterStats:
    """Thin read view over the cluster's MetricsRegistry (``cluster/*``
    counters, DESIGN.md §12) — same attribute names the old dataclass
    exposed, now always equal to what ``metrics_snapshot()`` exports."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = (registry if registry is not None
                         else MetricsRegistry())
        r = self.registry
        # handoffs dispatched onto the wire
        self._migrations_started = r.counter("cluster/migrations_started")
        # prefix_affinity routing decisions / ... that found >= 1 hot block
        self._affinity_routed = r.counter("cluster/affinity_routed")
        self._affinity_hits = r.counter("cluster/affinity_hits")
        self._cancelled = r.counter("cluster/cancelled")
        # failure handling (DESIGN.md §15)
        self._replica_deaths = r.counter("cluster/replica_deaths")
        self._requeued = r.counter("cluster/requeued")

    @property
    def migrations_started(self) -> int:
        return self._migrations_started.value

    @property
    def affinity_routed(self) -> int:
        return self._affinity_routed.value

    @property
    def affinity_hits(self) -> int:
        return self._affinity_hits.value

    @property
    def cancelled(self) -> int:
        return self._cancelled.value

    @property
    def replica_deaths(self) -> int:
        return self._replica_deaths.value

    @property
    def requeued(self) -> int:
        return self._requeued.value

    @property
    def affinity_hit_rate(self) -> float:
        return (self.affinity_hits / self.affinity_routed
                if self.affinity_routed else 0.0)


class Replica:
    """One engine plus its virtual clock and event queues.  Replicas model
    independent machines sharing nothing but the wall-clock axis: routed
    arrivals and migrations enter through time-stamped queues, and
    ``tick`` admits whatever is due before running one engine step."""

    def __init__(self, name: str, engine: Engine, role: str = "mixed",
                 step_cost: Optional[StepCost] = None):
        if role not in ("mixed", "prefill", "decode"):
            raise ValueError(f"unknown replica role {role!r}")
        self.name = name
        self.engine = engine
        self.role = role
        # one recorder, one track per replica (DESIGN.md §12): claim the
        # engine's default track name so fleet traces don't collide
        if engine.obs is not None and engine.obs_track == "engine":
            engine.obs_track = name
        # an explicit per-replica cost (heterogeneous fleet) wins over the
        # cluster-wide default; None is filled in by ClusterServer
        self.step_cost = step_cost
        self.clock = 0.0
        # liveness (DESIGN.md §15): a dead replica stops ticking at once,
        # but the ROUTER keeps sending to it until the detector declares
        # it dead (``detected``) — requests routed inside that window
        # strand in its queue and are requeued at detection, the
        # realistic cost of failure detection by timeout
        self.alive = True
        self.detected = False
        self.last_heartbeat = 0.0
        self._pending: List[Tuple[float, int, Request]] = []   # arrivals
        self._adopt: List[Tuple[float, int, Handoff]] = []     # migrations
        self._finished_cursor = 0

    # ---- event ingress ---------------------------------------------------
    def submit(self, req: Request, at: float) -> None:
        bisect.insort(self._pending, (at, req.rid, req))

    def queue_adoption(self, at: float, handoff: Handoff) -> None:
        bisect.insort(self._adopt, (at, handoff.req.rid, handoff))

    # ---- scheduling ------------------------------------------------------
    def next_work_time(self) -> Optional[float]:
        """Earliest virtual time this replica can make progress: now if the
        engine holds any request, else its next queued arrival/adoption,
        else None (quiescent or dead)."""
        if not self.alive:
            return None
        if (self.engine.sched.waiting
                or any(r is not None for r in self.engine.sched.active)):
            return self.clock
        times = []
        if self._pending:
            times.append(self._pending[0][0])
        if self._adopt:
            times.append(self._adopt[0][0])
        return min(times) if times else None

    def _admit_due(self) -> None:
        while self._pending and self._pending[0][0] <= self.clock:
            _, _, req = self._pending.pop(0)
            req.admit_time = self.clock
            if self.engine.obs is not None:
                self.engine.obs.request_event(
                    req.rid, "arrival", ts=req.arrival_time,
                    args={"replica": self.name, "deadline": req.deadline})
            self.engine.add_request(req)
        # adoptions are head-of-line like paged admission: if the oldest
        # migrated request cannot land (no slot / no blocks), younger ones
        # wait behind it — no reordering, no starvation
        while self._adopt and self._adopt[0][0] <= self.clock:
            _, _, h = self._adopt[0]
            if not self.engine.adopt_request(h.req, h.n_tokens, h.payload):
                break
            self._adopt.pop(0)

    def tick(self) -> bool:
        """Admit due events, run ONE engine step, advance the clock by its
        cost.  Returns False when the engine made no progress."""
        if self.engine.obs is not None:
            # this replica owns the recorder's clock for the duration of
            # its tick: admission/adoption/step events stamp at its time
            self.engine.obs.sync(self.clock)
        self._admit_due()
        before = self.engine.stats.forward_tokens
        if not self.engine.step():
            return False
        if self.step_cost is None:          # standalone use, no cluster
            self.step_cost = StepCost()
        self.clock += self.step_cost.of(
            self.engine.stats.forward_tokens - before)
        self.last_heartbeat = self.clock
        return True

    def take_new_finished(self) -> List[Request]:
        fin = self.engine.sched.finished
        out = fin[self._finished_cursor:]
        self._finished_cursor = len(fin)
        return out

    # ---- router inputs ---------------------------------------------------
    def load(self) -> int:
        """Queued tokens at the current virtual clock: remaining prefill
        plus remaining decode budget of every request this replica owns in
        any pre-terminal stage (queued arrival, in-flight adoption,
        waiting, active)."""
        reqs = ([r for _, _, r in self._pending]
                + [h.req for _, _, h in self._adopt]
                + list(self.engine.sched.waiting)
                + [r for r in self.engine.sched.active if r is not None])
        return sum(max(len(r.context_tokens) - r.prefill_pos, 0)
                   + max(r.max_new_tokens - len(r.output), 0)
                   for r in reqs)

    def prefix_hit_blocks(self, hashes: Sequence[int]) -> int:
        """Leading full-block prefix hits this replica's cache would serve
        a prompt with the given chain hashes (0 on legacy-slot engines)."""
        mgr = self.engine.block_mgr
        if mgr is None or not mgr.prefix_caching:
            return 0
        return len(mgr.prefix.match(hashes))


# --------------------------------------------------------------------------
# routers — route(cluster, req, candidates, t) -> Replica.  Pure functions
# of replica state at virtual time t (the §11 router contract); the sort
# keys make every tie-break explicit and deterministic.
# --------------------------------------------------------------------------

def route_round_robin(cluster: "ClusterServer", req: Request,
                      cands: List[Replica], t: float) -> Replica:
    key = tuple(c.name for c in cands)
    i = cluster._rr.get(key, 0)
    cluster._rr[key] = i + 1
    return cands[i % len(cands)]


def route_least_loaded(cluster: "ClusterServer", req: Request,
                       cands: List[Replica], t: float) -> Replica:
    return min(enumerate(cands), key=lambda ic: (ic[1].load(), ic[0]))[1]


def route_prefix_affinity(cluster: "ClusterServer", req: Request,
                          cands: List[Replica], t: float) -> Replica:
    """Most leading prompt blocks already hot wins; ties (including the
    cold 0-hit case) fall back to least-loaded, then fleet order."""
    bs = cluster._block_size(cands)
    hashes = chain_hashes(req.prompt, bs)
    hits = [c.prefix_hit_blocks(hashes) for c in cands]
    best = max(hits)
    cluster.stats._affinity_routed.inc()
    if best > 0:
        cluster.stats._affinity_hits.inc()
    pool = [(i, c) for i, c in enumerate(cands) if hits[i] == best]
    return min(pool, key=lambda ic: (ic[1].load(), ic[0]))[1]


ROUTERS: Dict[str, Callable] = {
    "round_robin": route_round_robin,
    "least_loaded": route_least_loaded,
    "prefix_affinity": route_prefix_affinity,
}


class ClusterServer:
    """Deterministic multi-replica serving loop.  Usage::

        reps = [Replica(f"r{i}", engine_i) for i in range(3)]
        cs = ClusterServer(reps, ClusterConfig(router="prefix_affinity"))
        for r in poisson_arrivals(trace, rate=0.5, seed=0):
            cs.submit(r)
        done = cs.run()
        cs.summary()     # per-replica weave rates, migrations, affinity

    Disaggregated mode is enabled by replica roles: with any
    ``prefill``/``decode`` replicas present, arrivals enter through the
    prefill fleet (``handoff_after_prefill`` set) and completed prefills
    migrate to the decode fleet under the same router policy."""

    def __init__(self, replicas: List[Replica],
                 cfg: Optional[ClusterConfig] = None):
        if not replicas:
            raise ValueError("a cluster needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names: {names}")
        self.replicas = replicas
        self.cfg = cfg or ClusterConfig()
        self.router = (self.cfg.router if callable(self.cfg.router)
                       else ROUTERS[self.cfg.router])
        for rep in replicas:
            if rep.step_cost is None:
                rep.step_cost = self.cfg.step_cost
        if self.cfg.plan_path:
            # one tuned plan for the whole fleet (DESIGN.md §14): each
            # replica installs the same policy, so routing decisions never
            # change which overlap scheme a request's tokens see
            from repro.core.policy import load_policy
            policy = load_policy(self.cfg.plan_path)
            for rep in replicas:
                rep.engine.install_overlap_policy(policy)

        prefill = [r for r in replicas if r.role == "prefill"]
        decode = [r for r in replicas if r.role == "decode"]
        mixed = [r for r in replicas if r.role == "mixed"]
        self.disaggregated = bool(prefill or decode)
        if self.disaggregated:
            if not (prefill and decode):
                raise ValueError("disaggregated mode needs at least one "
                                 "prefill AND one decode replica")
            if mixed:
                raise ValueError("mixed replicas cannot join a "
                                 "disaggregated fleet")
            for rep in prefill + decode:
                if not rep.engine.paged:
                    raise ValueError(
                        f"replica {rep.name!r}: KV handoff requires the "
                        f"paged backend on every replica")
            self.ingress = prefill
            self.decode_fleet = decode
        else:
            self.ingress = mixed
            self.decode_fleet = []

        if self.cfg.wire not in (None, "loopback"):
            raise ValueError(f"unknown wire mode {self.cfg.wire!r} "
                             f"(expected None or 'loopback')")
        self.wire = (LoopbackTransport() if self.cfg.wire == "loopback"
                     else None)
        self.metrics = MetricsRegistry()
        self.stats = ClusterStats(self.metrics)
        # the fleet shares ONE recorder (first traced engine wins): one
        # lifecycle thread per rid across migrations, one track per replica
        self.obs = next((rep.engine.obs for rep in replicas
                         if rep.engine.obs is not None), None)
        self.requests: List[Request] = []
        self.completed: List[Request] = []
        self.aborted: List[Request] = []
        self.placement: Dict[int, str] = {}   # rid -> ingress replica name
        self._arrivals: List[Tuple[float, int, Request]] = []
        self._cancels: List[Tuple[float, int]] = []
        self._by_rid: Dict[int, Request] = {}
        self._rr: Dict[Tuple[str, ...], int] = {}
        self._by_name: Dict[str, Replica] = {r.name: r for r in replicas}
        # failure injection/detection event queues (DESIGN.md §15)
        self._kills: List[Tuple[float, str]] = []
        self._detects: List[Tuple[float, str]] = []

    # ------------------------------------------------------------------
    # client API
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.rid in self._by_rid:
            raise ValueError(f"duplicate rid {req.rid}")
        self.requests.append(req)
        self._by_rid[req.rid] = req
        bisect.insort(self._arrivals, (req.arrival_time, req.rid, req))

    def cancel(self, rid: int, at: Optional[float] = None) -> None:
        """Schedule a client disconnect at virtual time ``at`` — honored
        wherever the request then lives: unrouted, queued at a replica,
        admitted (``Engine.abort`` releases slot/blocks/prefix refs), or
        mid-migration (the handoff is dropped; the exporter already
        released everything at park, the importer never allocated)."""
        if rid not in self._by_rid:
            raise ValueError(f"unknown rid {rid}")
        t = self._by_rid[rid].arrival_time if at is None else at
        bisect.insort(self._cancels, (t, rid))

    def kill_replica(self, name: str, at: float) -> None:
        """Fault injection (DESIGN.md §15): model a machine crash at
        virtual time ``at``.  The replica stops heartbeating and ticking;
        everything it owns is requeued onto surviving replicas once the
        detector fires at ``at + heartbeat_timeout``.  Requests routed to
        it in the detection window strand in its queue until then — the
        realistic cost of failure detection by timeout."""
        if name not in self._by_name:
            raise ValueError(f"unknown replica {name!r}")
        bisect.insort(self._kills, (at, name))

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _block_size(self, cands: List[Replica]) -> int:
        sizes = {c.engine.scfg.block_size for c in cands
                 if c.engine.block_mgr is not None}
        if not sizes:
            raise ValueError("prefix_affinity needs paged replicas")
        if len(sizes) != 1:
            raise ValueError(f"prefix_affinity needs one fleet-wide "
                             f"block_size, got {sorted(sizes)}")
        return sizes.pop()

    def _routable(self, fleet: List[Replica], what: str) -> List[Replica]:
        """Router candidates: every replica not yet DETECTED dead — the
        frontend cannot know about a crash before the detector fires, so
        the detection window routes into a dead replica's queue."""
        cands = [r for r in fleet if not r.detected]
        if not cands:
            raise RuntimeError(f"no alive {what} replica left in the fleet")
        return cands

    def _wire_transfer(self, kind: str, obj: object) -> Tuple[object, int]:
        """Round-trip one envelope through the loopback codec with frame
        and byte accounting (the §15 ``cluster/wire/*`` metrics the
        `serve/cluster_wire` benchmark exports)."""
        got, nbytes = self.wire.transfer(kind, obj)
        self.metrics.counter("cluster/wire/frames").inc()
        self.metrics.counter("cluster/wire/bytes").inc(nbytes)
        self.metrics.histogram("cluster/wire/frame_bytes").observe(nbytes)
        return got, nbytes

    def _route_arrival(self) -> None:
        t, _, req = self._arrivals.pop(0)
        if self.wire is not None:
            # the envelope a socket frontend would send: round-trip it
            # through the codec so the wire schema stays honest even in
            # the deterministic twin.  The decoded copy is only checked —
            # the cluster keeps routing the ORIGINAL object so identity-
            # based bookkeeping (cancel, placement) is unchanged.
            got, _ = self._wire_transfer("submit", request_to_wire(req))
            decoded = request_from_wire(got)
            assert (decoded.rid, decoded.prompt) == (req.rid, req.prompt)
        target = self.router(self, req,
                             self._routable(self.ingress, "ingress"), t)
        self.placement[req.rid] = target.name
        if self.disaggregated:
            req.handoff_after_prefill = True
        target.submit(req, at=t)

    def _dispatch_handoffs(self, rep: Replica) -> None:
        for h in rep.engine.take_handoffs():
            self.stats._migrations_started.inc()
            delay = self.cfg.migration_cost.of(h.n_tokens)
            if self.wire is not None:
                # KV payload crosses the codec for real: the adopted
                # blocks are the decoded bytes, and the transfer adds
                # payload-proportional virtual latency
                got, nbytes = self._wire_transfer(
                    "handoff", handoff_to_wire(h))
                h = handoff_from_wire(got, req=h.req)
                delay += self.cfg.wire_per_byte * nbytes
                self.metrics.histogram("cluster/wire/latency").observe(
                    self.cfg.wire_per_byte * nbytes)
            target = self.router(self, h.req,
                                 self._routable(self.decode_fleet,
                                                "decode"), rep.clock)
            if self.obs is not None and self.wire is not None:
                # per-replica wire track: replica clocks can leapfrog, so
                # a single shared track would break trace monotonicity
                self.obs.complete(
                    f"wire/{rep.name}", f"migrate/{h.req.rid}",
                    ts=rep.clock, dur=delay, cat="wire",
                    args={"n_tokens": h.n_tokens, "to": target.name})
            target.queue_adoption(rep.clock + delay, h)

    def _collect_finished(self, rep: Replica) -> None:
        for req in rep.take_new_finished():
            req.finish_time = rep.clock
            self.completed.append(req)

    def _process_cancel(self) -> None:
        t, rid = self._cancels.pop(0)
        req = self._by_rid[rid]
        if req.state == State.DONE:
            return
        # 1. not yet routed
        for i, (_, r_rid, _) in enumerate(self._arrivals):
            if r_rid == rid:
                self._arrivals.pop(i)
                self._mark_cancelled(req, t)
                return
        for rep in self.replicas:
            # 2. routed but not yet admitted
            for i, (_, p_rid, _) in enumerate(rep._pending):
                if p_rid == rid:
                    rep._pending.pop(i)
                    self._mark_cancelled(req, t)
                    return
            # 3. mid-migration: exporter freed at park, importer never
            #    allocated — dropping the handoff releases everything
            for i, (_, a_rid, _) in enumerate(rep._adopt):
                if a_rid == rid:
                    rep._adopt.pop(i)
                    self._mark_cancelled(req, t)
                    return
            # 4. owned by a replica engine (waiting or active)
            sched = rep.engine.sched
            if req in sched.waiting or any(r is req for r in sched.active):
                if rep.engine.obs is not None:
                    # stamp the abort's terminal event at the owning
                    # replica's time (>= every prior event of this rid)
                    rep.engine.obs.sync(max(rep.clock, t))
                rep.engine.abort(req, "cancelled")
                req.finish_time = rep.clock
                self.stats._cancelled.inc()
                self.aborted.append(req)
                return
        raise AssertionError(f"rid {rid} not found anywhere in the cluster")

    def _mark_cancelled(self, req: Request, t: float) -> None:
        """Cancel a request no engine owns (unrouted, pre-admission, or
        mid-migration): the engine abort path can't emit its terminal
        lifecycle event, so the cluster does — exactly one terminal per
        rid either way (DESIGN.md §12)."""
        req.state = State.DONE
        req.finish_reason = "cancelled"
        self.stats._cancelled.inc()
        if self.obs is not None:
            self.obs.request_event(req.rid, "cancel", ts=t,
                                   args={"reason": "cancelled"})
        self.aborted.append(req)

    # ------------------------------------------------------------------
    # failure handling (DESIGN.md §15)
    # ------------------------------------------------------------------
    def _mark_dead(self, rep: Replica, t: float) -> None:
        rep.alive = False
        rep.clock = max(rep.clock, t)
        self.stats._replica_deaths.inc()
        if self.obs is not None:
            self.obs.instant(rep.name, "replica_dead", ts=rep.clock,
                             cat="fault")
        bisect.insort(self._detects,
                      (rep.clock + self.cfg.heartbeat_timeout, rep.name))

    def _process_kill(self) -> None:
        t, name = self._kills.pop(0)
        rep = self._by_name[name]
        if rep.alive:
            self._mark_dead(rep, t)

    def _schedule_death(self, rep: Replica) -> None:
        """A socket replica died mid-RPC (``ReplicaGone``): the failed
        call is the missed heartbeat, so detection fires one timeout after
        the replica's last observed progress."""
        if rep.alive:
            self._mark_dead(rep, rep.clock)

    def _process_detect(self) -> None:
        """Declare a replica dead and requeue everything it owned —
        queued arrivals and in-flight adoptions (cluster-side), plus
        parked/waiting/active requests (``Engine.evacuate``) — onto
        surviving ingress replicas with recompute semantics.  DONE
        requests already left the replica via ``_collect_finished``."""
        t, name = self._detects.pop(0)
        rep = self._by_name[name]
        rep.detected = True            # out of every router candidate set
        stranded = ([req for _, _, req in rep._pending]
                    + [h.req for _, _, h in rep._adopt])
        rep._pending.clear()
        rep._adopt.clear()
        for req in stranded:
            reset_for_requeue(req)
        evacuated = rep.engine.evacuate()
        for req in stranded + evacuated:
            if req.state == State.DONE:
                continue
            # re-admission is a fresh arrival at detection time; keeping
            # the original arrival_time would re-emit the rid's "arrival"
            # instant in the past and break per-thread trace monotonicity
            req.arrival_time = t
            if self.disaggregated:
                req.handoff_after_prefill = True
            target = self.router(self, req,
                                 self._routable(self.ingress, "ingress"), t)
            self.placement[req.rid] = target.name
            self.stats._requeued.inc()
            if self.obs is not None:
                self.obs.request_event(
                    req.rid, "requeue", ts=t,
                    args={"from": name, "to": target.name,
                          "recovered_tokens": len(req.output)})
            target.submit(req, at=t)

    # ------------------------------------------------------------------
    # serving loop
    # ------------------------------------------------------------------
    def run(self) -> List[Request]:
        """Serve until every submitted request reached a terminal state.
        One global deterministic event order: the earliest of (cancel,
        kill, detect, route, replica step); at equal times cancels run
        first, then kills, then detections, then routing, then the
        lowest-index replica steps."""
        steps = 0
        while True:
            t_cancel = self._cancels[0][0] if self._cancels else None
            t_kill = self._kills[0][0] if self._kills else None
            t_detect = self._detects[0][0] if self._detects else None
            t_route = self._arrivals[0][0] if self._arrivals else None
            work = [(w, i) for i, rep in enumerate(self.replicas)
                    if (w := rep.next_work_time()) is not None]
            t_work = min(work)[0] if work else None
            times = [t for t in (t_cancel, t_kill, t_detect, t_route,
                                 t_work) if t is not None]
            if not times:
                break
            t = min(times)
            if t_cancel is not None and t_cancel <= t:
                self._process_cancel()
                continue
            if t_kill is not None and t_kill <= t:
                self._process_kill()
                continue
            if t_detect is not None and t_detect <= t:
                self._process_detect()
                continue
            if t_route is not None and t_route <= t:
                self._route_arrival()
                continue
            _, i = min(w for w in work if w[0] <= t)
            rep = self.replicas[i]
            rep.clock = max(rep.clock, t)
            try:
                progressed = rep.tick()
            except ReplicaGone:
                # a socket replica died mid-RPC: treat the failed call as
                # the missed heartbeat and let the detector requeue
                self._schedule_death(rep)
                continue
            if progressed:
                steps += 1
                if steps > self.cfg.max_steps:
                    raise RuntimeError(
                        f"cluster exceeded max_steps={self.cfg.max_steps}")
                self._dispatch_handoffs(rep)
                self._collect_finished(rep)
                continue
            # replica had work on paper but the engine made no progress:
            # nothing else in the cluster can unblock it (pools are
            # per-replica), so surface it like Engine.run does
            stuck = [r.rid for r in rep.engine.sched.waiting]
            stuck += [h.req.rid for _, _, h in rep._adopt]
            raise RuntimeError(
                f"replica {rep.name!r} idle with unservable request(s) "
                f"{stuck}: block pool or slots too small")
        return self.completed

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    def check_quiescent(self) -> None:
        """End-of-trace invariant sweep (tests + fault injection lean on
        this): every block table released and every refcount back to zero
        on every replica — a leaking ``import_blocks``/``free_request`` is
        caught here, not silently absorbed.  Dead LOCAL replicas are still
        swept (``evacuate`` is what empties them, so a leaky evacuation
        trips here); remote replicas sweep host-side via their own
        ``check_quiescent`` RPC."""
        for rep in self.replicas:
            if hasattr(rep.engine, "check_quiescent"):
                rep.engine.check_quiescent()   # RemoteEngine (§15)
                continue
            mgr = rep.engine.block_mgr
            if mgr is None:
                continue
            assert not mgr.tables, (rep.name, list(mgr.tables))
            leaked = [b for b in range(mgr.alloc.num_blocks)
                      if mgr.alloc.ref[b]]
            assert not leaked, (rep.name, leaked)

    def summary(self) -> Dict[str, float]:
        """Deterministic cluster counters: per-replica weave rate and
        tokens/forward, migration count, affinity hit rate, and the
        decode-fleet aggregate weave rate (the §11 payoff metric).
        ``migrations`` counts COMPLETED handoffs (adoptions) — a handoff
        cancelled on the wire is in ``stats.migrations_started`` only."""
        done = sum(rep.engine.block_mgr.stats.migrations_in
                   for rep in self.replicas
                   if rep.engine.block_mgr is not None)
        out: Dict[str, float] = {
            "migrations": float(done),
            "affinity_hit_rate": self.stats.affinity_hit_rate,
            "completed": float(len(self.completed)),
        }
        for rep in self.replicas:
            st = rep.engine.stats
            out[f"{rep.name}/weave_rate"] = st.weave_rate
            out[f"{rep.name}/tokens_per_forward"] = st.tokens_per_forward
        if self.decode_fleet:
            fwd = sum(r.engine.stats.forwards for r in self.decode_fleet)
            wv = sum(r.engine.stats.weave_forwards
                     for r in self.decode_fleet)
            out["decode_fleet/weave_rate"] = wv / fwd if fwd else 0.0
        return out

    def metrics_snapshot(self) -> Dict[str, float]:
        """Registry flatten for the benchmark provenance gate
        (DESIGN.md §12): ``cluster/*`` counters plus every ``summary()``
        value synced into a ``summary/<key>`` gauge."""
        for k, v in self.summary().items():
            self.metrics.gauge(f"summary/{k}").set(v)
        return self.metrics.snapshot()
