"""TokenWeave's primary contribution (DESIGN.md §2): wave-aware token
splitting, the fused AllReduce-RMSNorm collective, and the two-split
overlap weave."""
from repro.core.splitting import smart_split, split_sizes_for_batch  # noqa: F401
