"""Per-site overlap policy & tuned plan cache (DESIGN.md §14).

The TokenWeave decision used to be a single global token threshold
(``core/splitting.split_decision``).  The paper — and NeMo's per-site
``TransformerLayerTPOverlapCfg`` — show the right overlap scheme differs
per collective site and per (tokens, tp) regime, with an explicit
resource budget à la Flash Communication.  This module is the one plan
format every consumer shares:

* ``OverlapPlan`` — what to do at one (site, tokens-bucket, tp, family)
  key: method ∈ {``none``, ``weave``, ``fused-unsplit``, ``fused``}, the
  prefix-wave split fraction, and the comm resource-budget fraction
  (mapped to the ring kernel's lane count by
  ``core.splitting.ring_channels``).
* ``ThresholdPolicy`` — the DEGENERATE policy: the global token
  threshold, pinned token-identical to ``split_decision`` (property-
  tested field-for-field).  This is the default everywhere, so engines
  without a tuned plan behave exactly as before.
* ``TunedPolicy`` — a plan cache fitted offline by
  ``analysis/autotune.py`` against the §9 sim under a calibrated ``HW``
  (§13), serialized as versioned JSON under ``benchmarks/plans/`` and
  loaded by ``Engine`` / ``OnlineServer`` / ``ClusterServer`` at
  startup.  Lookups that miss fall back to the threshold decision, so a
  partial plan is always safe.

Decision sites mirror the engine's dispatch kinds — ``prefill`` (seq-
axis split), ``decode`` (batch-axis), ``verify`` (γ+1 windows,
batch-axis), ``packed`` (flat token axis) — because that is where the
fused AllReduce+RMSNorm collectives fire per forward; a finer
per-collective key (attn-out vs MLP) reuses the same format when the
fused kernel becomes schedulable per site.

Every decision is stamped with (plan_id, bucket) in its
``SplitDecision`` so the §12 trace attribution can name which plan fired
per forward.  Policies are frozen/hashable so they can ride inside the
frozen ``ParallelConfig``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

from repro.core.splitting import (DEFAULT_BUCKET_EDGES, SplitDecision,
                                  plan_split, split_decision, token_bucket)

SITES = ("prefill", "decode", "verify", "packed")
# method semantics (DESIGN.md §14):
#   none          — never split, generic comm path
#   weave         — wave-aware token split, composed-collective comm
#   fused-unsplit — REAL ring AllReduce-RMSNorm kernel, no split (the
#                   paper's fused kernel without TokenWeave; its `budget`
#                   sizes the kernel's ring lanes via
#                   core.splitting.ring_channels)
#   fused         — ring kernel + wave-aware split: the full TokenWeave
#                   configuration the paper ships (Fig. 8)
METHODS = ("none", "weave", "fused-unsplit", "fused")
PLAN_VERSION = 1


@dataclasses.dataclass(frozen=True)
class OverlapPlan:
    """One resolved per-site overlap scheme (DESIGN.md §14)."""
    site: str
    bucket: str
    method: str          # none | weave | fused-unsplit | fused
    split_frac: float    # prefix-wave fraction (weave/fused; 0.5 = balanced)
    budget: float        # comm resource-budget fraction in (0, 1]
    plan_id: int


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """One row of the tuned plan cache, keyed (site, bucket, tp, family)."""
    site: str
    bucket: str
    tp: int
    family: str
    method: str
    split_frac: float = 0.5
    budget: float = 1.0

    def validate(self) -> Optional[str]:
        """Schema check; returns a failure string or None (valid)."""
        if self.site not in SITES:
            return f"unknown site {self.site!r} (want one of {SITES})"
        if self.method not in METHODS:
            return f"unknown method {self.method!r} (want one of {METHODS})"
        if not (0.0 < self.split_frac < 1.0):
            return f"split_frac {self.split_frac} outside (0, 1)"
        if not (0.0 < self.budget <= 1.0):
            return f"budget {self.budget} outside (0, 1]"
        if self.tp < 1:
            return f"tp {self.tp} < 1"
        return None


class OverlapPolicy:
    """Interface: yield a per-site ``SplitDecision`` / ``OverlapPlan``.

    ``decide`` receives exactly the arguments the legacy threshold
    decision saw (n units along the split axis, wave unit, threshold,
    rectangularity constraint) plus the plan key (site, tp, family) and
    an optional ``bucket_tokens`` — the TRUE token count when the split
    axis is rows (decode/verify), so bucket lookup keys on tokens even
    where the split counts rows.
    """
    plan_id: int = 0

    def decide(self, site: str, n_tokens: int, *, unit: int,
               min_tokens: int, row_multiple: int = 1, tp: int = 1,
               family: str = "dense",
               bucket_tokens: Optional[int] = None) -> SplitDecision:
        raise NotImplementedError

    def plan_for(self, site: str, tokens: int, *, tp: int = 1,
                 family: str = "dense") -> Optional[OverlapPlan]:
        """The tuned plan covering (site, bucket(tokens), tp, family), or
        None when the degenerate threshold fallback applies."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ThresholdPolicy(OverlapPolicy):
    """The degenerate global-threshold policy: ``split_decision``
    verbatim (plan_id pinned 0), the repo-wide default when no tuned
    plan is installed.  Token-identity with the legacy path is exact by
    construction and property-tested (tests/test_policy.py)."""
    plan_id: int = 0

    def decide(self, site: str, n_tokens: int, *, unit: int,
               min_tokens: int, row_multiple: int = 1, tp: int = 1,
               family: str = "dense",
               bucket_tokens: Optional[int] = None) -> SplitDecision:
        d = split_decision(n_tokens, unit=unit, min_tokens=min_tokens,
                           row_multiple=row_multiple)
        if bucket_tokens is not None and bucket_tokens != n_tokens:
            d = dataclasses.replace(d, bucket=token_bucket(bucket_tokens))
        return d

    def plan_for(self, site: str, tokens: int, *, tp: int = 1,
                 family: str = "dense") -> Optional[OverlapPlan]:
        return None


DEFAULT_POLICY = ThresholdPolicy()


@dataclasses.dataclass(frozen=True)
class TunedPolicy(OverlapPolicy):
    """Plan-cache-backed policy (DESIGN.md §14): per-(site, bucket, tp,
    family) entries fitted offline by ``analysis/autotune.py``.  Keys
    with no entry fall back to the degenerate threshold decision, so a
    plan tuned for one deployment never breaks another."""
    plan_id: int = 1
    version: int = PLAN_VERSION
    bucket_edges: Tuple[int, ...] = DEFAULT_BUCKET_EDGES
    entries: Tuple[PlanEntry, ...] = ()
    _index: Dict = dataclasses.field(init=False, repr=False, compare=False,
                                     default_factory=dict)

    def __post_init__(self):
        idx = {(e.site, e.bucket, e.tp, e.family): e for e in self.entries}
        object.__setattr__(self, "_index", idx)

    def lookup(self, site: str, tokens: int, *, tp: int,
               family: str) -> Optional[PlanEntry]:
        bucket = token_bucket(tokens, self.bucket_edges)
        return self._index.get((site, bucket, int(tp), family))

    def plan_for(self, site: str, tokens: int, *, tp: int = 1,
                 family: str = "dense") -> Optional[OverlapPlan]:
        e = self.lookup(site, tokens, tp=tp, family=family)
        if e is None:
            return None
        return OverlapPlan(site=e.site, bucket=e.bucket, method=e.method,
                           split_frac=e.split_frac, budget=e.budget,
                           plan_id=self.plan_id)

    def decide(self, site: str, n_tokens: int, *, unit: int,
               min_tokens: int, row_multiple: int = 1, tp: int = 1,
               family: str = "dense",
               bucket_tokens: Optional[int] = None) -> SplitDecision:
        import math
        bt = bucket_tokens if bucket_tokens is not None else n_tokens
        e = self.lookup(site, bt, tp=tp, family=family)
        if e is None:
            # no tuned coverage: the degenerate threshold decision, but
            # stamped with THIS plan's id so attribution shows the plan
            # was consulted (bucket label reveals the fallback key)
            d = split_decision(n_tokens, unit=unit, min_tokens=min_tokens,
                               row_multiple=row_multiple)
            return dataclasses.replace(d, plan_id=self.plan_id,
                                       bucket=token_bucket(
                                           bt, self.bucket_edges))
        eff_unit = math.lcm(unit, max(row_multiple, 1))
        if e.method in ("weave", "fused"):
            split = plan_split(n_tokens, eff_unit, e.split_frac)
            if split is not None:
                return SplitDecision(split, "plan_split", n_tokens,
                                     eff_unit, min_tokens, self.plan_id,
                                     e.bucket, e.budget)
            # tuned weave structurally infeasible at this exact size
            # (fewer than two full waves at the effective quantum)
            return SplitDecision(None, "below_wave_floor", n_tokens,
                                 eff_unit, min_tokens, self.plan_id,
                                 e.bucket, e.budget)
        return SplitDecision(None, "plan_unsplit", n_tokens, eff_unit,
                             min_tokens, self.plan_id, e.bucket, e.budget)

    # ---- versioned JSON plan cache (benchmarks/plans/*.json) ----------
    def to_doc(self, **meta) -> dict:
        doc = {
            "version": self.version,
            "plan_id": self.plan_id,
            "bucket_edges": list(self.bucket_edges),
            "entries": [dataclasses.asdict(e) for e in self.entries],
        }
        doc.update(meta)
        return doc

    @classmethod
    def from_doc(cls, doc: dict) -> "TunedPolicy":
        version = int(doc.get("version", -1))
        if version != PLAN_VERSION:
            raise ValueError(
                f"plan cache version {version} unsupported (this build "
                f"reads version {PLAN_VERSION}); regenerate with "
                f"python -m repro.analysis.autotune")
        names = {f.name for f in dataclasses.fields(PlanEntry)}
        entries = tuple(
            PlanEntry(**{k: v for k, v in e.items() if k in names})
            for e in doc.get("entries", ()))
        for e in entries:
            err = e.validate()
            if err:
                raise ValueError(f"invalid plan entry {e}: {err}")
        return cls(plan_id=int(doc.get("plan_id", 1)), version=version,
                   bucket_edges=tuple(int(x)
                                      for x in doc.get("bucket_edges",
                                                       DEFAULT_BUCKET_EDGES)),
                   entries=entries)

    def save(self, path: str, **meta) -> None:
        with open(path, "w") as f:
            json.dump(self.to_doc(**meta), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "TunedPolicy":
        with open(path) as f:
            return cls.from_doc(json.load(f))


def load_policy(path: Optional[str]) -> OverlapPolicy:
    """Startup hook for ``Engine`` / ``OnlineServer`` / ``ClusterServer``:
    a plan-cache path loads the tuned policy, None keeps the degenerate
    global-threshold default (DESIGN.md §14)."""
    if not path:
        return DEFAULT_POLICY
    return TunedPolicy.load(path)
