"""The paper's core op (DESIGN.md §2): AllReduce + residual-add + RMSNorm,
five ways.

All variants run inside ``jax.shard_map`` with manual collectives so the
collective schedule is explicit (the paper's point). Shapes (per dp shard):

    x          (T, d)      row-parallel matmul output; *partial sums* over TP
    residual   vanilla/nocomm: (T, d)  full
               reordered/fused: (T // tp, d)  this shard's token slice only
               (paper Listing 1: each GPU only ever touches its 1/N residual
               slice -> the residual stream lives permanently token-sharded)
    weight     (d,)        RMSNorm gain, replicated
    returns    (normed_full (T, d), new_residual (layout per mode))

Modes:
    vanilla   : psum -> +residual -> RMSNorm on all T tokens on every shard
                (the vLLM default the paper measures 5-9% overhead for)
    reordered : psum_scatter -> +res -> RMSNorm (1/N tokens) -> all_gather,
                with the *unfused* two-pass add+norm (paper Fig. 4 middle bar:
                reordering alone, overheads eat the gains)
    fused     : psum_scatter -> single-pass fused add+norm kernel ->
                all_gather (paper's fused AllReduce-RMSNorm, composed from
                XLA collectives)
    ring      : the REAL single-kernel path — kernels/ring_ar_rmsnorm.py
                does reduce-scatter + fused add/norm + all-gather in ONE
                Pallas kernel on ``ring_channels(ctx.comm_budget)`` comm
                lanes (the paper's 2-8 SM multimem kernel, TPU ring
                analogue). Falls down a ladder to the ``fused``
                composition when the backend can't run it (see
                ``_ring_supported``); numerics pinned either way by
                tests/test_fused_path.py.
    nocomm    : collectives skipped entirely (perf counterfactual; wrong math,
                correct shapes - mirrors vllm-nocomm)
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.experimental.pallas import tpu as pltpu

from repro.core.splitting import ring_channels
from repro.distributed.context import CommCtx, token_shard_slice
from repro.kernels.ops import fused_residual_rmsnorm
from repro.kernels.ring_ar_rmsnorm import ring_fused_ar_rmsnorm
from repro.layers.norms import residual_rmsnorm_unfused, rms_norm


def _ring_supported(ctx: CommCtx, reduce_input: bool, weight_post) -> bool:
    """Fallback ladder for mode="ring" (DESIGN.md §2): the one-kernel ring
    path needs (a) a genuine reduction to fold in (``reduce_input``), (b)
    no sandwich post-norm (the kernel fuses exactly add+norm), (c) Pallas
    enabled, and (d) a backend whose interpreter can emulate remote DMAs
    when interpreting — jax < 0.5's CPU interpreter (no
    ``pltpu.InterpretParams``) cannot, so CI gates to the composition."""
    if not (reduce_input and weight_post is None and ctx.use_pallas):
        return False
    if ctx.interpret and not hasattr(pltpu, "InterpretParams"):
        return False
    return True


def comm_norm(x, residual, weight, *, ctx: CommCtx, reduce_input: bool = True,
              weight_post=None):
    """The fused AllReduce-RMSNorm slot at the end of attention / FFN.

    ``reduce_input=False`` means x is already complete per token (e.g. the
    MoE ep2d combine returned full values): the reduction is skipped but the
    token-sharded norm + AG structure is preserved.

    ``weight_post``: optional gemma-style post-norm applied to the *reduced
    block output* before the residual add (sandwich norm); it rides the same
    scattered shard so the redundancy elimination still applies.
    """
    mode = ctx.mode
    if mode in ("nocomm", "vanilla"):
        if mode == "vanilla" and reduce_input:
            x = lax.psum(x, ctx.tp_axis)
            if ctx.bf16_wire:
                x = lax.optimization_barrier(x)
        if weight_post is not None:
            x = rms_norm(x, weight_post, ctx.eps)
        out, new_res = residual_rmsnorm_unfused(x, residual, weight, ctx.eps)
        return out, new_res

    if mode not in ("reordered", "fused", "ring"):
        raise ValueError(f"unknown comm mode {mode!r}")

    if mode == "ring":
        if _ring_supported(ctx, reduce_input, weight_post):
            return ring_fused_ar_rmsnorm(
                x, residual, weight, axis_name=ctx.tp_axis,
                n_dev=ctx.tp_size(), eps=ctx.eps, interpret=ctx.interpret,
                channels=max(1, ring_channels(ctx.comm_budget)))
        mode = "fused"  # rung 2 of the ladder: the composed RS/fused/AG path

    # --- TokenWeave path: RS -> (+res, norm on 1/N tokens) -> AG -----------
    if reduce_input:
        local = lax.psum_scatter(x, ctx.tp_axis, scatter_dimension=0, tiled=True)
        if ctx.bf16_wire:
            # stop XLA's excess-precision pass from hoisting the fp32 norm
            # cast above the reduce-scatter (f32 wire = 2x bytes)
            local = lax.optimization_barrier(local)
    else:
        local = token_shard_slice(x, ctx)

    if weight_post is not None:
        local = rms_norm(local, weight_post, ctx.eps)

    if mode == "fused" and weight_post is None:
        normed_shard, new_res = fused_residual_rmsnorm(
            local, residual, weight, eps=ctx.eps,
            use_pallas=ctx.use_pallas, interpret=ctx.interpret)
    else:
        normed_shard, new_res = residual_rmsnorm_unfused(
            local, residual, weight, ctx.eps)

    full = lax.all_gather(normed_shard, ctx.tp_axis, axis=0, tiled=True)
    return full, new_res


def final_norm(residual, weight, *, ctx: CommCtx):
    """Final pre-LM-head RMSNorm on the residual stream (no add)."""
    if ctx.sharded_residual:
        normed_shard = rms_norm(residual, weight, ctx.eps)
        return lax.all_gather(normed_shard, ctx.tp_axis, axis=0, tiled=True)
    return rms_norm(residual, weight, ctx.eps)


def fresh_residual(t_tokens: int, d: int, dtype, *, ctx: CommCtx):
    """Zero residual in the layout the configured mode expects."""
    if ctx.sharded_residual:
        tp = ctx.tp_size()
        return jnp.zeros((t_tokens // tp, d), dtype=dtype)
    return jnp.zeros((t_tokens, d), dtype=dtype)


def gather_residual(residual, *, ctx: CommCtx):
    """Materialize the full residual stream (checkpointing / logits paths)."""
    if ctx.sharded_residual:
        return lax.all_gather(residual, ctx.tp_axis, axis=0, tiled=True)
    return residual
