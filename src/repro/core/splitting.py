"""Wave-aware Token-Splitting (paper §3.1; DESIGN.md §2, packed-axis
split decision in DESIGN.md §6).

The GPU notion of a "wave" (gridDim CTAs / 132 SMs) maps on TPU to the tile
quantization of the token dimension: XLA/Mosaic process the M-dimension of a
GEMM in tiles of `unit` rows (a multiple of the 8-row sublane tile; we default
to 256 which is also what our Pallas kernels use), and a split that turns one
partial tile into two wastes an MXU pass per kernel.

Smart-splitting guarantees:
    ceil(L1/u) + ceil(L2/u) == ceil(L/u)      (no extra waves)
    L1 % u == 0                               (prefix split = full waves only)
    |L1 - L2| minimized subject to the above  (balanced overlap)
and, because ``u`` is chosen as a multiple of the TP degree, both splits stay
divisible by TP so the fused ReduceScatter-RMSNorm-AllGather can tile tokens
across the TP group.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


def smart_split(n_tokens: int, unit: int) -> Optional[Tuple[int, int]]:
    """Split ``n_tokens`` into (L1, L2) wave-aware halves; None if unsplittable.

    L1 is the prefix split (full waves only); L2 = n - L1 carries the single
    partial wave, exactly matching the paper's 300-CTA -> (132, 168) example
    with unit=132.
    """
    if unit <= 0:
        raise ValueError(f"unit must be positive, got {unit}")
    if n_tokens < 2 * unit:
        return None  # a split would necessarily add a wave (or produce L1=0)
    total_waves = math.ceil(n_tokens / unit)
    l1 = (total_waves // 2) * unit
    l2 = n_tokens - l1
    assert l1 > 0 and l2 > 0
    return l1, l2


def naive_split(n_tokens: int) -> Tuple[int, int]:
    """Equal halves, ignoring wave quantization (paper's strawman)."""
    l1 = n_tokens // 2
    return l1, n_tokens - l1


def wave_count(n_tokens: int, unit: int) -> int:
    return math.ceil(n_tokens / unit)


def split_sizes_for_batch(
    n_tokens: int,
    *,
    unit: int,
    min_tokens: int,
    row_multiple: int = 1,
) -> Optional[Tuple[int, int]]:
    """Splitting decision used by the runtime.

    ``row_multiple`` constrains the split point to a multiple of the batch
    size when tokens are laid out (B, S) row-major and we split along S (all
    rows split at the same sequence position, keeping shapes rectangular).
    Returns None when the batch is too small for splitting to pay off
    (paper: TokenWeave is bypassed below ~1K tokens; the fused kernel is
    still used unsplit).
    """
    return split_decision(n_tokens, unit=unit, min_tokens=min_tokens,
                          row_multiple=row_multiple).split


@dataclasses.dataclass(frozen=True)
class SplitDecision:
    """Reasoned split decision (the trace-attribution record's core,
    DESIGN.md §12): the split chosen — or None plus WHY not.

    reasons: ``split`` (weave fires), ``below_min_tokens`` (under the
    paper's ~1K-token bypass threshold), ``below_wave_floor`` (enough
    tokens nominally, but a cut could not avoid adding a wave — fewer
    than two full tile units at the effective quantum)."""
    split: Optional[Tuple[int, int]]
    reason: str
    n_tokens: int
    unit: int                 # effective wave quantum (lcm w/ row_multiple)
    min_tokens: int


def split_decision(
    n_tokens: int,
    *,
    unit: int,
    min_tokens: int,
    row_multiple: int = 1,
) -> SplitDecision:
    """``split_sizes_for_batch`` with the refusal reason attached —
    identical decision, used by the observability layer (DESIGN.md §12)
    to explain every weave/no-weave call per forward step."""
    eff_unit = math.lcm(unit, max(row_multiple, 1))
    if n_tokens < min_tokens:
        return SplitDecision(None, "below_min_tokens", n_tokens, eff_unit,
                             min_tokens)
    if n_tokens < 2 * unit:
        return SplitDecision(None, "below_wave_floor", n_tokens, eff_unit,
                             min_tokens)
    split = smart_split(n_tokens, eff_unit)
    return SplitDecision(split, "split" if split is not None
                         else "below_wave_floor", n_tokens, eff_unit,
                         min_tokens)


def packed_split(
    n_tokens: int,
    *,
    unit: int,
    min_tokens: int,
) -> Optional[Tuple[int, int]]:
    """Weave decision for a packed hybrid iteration (DESIGN.md §6).

    A packed plan concatenates prefill-chunk segments, single-token decode
    slots, and speculative verify windows along ONE flat token axis, so the
    split point needs no rectangularity constraint (``row_multiple == 1``)
    and — crucially — the decision sees the TRUE combined iteration size.
    Under the two-dispatch scheme each half is judged against
    ``min_tokens`` alone; mixed iterations that would jointly cross the
    threshold fall back to the unsplit path on both calls.  Segment
    boundaries need not align with the split: a segment straddling the cut
    attends the prefix split's freshly written KV (the §3.1 chunked
    attention dependency).
    """
    return split_sizes_for_batch(n_tokens, unit=unit, min_tokens=min_tokens,
                                 row_multiple=1)


def pad_to_multiple(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple
