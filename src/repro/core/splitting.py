"""Wave-aware Token-Splitting (paper §3.1; DESIGN.md §2, packed-axis
split decision in DESIGN.md §6).

The GPU notion of a "wave" (gridDim CTAs / 132 SMs) maps on TPU to the tile
quantization of the token dimension: XLA/Mosaic process the M-dimension of a
GEMM in tiles of `unit` rows (a multiple of the 8-row sublane tile; we default
to 256 which is also what our Pallas kernels use), and a split that turns one
partial tile into two wastes an MXU pass per kernel.

Smart-splitting guarantees:
    ceil(L1/u) + ceil(L2/u) == ceil(L/u)      (no extra waves)
    L1 % u == 0                               (prefix split = full waves only)
    |L1 - L2| minimized subject to the above  (balanced overlap)
and, because ``u`` is chosen as a multiple of the TP degree, both splits stay
divisible by TP so the fused ReduceScatter-RMSNorm-AllGather can tile tokens
across the TP group.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


def smart_split(n_tokens: int, unit: int) -> Optional[Tuple[int, int]]:
    """Split ``n_tokens`` into (L1, L2) wave-aware halves; None if unsplittable.

    L1 is the prefix split (full waves only); L2 = n - L1 carries the single
    partial wave, exactly matching the paper's 300-CTA -> (132, 168) example
    with unit=132.
    """
    if unit <= 0:
        raise ValueError(f"unit must be positive, got {unit}")
    if n_tokens < 2 * unit:
        return None  # a split would necessarily add a wave (or produce L1=0)
    total_waves = math.ceil(n_tokens / unit)
    l1 = (total_waves // 2) * unit
    l2 = n_tokens - l1
    assert l1 > 0 and l2 > 0
    return l1, l2


def naive_split(n_tokens: int) -> Tuple[int, int]:
    """Equal halves, ignoring wave quantization (paper's strawman)."""
    l1 = n_tokens // 2
    return l1, n_tokens - l1


def wave_count(n_tokens: int, unit: int) -> int:
    return math.ceil(n_tokens / unit)


# ---- comm resource budget -> ring-lane (SM-equivalent) mapping ----------
# The paper's fused multimem kernel runs on 2-8 SMs; the TPU ring kernel's
# analogue resource is its comm-slot ("channel") count — the number of
# in-flight ring lanes (kernels/ring_ar_rmsnorm.py).  A plan entry's
# ``budget`` in (0, 1] is the SM-equivalent fraction granted to comm
# (NeMo's per-op ``num_sm`` knob, DESIGN.md §14):
#     channels = round(budget * MAX_RING_CHANNELS)
# Deliberately NOT clamped to >= 1 here: scripts/check_plan.py rejects
# plan entries whose budget maps to zero lanes (an overcommitted plan
# would grant the kernel no comm resources at all); runtime callers clamp
# with max(1, ...) after validation.
MAX_RING_CHANNELS: int = 8


def ring_channels(budget: float) -> int:
    """SM-equivalent comm budget -> ring-lane count for the fused kernel."""
    return int(round(float(budget) * MAX_RING_CHANNELS))


# token-bucket edges shared by the overlap policy layer (core/policy.py,
# DESIGN.md §14): a decision at n tokens falls in the bucket whose lower
# edge is the largest edge <= n.  Kept here (pure token math) so both the
# SplitDecision record and the plan cache key on the same labels.
DEFAULT_BUCKET_EDGES: Tuple[int, ...] = (
    0, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384)


def token_bucket(n_tokens: int,
                 edges: Tuple[int, ...] = DEFAULT_BUCKET_EDGES) -> str:
    """Bucket label for a token count: ``"lo-hi"`` (inclusive) for bounded
    buckets, ``"lo+"`` for the open last bucket."""
    n = max(int(n_tokens), 0)
    for lo, hi in zip(edges, edges[1:]):
        if lo <= n < hi:
            return f"{lo}-{hi - 1}"
    return f"{edges[-1]}+"


def split_sizes_for_batch(
    n_tokens: int,
    *,
    unit: int,
    min_tokens: int,
    row_multiple: int = 1,
) -> Optional[Tuple[int, int]]:
    """Splitting decision used by the runtime — the degenerate
    global-threshold form of the overlap policy (``core/policy.
    ThresholdPolicy`` reproduces it token-identically; tuned per-site
    plans override it via ``ParallelConfig.overlap_policy``, DESIGN.md
    §14).

    ``row_multiple`` constrains the split point to a multiple of the batch
    size when tokens are laid out (B, S) row-major and we split along S (all
    rows split at the same sequence position, keeping shapes rectangular).
    Returns None when the batch is too small for splitting to pay off
    (paper: TokenWeave is bypassed below ~1K tokens; the fused kernel is
    still used unsplit).
    """
    return split_decision(n_tokens, unit=unit, min_tokens=min_tokens,
                          row_multiple=row_multiple).split


@dataclasses.dataclass(frozen=True)
class SplitDecision:
    """Reasoned split decision (the trace-attribution record's core,
    DESIGN.md §12): the split chosen — or None plus WHY not — stamped
    with the overlap plan that produced it (DESIGN.md §14).

    reasons: ``split`` (weave fires), ``below_min_tokens`` (under the
    paper's ~1K-token bypass threshold), ``below_wave_floor`` (enough
    tokens nominally, but a cut could not avoid adding a wave — fewer
    than two full tile units at the effective quantum), plus the tuned-
    plan reasons ``plan_split`` / ``plan_unsplit`` when a
    ``core/policy.TunedPolicy`` entry decided (DESIGN.md §14)."""
    split: Optional[Tuple[int, int]]
    reason: str
    n_tokens: int
    unit: int                 # effective wave quantum (lcm w/ row_multiple)
    min_tokens: int
    plan_id: int = 0          # 0 = degenerate global-threshold policy
    bucket: str = ""          # tokens-bucket the decision was keyed on
    budget: float = 1.0       # comm resource budget -> ring_channels()


def split_decision(
    n_tokens: int,
    *,
    unit: int,
    min_tokens: int,
    row_multiple: int = 1,
) -> SplitDecision:
    """``split_sizes_for_batch`` with the refusal reason attached —
    identical decision, used by the observability layer (DESIGN.md §12)
    to explain every weave/no-weave call per forward step.  ``plan_id``
    is pinned 0: this IS the degenerate global-threshold plan the policy
    layer falls back to (DESIGN.md §14)."""
    eff_unit = math.lcm(unit, max(row_multiple, 1))
    bucket = token_bucket(n_tokens)
    if n_tokens < min_tokens:
        return SplitDecision(None, "below_min_tokens", n_tokens, eff_unit,
                             min_tokens, 0, bucket)
    if n_tokens < 2 * unit:
        return SplitDecision(None, "below_wave_floor", n_tokens, eff_unit,
                             min_tokens, 0, bucket)
    split = smart_split(n_tokens, eff_unit)
    return SplitDecision(split, "split" if split is not None
                         else "below_wave_floor", n_tokens, eff_unit,
                         min_tokens, 0, bucket)


def plan_split(n_tokens: int, unit: int, frac: float
               ) -> Optional[Tuple[int, int]]:
    """Wave-conserving split at an arbitrary prefix-wave fraction (the
    tuned-plan generalization of ``smart_split``, DESIGN.md §14).

    The prefix takes ``k = floor(frac * total_waves)`` full waves (clamped
    to [1, total_waves-1]), so every invariant of ``smart_split`` holds
    for ANY frac: no extra wave, prefix split full-waves-only.
    ``frac = 0.5`` reproduces ``smart_split`` exactly.
    """
    if unit <= 0:
        raise ValueError(f"unit must be positive, got {unit}")
    if n_tokens < 2 * unit:
        return None
    total_waves = math.ceil(n_tokens / unit)
    k = min(max(int(frac * total_waves), 1), total_waves - 1)
    l1 = k * unit
    return l1, n_tokens - l1


def packed_split(
    n_tokens: int,
    *,
    unit: int,
    min_tokens: int,
) -> Optional[Tuple[int, int]]:
    """Weave decision for a packed hybrid iteration (DESIGN.md §6), in
    its degenerate global-threshold form — the engine's packed planner
    consults the active ``OverlapPolicy`` through the same
    ``SplitDecision`` format (``site="packed"``, DESIGN.md §14), of
    which this is the pinned ``plan_id=0`` fallback.

    A packed plan concatenates prefill-chunk segments, single-token decode
    slots, and speculative verify windows along ONE flat token axis, so the
    split point needs no rectangularity constraint (``row_multiple == 1``)
    and — crucially — the decision sees the TRUE combined iteration size.
    Under the two-dispatch scheme each half is judged against
    ``min_tokens`` alone; mixed iterations that would jointly cross the
    threshold fall back to the unsplit path on both calls.  Segment
    boundaries need not align with the split: a segment straddling the cut
    attends the prefix split's freshly written KV (the §3.1 chunked
    attention dependency).
    """
    return split_sizes_for_batch(n_tokens, unit=unit, min_tokens=min_tokens,
                                 row_multiple=1)


def pad_to_multiple(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple
