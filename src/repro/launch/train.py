"""Fault-tolerant training driver.

Features exercised here (and in tests/test_checkpoint.py):
  * resume-from-latest on (re)start — surviving node failure / preemption
  * periodic async checkpointing (atomic renames, keep_last trimming)
  * SIGTERM/SIGINT handler -> final checkpoint before exit (preemption)
  * elastic restore: the checkpoint stores full logical arrays, so a run
    can resume on a different mesh/device count
  * optional int8 gradient compression across pods (--manual-sync)

Usage (CPU demo):
    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
        --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="gemma3-1b")
    p.add_argument("--reduced", action="store_true",
                   help="tiny same-family config (CPU)")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--mesh", default="1x1",
                   help="DATAxMODEL (or PODxDATAxMODEL)")
    p.add_argument("--comm-mode", default="fused",
                   choices=["vanilla", "reordered", "fused", "nocomm"])
    p.add_argument("--no-tokenweave", action="store_true")
    p.add_argument("--manual-sync", action="store_true",
                   help="explicit grad sync (+int8 pod compression)")
    p.add_argument("--fail-at-step", type=int, default=0,
                   help="simulate a crash at step N (fault-tolerance test)")
    args = p.parse_args(argv)

    from repro.configs import get_config
    from repro.configs.base import ParallelConfig
    from repro.checkpoint.manager import CheckpointManager
    from repro.models.build import build_model
    from repro.training.data import SyntheticLM
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_step import (make_manual_sync_train_step,
                                           make_train_step)

    dims = [int(x) for x in args.mesh.split("x")]
    if len(dims) == 2:
        axes, dp_axes = ("data", "model"), ("data",)
    else:
        axes, dp_axes = ("pod", "data", "model"), ("pod", "data")
    mesh = jax.make_mesh(tuple(dims), axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(dims))
    tp = dims[-1]
    dp = int(np.prod(dims[:-1]))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    pcfg = ParallelConfig(comm_mode=args.comm_mode,
                          tokenweave=not args.no_tokenweave,
                          dp_axes=dp_axes, split_unit=64,
                          tokenweave_min_tokens=256,
                          grad_compression="int8" if args.manual_sync
                          else "none")
    api = build_model(cfg, pcfg, tp=tp, ep=dims[-2] if len(dims) > 2 else
                      dims[0])

    data = SyntheticLM(vocab=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch)
    batch0 = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 4 + 1))

    if args.manual_sync:
        step_fn, init_fn = make_manual_sync_train_step(api, mesh, batch0,
                                                       ocfg)
    else:
        step_fn, init_fn = make_train_step(api, mesh, batch0, ocfg,
                                           dp_size=dp)

    state = list(init_fn(jax.random.PRNGKey(0)))
    mgr = CheckpointManager(args.ckpt_dir)
    start_step = 0
    latest = mgr.latest_step()
    if latest is not None:
        _, restored = mgr.restore_latest(tuple(state))
        state = list(restored)
        start_step = latest
        print(f"[train] resumed from checkpoint step {start_step}")

    stop = {"now": False}

    def _handler(signum, frame):
        print(f"[train] signal {signum}: checkpointing and exiting")
        stop["now"] = True

    signal.signal(signal.SIGTERM, _handler)
    signal.signal(signal.SIGINT, _handler)

    t0 = time.time()
    i = start_step
    for i in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        out = step_fn(*state[:2], *( [state[2], batch] if len(state) == 3
                                     else [batch]))
        if len(state) == 3:
            state[0], state[1], metrics, state[2] = out
        else:
            state[0], state[1], metrics = out
        if i % 10 == 0 or i == args.steps - 1:
            print(f"[train] step {i} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time()-t0:.1f}s)")
        if args.fail_at_step and i + 1 == args.fail_at_step:
            mgr.save(i + 1, tuple(state))
            mgr.wait()
            print(f"[train] simulated failure at step {i + 1}")
            sys.exit(42)
        if (i + 1) % args.ckpt_every == 0 or stop["now"]:
            mgr.save(i + 1, tuple(state))
        if stop["now"]:
            break
    mgr.save(i + 1, tuple(state))
    mgr.wait()
    print(f"[train] done at step {i + 1}")


if __name__ == "__main__":
    main()
