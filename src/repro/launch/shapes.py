"""Assigned input-shape sets and per-(arch x shape) input_specs().

ShapeDtypeStruct stand-ins only — weak-type-correct, shardable, zero device
allocation. The four LM shapes:

    train_4k     seq 4096   global_batch 256   -> train_step
    prefill_32k  seq 32768  global_batch 32    -> serve prefill
    decode_32k   seq 32768  global_batch 128   -> serve decode (1 new token)
    long_500k    seq 524288 global_batch 1     -> long-context decode

`long_500k` runs only for bounded-state archs (cfg.supports_long_context);
see DESIGN.md §4. Family quirks (whisper enc length, VLM patch split) are
documented inline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    long_context: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1,
                           long_context=True),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason."""
    if shape.long_context and not cfg.supports_long_context:
        return ("pure full-attention arch: 500k decode KV state unbounded "
                "(DESIGN.md §4)")
    return None


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, dp_axes) -> Dict:
    """Model inputs as ShapeDtypeStructs (batch sharded over dp axes)."""
    b, s = shape.global_batch, shape.seq_len
    dp = tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]
    bspec = P(dp) if b > 1 else P(None)
    i32 = jnp.int32

    if cfg.family == "encdec":
        # whisper: seq_len = DECODER length; encoder fixed at the real
        # 1500-frame mel window (conv frontend stubbed -> embeddings)
        s_enc = cfg.max_source_positions
        if shape.kind == "train":
            return {
                "frames": _sds((b, s_enc, cfg.d_model), jnp.bfloat16, mesh,
                               P(dp) if b > 1 else P(None)),
                "tokens": _sds((b, s), i32, mesh, bspec),
                "labels": _sds((b, s), i32, mesh, bspec),
            }
        if shape.kind == "prefill":
            return {
                "frames": _sds((b, s_enc, cfg.d_model), jnp.bfloat16, mesh,
                               bspec),
                "tokens": _sds((b, s), i32, mesh, bspec),
            }
        return {"tokens": _sds((b, 1), i32, mesh, bspec),
                "positions": _sds((b, 1), i32, mesh, bspec)}

    if cfg.family == "vlm" and shape.kind != "decode":
        # dynamic-resolution stub: 1/4 of the context is patch embeddings
        s_img = s // 4
        s_txt = s - s_img
        out = {
            "tokens": _sds((b, s_txt), i32, mesh, bspec),
            "extra_embeds": _sds((b, s_img, cfg.d_model), jnp.bfloat16,
                                 mesh, bspec),
            "mrope_positions": _sds((b, 3, s), i32, mesh, bspec),
        }
        if shape.kind == "train":
            out["labels"] = _sds((b, s), i32, mesh, bspec)
        return out

    out = {"tokens": _sds((b, s if shape.kind != "decode" else 1), i32,
                          mesh, bspec)}
    if shape.kind == "train":
        out["labels"] = _sds((b, s), i32, mesh, bspec)
    if shape.kind == "decode":
        out["positions"] = _sds((b, 1), i32, mesh, bspec)
        if cfg.family == "vlm":
            out["mrope_positions"] = _sds((b, 3, 1), i32, mesh, bspec)
    if shape.kind == "prefill":
        out["positions"] = _sds(
            (b, s if cfg.family != "vlm" else s), i32, mesh, bspec)
    return out
