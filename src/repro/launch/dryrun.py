import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell, on the single-pod 16x16 mesh
AND the 2x16x16 multi-pod mesh:

    lowered  = jax.jit(step).lower(**input_specs)   # ShapeDtypeStructs only
    compiled = lowered.compile()
    print(compiled.memory_analysis())               # proves it fits
    print(compiled.cost_analysis())                 # FLOPs/bytes -> roofline

Results are written incrementally to a JSON file so interrupted sweeps
resume. Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b \
        --shape train_4k [--multi-pod] [--out runs/dryrun.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def build_cell(arch: str, shape_name: str, mesh, *, multi_pod: bool,
               pcfg_overrides=None, cfg_overrides=None):
    """Returns (fn, example_inputs, meta) ready for jit(fn).lower(*inputs)."""
    from repro.configs import get_config
    from repro.configs.base import ParallelConfig
    from repro.launch.shapes import SHAPES, cell_applicable, input_specs
    from repro.models.build import build_model
    from repro.runtime.sampler import sample
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_step import make_train_step

    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    skip = cell_applicable(cfg, shape)
    if skip:
        return None, None, {"skip": skip}

    dp_axes = ("pod", "data") if multi_pod else ("data",)
    tp = mesh.shape["model"]
    ep = mesh.shape["data"]
    n_dev = int(np.prod(list(mesh.shape.values())))
    over = dict(
        dp_axes=dp_axes,
        comm_mode="fused",
        tokenweave=True,
        seq_shard_kv=shape.long_context,
        attn_impl="chunked",
    )
    over.update(pcfg_overrides or {})
    pcfg = ParallelConfig(**over)
    api = build_model(cfg, pcfg, tp=tp, ep=ep)
    ins = input_specs(cfg, shape, mesh, dp_axes)
    pspec = api.specs()
    params_sds = _attach(jax.eval_shape(api.init, jax.random.PRNGKey(0)),
                         pspec, mesh)

    meta = {"arch": arch, "shape": shape_name,
            "multi_pod": multi_pod, "n_devices": n_dev,
            "kind": shape.kind, "seq_len": shape.seq_len,
            "n_tokens": shape.global_batch * shape.seq_len
            if shape.kind != "decode" else shape.global_batch,
            "decode_context": shape.seq_len if shape.kind == "decode" else 0,
            "train": shape.kind == "train"}

    if shape.kind == "train":
        from repro.training.train_step import make_train_step
        ocfg = AdamWConfig()
        jstep, _ = make_train_step(api, mesh, ins, ocfg,
                                   dp_size=int(np.prod(
                                       [mesh.shape[a] for a in dp_axes])))
        from repro.training.optimizer import init_opt_state, opt_state_specs
        ospec = opt_state_specs(
            jax.eval_shape(api.init, jax.random.PRNGKey(0)), pspec,
            dp_axes, int(np.prod([mesh.shape[a] for a in dp_axes])))
        opt_sds = _attach(
            jax.eval_shape(init_opt_state,
                           jax.eval_shape(api.init, jax.random.PRNGKey(0))),
            ospec, mesh)
        return jstep, (params_sds, opt_sds, ins), meta

    bspecs = {k: v.sharding.spec for k, v in ins.items()}

    if shape.kind == "prefill":
        def fn(params, inputs):
            if cfg.family == "encdec":
                logits, kv, _ = api.mod.prefill(
                    params, inputs, None, cfg=cfg, pcfg=pcfg)
            else:
                logits, kv, _ = api.mod.prefill(
                    params, inputs["tokens"], None, cfg=cfg, pcfg=pcfg,
                    positions=inputs.get("positions"),
                    **({k: inputs[k] for k in
                        ("mrope_positions", "extra_embeds")
                        if k in inputs}))
            tok = sample(logits, vocab_size=cfg.vocab_size,
                         tp_axis=pcfg.tp_axis)
            return tok, kv
        sm = jax.shard_map(fn, mesh=mesh, in_specs=(pspec, bspecs),
                           out_specs=(P(), _kv_out_specs(api, pcfg)),
                           check_vma=False)
        return jax.jit(sm), (params_sds, ins), meta

    # decode
    cache_sds = _attach(
        jax.eval_shape(lambda: api.init_cache(shape.global_batch,
                                              shape.seq_len)),
        _cache_specs_for(api, pcfg, shape), mesh)

    def fn(params, inputs, cache):
        logits, new_cache = api.mod.decode_step(
            params, inputs["tokens"], cache, cfg=cfg, pcfg=pcfg,
            positions=inputs["positions"],
            **({"mrope_positions": inputs["mrope_positions"]}
               if "mrope_positions" in inputs else {}))
        tok = sample(logits, vocab_size=cfg.vocab_size, tp_axis=pcfg.tp_axis)
        return tok, new_cache
    cspec = _cache_specs_for(api, pcfg, shape)
    sm = jax.shard_map(fn, mesh=mesh, in_specs=(pspec, bspecs, cspec),
                       out_specs=(P(), cspec), check_vma=False)
    return jax.jit(sm, donate_argnums=(2,)), (params_sds, ins, cache_sds), \
        meta


def _dp_size(mesh, dp_axes):
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return n


def _attach(sds_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        sds_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _kv_out_specs(api, pcfg):
    """Prefill returns (logits-sample, chunk kv) — kv out specs per family."""
    from jax.sharding import PartitionSpec as P
    dp = tuple(pcfg.dp_axes)
    cfg = api.cfg
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import use_scan
        kv = (P(None, dp, None, "model", None),
              P(None, dp, None, "model", None), P(None, dp, None))
        if use_scan(cfg, pcfg):
            return kv
        return {f"layer_{i}": tuple(P(*s[1:]) for s in kv)
                for i in range(cfg.num_layers)}
    if cfg.family == "ssm":
        return (P(None, dp, None, "model"), P(None, dp, "model", None))
    if cfg.family == "hybrid":
        return {
            "mamba": ((P(None, dp, None, "model"), P(None, dp, None, None)),
                      P(None, dp, "model", None, None)),
            "shared": (P(None, dp, None, "model", None),
                       P(None, dp, None, "model", None), P(None, dp, None)),
        }
    if cfg.family == "encdec":
        kv = {"k": P(None, dp, None, "model", None),
              "v": P(None, dp, None, "model", None),
              "pos": P(None, dp, None)}
        return {"self": (P(None, dp, None, "model", None),
                         P(None, dp, None, "model", None), P(None, dp, None)),
                "cross": dict(kv)}
    raise KeyError(cfg.family)


def _cache_specs_for(api, pcfg, shape):
    return api.cache_specs(batch1=shape.global_batch == 1)


def run_cell(arch, shape_name, *, multi_pod, out_path=None, mesh=None,
             pcfg_overrides=None, cfg_overrides=None, tag="baseline"):
    from repro.analysis.roofline import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES

    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    key = f"{arch}|{shape_name}|{'2x16x16' if multi_pod else '16x16'}|{tag}"
    try:
        fn, inputs, meta = build_cell(arch, shape_name, mesh,
                                      multi_pod=multi_pod,
                                      pcfg_overrides=pcfg_overrides,
                                      cfg_overrides=cfg_overrides)
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "tag": tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
        print(f"[dryrun] {key} BUILD FAILED: {rec['error']}")
        _emit(out_path, key, rec)
        return rec
    rec = dict(meta, tag=tag, mesh=str(dict(mesh.shape)))
    if fn is None:
        rec["status"] = "skipped"
        _emit(out_path, key, rec)
        return rec
    try:
        if shape_name.startswith("train"):
            lowered = fn.lower(*inputs)
        else:
            lowered = fn.lower(*inputs)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        print(ma)
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in sorted(ca)[:8]} if ca else ca)
        from repro.configs import get_config
        cfg = get_config(arch)
        roof = analyze(compiled, None, cfg,
                       n_devices=meta["n_devices"],
                       n_tokens_global=meta["n_tokens"],
                       train=meta["train"],
                       decode_context=meta["decode_context"],
                       seq_len=meta["seq_len"])
        rec.update(
            status="ok", lower_s=round(t1 - t0, 1),
            compile_s=round(t2 - t1, 1),
            memory=dict(
                args=ma.argument_size_in_bytes,
                out=ma.output_size_in_bytes,
                temp=ma.temp_size_in_bytes,
                alias=ma.alias_size_in_bytes,
                code=ma.generated_code_size_in_bytes),
            roofline=roof.to_dict())
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {key} FAILED: {rec['error']}")
    _emit(out_path, key, rec)
    return rec


def _emit(out_path, key, rec):
    if not out_path:
        return
    data = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    rec = dict(rec)
    rec.pop("traceback", None)
    data[key] = rec
    tmp = out_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, default=str)
    os.replace(tmp, out_path)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="runs/dryrun.json")
    p.add_argument("--skip-done", action="store_true")
    p.add_argument("--tag", default="baseline")
    args = p.parse_args(argv)

    from repro.configs import ASSIGNED
    from repro.launch.shapes import SHAPES

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    done = {}
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            done = {k: v for k, v in json.load(f).items()
                    if v.get("status") in ("ok", "skipped")}

    for mp in meshes:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=mp)
        for arch in archs:
            for shape in shapes:
                key = (f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
                       f"|{args.tag}")
                if key in done:
                    print(f"[dryrun] {key}: cached, skipping")
                    continue
                print(f"[dryrun] === {key} ===", flush=True)
                rec = run_cell(arch, shape, multi_pod=mp, out_path=args.out,
                               mesh=mesh, tag=args.tag)
                print(f"[dryrun] {key}: {rec['status']} "
                      f"(lower {rec.get('lower_s')}s, "
                      f"compile {rec.get('compile_s')}s)", flush=True)


if __name__ == "__main__":
    main()
