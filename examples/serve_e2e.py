"""End-to-end serving driver (the paper is an inference paper — this is the
required e2e example): a ShareGPT-like trace through the continuous-batching
engine with Sarathi-style chunked prefill, TokenWeave on, reporting
throughput and per-request latency stats.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 8] [--weave-off]
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.models.build import build_model
from repro.runtime.engine import Engine
from repro.runtime.requests import sharegpt_like_trace
from repro.runtime.scheduler import SchedulerConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--arch", default="qwen1.5-4b")
    p.add_argument("--weave-off", action="store_true")
    p.add_argument("--chunk", type=int, default=128)
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    pcfg = ParallelConfig(tokenweave=not args.weave_off, comm_mode="fused",
                          remat=False, split_unit=32,
                          tokenweave_min_tokens=64)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))

    eng = Engine(api, mesh, params,
                 SchedulerConfig(max_batch=4, chunk_tokens=args.chunk,
                                 max_len=1024, prefill_bucket=64))
    trace = sharegpt_like_trace(args.requests, vocab=cfg.vocab_size,
                                seed=0, max_in=512, max_out=32)
    for r in trace:
        r.max_new_tokens = min(r.max_new_tokens, 16)   # CPU demo budget
        eng.add_request(r)

    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = eng.stats.prefill_tokens + eng.stats.decode_tokens
    print(f"arch={cfg.name} tokenweave={'off' if args.weave_off else 'on'}")
    print(f"requests completed : {len(done)}/{args.requests}")
    print(f"engine iterations  : {eng.stats.steps}")
    print(f"prefill tokens     : {eng.stats.prefill_tokens}")
    print(f"decode tokens      : {eng.stats.decode_tokens}")
    print(f"throughput (CPU!)  : {toks/dt:,.0f} tok/s over {dt:.1f}s")
    ttfts = [r.first_token_step - r.arrival_step for r in done]
    print(f"TTFT (steps)       : mean {sum(ttfts)/len(ttfts):.1f} "
          f"max {max(ttfts)}")


if __name__ == "__main__":
    main()
