"""Quickstart: build a tiny TokenWeave model, train it for a handful of
steps on synthetic data, then greedily generate through the serving engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.build import build_model
from repro.runtime.engine import Engine
from repro.runtime.requests import Request
from repro.runtime.scheduler import SchedulerConfig
from repro.training.data import SyntheticLM
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import make_train_step


def main():
    cfg = ModelConfig(name="quickstart", family="dense", num_layers=2,
                      d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                      d_ff=256, vocab_size=256, dtype="float32")
    # TokenWeave on: fused AllReduce-RMSNorm + two-split weave
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=32, tokenweave_min_tokens=64)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)

    data = SyntheticLM(vocab=cfg.vocab_size, seq_len=128, global_batch=4)
    batch0 = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    step, init = make_train_step(api, mesh, batch0,
                                 AdamWConfig(lr=3e-3, warmup_steps=10),
                                 dp_size=1)
    params, opt = init(jax.random.PRNGKey(0))
    print("training a 2-layer model on synthetic Markov data...")
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, b)
        if i % 10 == 0 or i == 29:
            print(f"  step {i:3d}  loss {float(m['loss']):.4f}")

    print("serving with continuous batching + chunked prefill...")
    eng = Engine(api, mesh, params,
                 SchedulerConfig(max_batch=2, chunk_tokens=64, max_len=256,
                                 prefill_bucket=32))
    prompt = data.batch(999)["tokens"][0, :40].tolist()
    eng.add_request(Request(rid=0, prompt=prompt, max_new_tokens=16))
    done = eng.run()
    print(f"  prompt tail: {prompt[-8:]}")
    print(f"  generated : {done[0].output}")
    print("done — same schedule that runs on the 512-chip mesh "
          "(see launch/dryrun.py).")


if __name__ == "__main__":
    main()
