"""Observability walkthrough (DESIGN.md §12): a seeded online-serving run
with a ``TraceRecorder`` attached — writes the Chrome-trace/Perfetto JSON,
prints the per-phase compute/comm/overlapped virtual-time breakdown from
the per-forward weave attributions, and walks ONE request's weave-decision
log end to end (every forward the engine ran while it was live, with the
split decision and §9 roofline estimate each one carried).

    PYTHONPATH=src python examples/trace_serve.py [--requests 8] \
        [--packed] [--out trace.json] [--follow RID]

Load the JSON at https://ui.perfetto.dev (or inspect it with
``python scripts/trace_view.py trace.json``): one process per engine
track, one thread per request lifecycle.
"""
import argparse
from collections import defaultdict

import jax

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.build import build_model
from repro.obs import (TERMINAL_PHASES, TraceRecorder, export_chrome_trace,
                       validate_chrome_trace, weave_counts_from_trace)
from repro.runtime.engine import Engine
from repro.runtime.requests import poisson_arrivals, sharegpt_like_trace
from repro.runtime.scheduler import SchedulerConfig
from repro.runtime.server import OnlineServer, ServerConfig, StepCost


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--rate", type=float, default=0.25)
    p.add_argument("--packed", action="store_true",
                   help="packed hybrid batching (one forward/iteration)")
    p.add_argument("--out", default="trace.json",
                   help="Chrome-trace JSON output path")
    p.add_argument("--follow", type=int, default=0, metavar="RID",
                   help="request whose weave-decision log to walk")
    args = p.parse_args()

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))

    rec = TraceRecorder()
    eng = Engine(api, mesh, params,
                 SchedulerConfig(max_batch=4, chunk_tokens=48, max_len=128,
                                 prefill_bucket=16, paged=True,
                                 packed=args.packed),
                 obs=rec, obs_track="engine")
    srv = OnlineServer(eng, ServerConfig(
        step_cost=StepCost(base=1.0, per_token=0.05)))

    reqs = sharegpt_like_trace(args.requests, vocab=cfg.vocab_size, seed=11,
                               max_in=48, max_out=8)
    for r in reqs:
        r.max_new_tokens = max(2, min(r.max_new_tokens, 8))
    for r in poisson_arrivals(reqs, rate=args.rate, seed=5):
        srv.submit(r)
    done = srv.run()
    print(f"served {len(done)} requests in {srv.clock:.1f} virtual ticks, "
          f"{eng.stats.steps} engine steps")

    # ---- per-phase virtual-time breakdown from the attributions -------
    by_kind = defaultdict(lambda: [0, 0, 0.0, 0.0, 0.0])
    for ev in rec.events:
        if ev["kind"] != "span" or ev["cat"] != "forward":
            continue
        a = ev["args"]
        t = by_kind[a["kind"]]
        t[0] += 1
        t[1] += int(bool(a["weave"]))
        t[2] += a["est_compute"]
        t[3] += a["est_comm"]
        t[4] += a["est_overlapped"]
    print("\nper-phase breakdown (est. §9-roofline virtual seconds):")
    print(f"  {'phase':<9} {'fwds':>5} {'weave':>6} {'compute':>11} "
          f"{'comm':>11} {'overlapped':>11} {'comm hidden':>11}")
    for kind in sorted(by_kind):
        n, w, comp, comm, ovl = by_kind[kind]
        hidden = ovl / comm if comm else 0.0
        print(f"  {kind:<9} {n:>5} {w:>6} {comp:>11.3e} {comm:>11.3e} "
              f"{ovl:>11.3e} {hidden:>10.1%}")
    w, n = weave_counts_from_trace(rec)
    assert (w, n) == (eng.stats.weave_forwards, eng.stats.forwards), \
        "trace and EngineStats disagree — the §12 invariant broke"
    print(f"\nweave rate: {w}/{n} = {w / max(n, 1):.3f} "
          f"(trace == EngineStats: True)")

    # ---- one request end to end ---------------------------------------
    rid = args.follow
    evs = [ev for ev in rec.events
           if ev["kind"] == "request" and ev["rid"] == str(rid)]
    if not evs:
        raise SystemExit(f"request {rid} not in trace")
    print(f"\nrequest {rid} lifecycle:")
    for ev in evs:
        extra = {k: v for k, v in ev["args"].items() if v is not None}
        print(f"  t={ev['ts']:8.2f}  {ev['phase']:<15} {extra}")
    t0 = min(ev["ts"] for ev in evs)
    t1 = max(ev["ts"] for ev in evs)
    print(f"\nweave decisions while request {rid} was live "
          f"(t in [{t0:.1f}, {t1:.1f}]):")
    for ev in rec.events:
        if ev["kind"] != "span" or ev["cat"] != "forward":
            continue
        if not (t0 <= ev["ts"] <= t1):
            continue
        a = ev["args"]
        print(f"  t={ev['ts']:8.2f}  {ev['name']:<16} "
              f"weave={str(bool(a['weave'])):<5} reason={a['reason']:<18} "
              f"tokens={a['tokens']:>3}  split={a['split']}  "
              f"ovl={a['est_overlapped']:.3g}")
    term = [ev["phase"] for ev in evs if ev["phase"] in TERMINAL_PHASES]
    print(f"terminal: {term[0]}")

    # ---- export ---------------------------------------------------------
    doc = export_chrome_trace(rec, path=args.out)
    fails = validate_chrome_trace(doc)
    assert not fails, fails
    print(f"\nwrote {len(doc['traceEvents'])} events to {args.out} "
          f"(valid; open at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
