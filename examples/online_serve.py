"""Online serving walkthrough (DESIGN.md §10): a Poisson-arrival trace
through the OnlineServer — streaming tokens, a mid-flight cancellation,
tight deadlines — then the same trace offline to show the token-identity
pin, and the sim's load sweep showing where the packed engine starts
weaving before two-dispatch does.

    PYTHONPATH=src python examples/online_serve.py [--requests 8] \
        [--rate 0.25] [--packed] [--deadline 30]
"""
import argparse

import jax

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.build import build_model
from repro.runtime.engine import Engine
from repro.runtime.requests import poisson_arrivals, sharegpt_like_trace
from repro.runtime.scheduler import SchedulerConfig
from repro.runtime.server import OnlineServer, ServerConfig, StepCost


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--rate", type=float, default=0.25,
                   help="Poisson arrival rate (requests per virtual tick)")
    p.add_argument("--packed", action="store_true",
                   help="packed hybrid batching (one forward/iteration)")
    p.add_argument("--deadline", type=float, default=None,
                   help="per-request e2e SLO in virtual ticks")
    args = p.parse_args()

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))

    def trace():
        t = sharegpt_like_trace(args.requests, vocab=cfg.vocab_size,
                                seed=11, max_in=48, max_out=8)
        for r in t:
            r.max_new_tokens = max(2, min(r.max_new_tokens, 8))
            if args.deadline is not None:
                r.deadline = r.arrival_time + args.deadline
        return poisson_arrivals(t, rate=args.rate, seed=5)

    def scfg():
        return SchedulerConfig(max_batch=4, chunk_tokens=48, max_len=128,
                               prefill_bucket=16, paged=True,
                               packed=args.packed)

    # ---- offline reference (whole queue drained at once) -------------
    off = Engine(api, mesh, params, scfg())
    for r in trace():
        off.add_request(r)
    ref = {r.rid: r.output for r in off.run()}

    # ---- online: arrivals, streaming, a cancellation -----------------
    eng = Engine(api, mesh, params, scfg())
    srv = OnlineServer(eng, ServerConfig(
        step_cost=StepCost(base=1.0, per_token=0.05),
        expire_on_deadline=args.deadline is not None))

    def stream(req, tok, t):
        tag = "TTFT" if len(req.output) == 1 else "    "
        print(f"  t={t:7.2f}  rid={req.rid}  +tok {tok:3d}  {tag}")

    reqs = trace()
    for r in reqs:
        srv.submit(r, on_token=stream)
    victim = reqs[-1].rid
    srv.cancel(victim, at=reqs[-1].arrival_time + 2.0)
    done = srv.run()

    got = {r.rid: r.output for r in done}
    identical = all(got[rid] == ref[rid] for rid in got)
    print(f"\ncompleted={len(done)} "
          f"aborted={[(r.rid, r.finish_reason) for r in srv.aborted]}")
    print(f"online outputs identical to offline: {identical}")
    lat = eng.stats.latency.summary()
    print(f"goodput={lat['goodput']:.2f} ttft_p50={lat['ttft_p50']:.2f} "
          f"tpot_p50={lat['tpot_p50']:.2f} e2e_p99={lat['e2e_p99']:.2f} "
          f"weave_rate={eng.stats.weave_rate:.2f} (virtual ticks)")

    # ---- the load-dependence story (analytic, 70B/tp16) --------------
    from repro.configs import get_config
    from repro.sim.overlap_sim import online_summary
    big = get_config("llama3.3-70b")
    print("\noffered load sweep (llama3.3-70b, tp=16):")
    print(f"{'rate':>6} {'decode':>7} {'chunk':>6} {'packed_weaves':>14} "
          f"{'halves_weave':>13} {'packed_gain':>12}")
    for rate, s in online_summary(big, [5.0, 15.0, 25.0, 30.0, 40.0],
                                  tp=16).items():
        print(f"{rate:6.0f} {s['decode_tokens']:7.0f} "
              f"{s['chunk_tokens']:6.0f} {s['packed_weaves']:14.0f} "
              f"{s['halves_weave']:13.0f} {s['packed_gain']:12.3f}")


if __name__ == "__main__":
    main()
