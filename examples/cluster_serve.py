"""Cluster serving walkthrough (runtime/cluster.py, DESIGN.md §11): a
shared-prefix trace through a 3-replica fleet under each router — showing
where every request lands and that prefix-affinity keeps groups together —
then the same offered load through a disaggregated 2-prefill + 1-decode
fleet with KV handoff, showing the decode replica's merged batches weaving
where the monolithic fleet's engines sit below the floor; finally the
sim's analytic fleet crossover sweep.

    PYTHONPATH=src python examples/cluster_serve.py [--groups 4] \
        [--per-group 4] [--router prefix_affinity] [--requests 48]
"""
import argparse

import jax

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.build import build_model
from repro.runtime.cluster import (ClusterConfig, ClusterServer, Replica,
                                   ROUTERS)
from repro.runtime.engine import Engine
from repro.runtime.requests import (grouped_prefix_trace, poisson_arrivals,
                                    sharegpt_like_trace)
from repro.runtime.scheduler import SchedulerConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--groups", type=int, default=4)
    p.add_argument("--per-group", type=int, default=4)
    p.add_argument("--router", default=None, choices=sorted(ROUTERS),
                   help="run only this router (default: all three)")
    p.add_argument("--requests", type=int, default=48,
                   help="trace size for the disaggregation comparison")
    args = p.parse_args()

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=48)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    jit_cache = {}

    def engine(max_batch=16):
        return Engine(api, mesh, params,
                      SchedulerConfig(max_batch=max_batch, chunk_tokens=64,
                                      max_len=96, prefill_bucket=16,
                                      paged=True, block_size=8,
                                      packed=True), jit_cache=jit_cache)

    def affinity_trace():
        t = grouped_prefix_trace(args.groups, args.per_group, prefix_len=24,
                                 tail_len=6, output_len=6,
                                 vocab=cfg.vocab_size, seed=3)
        return poisson_arrivals(t, rate=0.5, seed=5)

    # ---- single-engine reference (the token-identity pin) ------------
    ref_eng = engine()
    for r in affinity_trace():
        ref_eng.add_request(r)
    ref = {r.rid: r.output for r in ref_eng.run()}

    routers = [args.router] if args.router else sorted(ROUTERS)
    for router in routers:
        reps = [Replica(f"r{i}", engine()) for i in range(3)]
        cs = ClusterServer(reps, ClusterConfig(router=router))
        for r in affinity_trace():
            cs.submit(r)
        done = cs.run()
        got = {r.rid: r.output for r in done}
        groups = {}
        for rid, name in sorted(cs.placement.items()):
            groups.setdefault(rid % args.groups, []).append(name)
        print(f"\n{router}: outputs identical to single engine: "
              f"{got == ref}")
        for g, names in sorted(groups.items()):
            print(f"  prompt-group {g}: {names}")
        s = cs.summary()
        print(f"  affinity_hit_rate={s['affinity_hit_rate']:.2f}  "
              + "  ".join(f"{r.name}:weave={s[f'{r.name}/weave_rate']:.2f}"
                          for r in reps))

    # ---- disaggregated prefill/decode vs monolithic fleet ------------
    def load_trace():
        t = sharegpt_like_trace(args.requests, vocab=cfg.vocab_size,
                                seed=11, max_in=32, max_out=32)
        for r in t:
            r.max_new_tokens = max(24, min(r.max_new_tokens, 32))
        return poisson_arrivals(t, rate=8.0, seed=5)

    ref_eng = engine()
    for r in load_trace():
        ref_eng.add_request(r)
    ref2 = {r.rid: r.output for r in ref_eng.run()}

    mono = [Replica(f"m{i}", engine()) for i in range(3)]
    cs_m = ClusterServer(mono, ClusterConfig(router="round_robin"))
    for r in load_trace():
        cs_m.submit(r)
    got_m = {r.rid: r.output for r in cs_m.run()}
    mono_fwd = sum(r.engine.stats.forwards for r in mono)
    mono_weave = (sum(r.engine.stats.weave_forwards for r in mono)
                  / max(mono_fwd, 1))

    disagg = [Replica("p0", engine(), role="prefill"),
              Replica("p1", engine(), role="prefill"),
              Replica("d0", engine(max_batch=48), role="decode")]
    cs_d = ClusterServer(disagg, ClusterConfig(router="round_robin"))
    for r in load_trace():
        cs_d.submit(r)
    got_d = {r.rid: r.output for r in cs_d.run()}
    s = cs_d.summary()
    st = disagg[2].engine.block_mgr.stats
    print(f"\ndisaggregation at the same offered load "
          f"({args.requests} requests, both fleets of 3):")
    print(f"  outputs identical (mono, disagg): "
          f"{got_m == ref2}, {got_d == ref2}")
    print(f"  monolithic fleet weave rate: {mono_weave:.2f}")
    print(f"  disagg decode-fleet weave rate: "
          f"{s['decode_fleet/weave_rate']:.2f}  "
          f"(migrations={int(s['migrations'])}, "
          f"imports shared/copied={st.import_shared_blocks}/"
          f"{st.import_copied_blocks})")

    # ---- the fleet-level story (analytic, 70B/tp16) ------------------
    from repro.configs import get_config
    from repro.sim.overlap_sim import cluster_crossover_rate, cluster_summary
    big = get_config("llama3.3-70b")
    rates = [10.0, 20.0, 30.0, 40.0, 60.0, 80.0]
    summ = cluster_summary(big, rates, n_replicas=4, tp=16)
    print("\ntotal offered load sweep (llama3.3-70b, tp=16, fleet of 4, "
          "1 decode replica):")
    print(f"{'rate':>6} {'mono_iter':>10} {'decode_fleet':>13} "
          f"{'mono_weaves':>12} {'fleet_weaves':>13} {'fleet_gain':>11}")
    for rate in rates:
        s = summ[rate]
        print(f"{rate:6.0f} {s['mono_iter_tokens']:10.0f} "
              f"{s['decode_fleet_tokens']:13.0f} "
              f"{s['mono_weaves']:12.0f} {s['decode_fleet_weaves']:13.0f} "
              f"{s['decode_fleet_gain']:11.3f}")
    print(f"crossover (fleet weaves, mono does not): "
          f"{cluster_crossover_rate(big, rates, 4, tp=16)}")


if __name__ == "__main__":
    main()
