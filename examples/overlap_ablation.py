"""Paper ablation (Fig. 16 analogue) on the calibrated v5e simulator:
vanilla vs RMSNorm-reordered vs fused-kernel-only vs full TokenWeave vs the
communication-free counterfactual, across models and sequence lengths.

    PYTHONPATH=src python examples/overlap_ablation.py
"""
from repro.configs import get_config
from repro.sim.overlap_sim import e2e_latency


def main():
    modes = ["vanilla", "reordered", "fuseonly", "tokenweave", "nocomm"]
    for arch in ("llama3.3-70b", "qwen2.5-72b", "mixtral-8x22b"):
        cfg = get_config(arch)
        print(f"\n=== {arch} on v5e-256 (tp=16), prefill latency (ms) ===")
        print(f"{'tokens':>8} " + " ".join(f"{m:>10}" for m in modes)
              + f" {'tw-gain':>8} {'vs-nocomm':>9}")
        for toks in (1024, 2048, 4096, 8192, 16384):
            r = {m: e2e_latency(cfg, m, toks, tp=16) for m in modes}
            print(f"{toks:8d} "
                  + " ".join(f"{r[m]*1e3:10.1f}" for m in modes)
                  + f" {r['vanilla']/r['tokenweave']:7.3f}x"
                  + f" {r['nocomm']/r['tokenweave']:8.3f}x")
    print("\n(tw-gain = paper Fig.11/16 speedup; vs-nocomm > 1 reproduces "
          "the paper's 'beats zero-communication' result)")


if __name__ == "__main__":
    main()
