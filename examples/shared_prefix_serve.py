"""Multi-turn shared-system-prompt serving through the PAGED engine: every
conversation starts with the same system prompt, and follow-up turns replay
their own growing history — the workload prefix caching is built for.

Reports prefix-hit rate, preemption count, evictions, and effective prefill
tokens saved vs. a no-prefix-cache run of the identical trace.  With greedy
sampling the two runs are token-identical, so the savings are pure.

    PYTHONPATH=src python examples/shared_prefix_serve.py \
        [--users 4] [--turns 3] [--paged-off]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.models.build import build_model
from repro.runtime.engine import Engine
from repro.runtime.requests import Request
from repro.runtime.scheduler import SchedulerConfig


def conversation_trace(users: int, turns: int, vocab: int, sys_len: int = 96,
                       turn_len: int = 24, seed: int = 0):
    """Per user: turn t's prompt = system + full history of turns < t +
    fresh user tokens (multi-turn chat replay, the serving-paper staple)."""
    rng = np.random.RandomState(seed)
    system = list(rng.randint(0, vocab, size=sys_len))
    convs = [[] for _ in range(users)]
    reqs = []
    rid = 0
    for t in range(turns):
        for u in range(users):
            fresh = list(rng.randint(0, vocab, size=turn_len))
            convs[u].extend(fresh)
            reqs.append(Request(rid=rid, prompt=system + list(convs[u]),
                                max_new_tokens=8))
            rid += 1
    return reqs


def run_trace(api, mesh, params, reqs, prefix_caching, paged, chunk,
              max_batch=4):
    eng = Engine(api, mesh, params,
                 SchedulerConfig(max_batch=max_batch, chunk_tokens=chunk,
                                 max_len=1024, prefill_bucket=32,
                                 paged=paged, block_size=16,
                                 prefix_caching=prefix_caching))
    for r in reqs:
        eng.add_request(r)
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    return eng, done, dt


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--users", type=int, default=4)
    p.add_argument("--turns", type=int, default=3)
    p.add_argument("--arch", default="qwen1.5-4b")
    p.add_argument("--chunk", type=int, default=128)
    p.add_argument("--paged-off", action="store_true",
                   help="legacy slot engine (no paging, no prefix cache)")
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=32, tokenweave_min_tokens=64)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))

    def fresh_trace():
        return conversation_trace(args.users, args.turns,
                                  vocab=cfg.vocab_size)

    paged = not args.paged_off
    eng, done, dt = run_trace(api, mesh, params, fresh_trace(),
                              prefix_caching=paged, paged=paged,
                              chunk=args.chunk)
    nominal = sum(len(r.prompt) for r in done)
    print(f"arch={cfg.name} paged={'on' if paged else 'off'}")
    print(f"requests completed   : {len(done)}")
    print(f"engine iterations    : {eng.stats.steps}")
    print(f"nominal prompt tokens: {nominal}")
    print(f"prefill tokens run   : {eng.stats.prefill_tokens}")
    print(f"decode tokens        : {eng.stats.decode_tokens}")
    print(f"wall time (CPU!)     : {dt:.1f}s")
    if paged:
        st = eng.block_mgr.stats
        # vs. actually-computed prefill (miss_tokens would also count
        # recompute-readmission contexts and understate savings)
        saved = nominal - eng.stats.prefill_tokens
        print(f"prefix-hit tokens    : {st.hit_tokens} "
              f"(hit rate {st.hit_rate:.1%})")
        print(f"prefill saved        : {saved} tokens "
              f"({saved / max(nominal, 1):.1%} of nominal prefill FLOPs)")
        print(f"preemptions          : {st.preemptions}")
        print(f"evictions            : {st.evictions}")
        print(f"cow copies           : {st.cow_copies}")

        # cross-check: identical trace, prefix cache off -> same tokens
        eng2, done2, _ = run_trace(api, mesh, params, fresh_trace(),
                                   prefix_caching=False, paged=True,
                                   chunk=args.chunk)
        same = all(a.output == b.output for a, b in
                   zip(sorted(done, key=lambda r: r.rid),
                       sorted(done2, key=lambda r: r.rid)))
        print(f"outputs identical to cold-prefill run: {same}")
        assert same, "prefix caching changed outputs!"


if __name__ == "__main__":
    main()
