"""Benchmark harness: one entry per paper table/figure.

CPU container => wall-clock TPU numbers are impossible; each figure is
reproduced through the calibrated two-stream simulator (sim/overlap_sim,
fed by the same v5e roofline constants the dry-run uses) plus CPU
micro-benchmarks where a kernel can be timed for real (interpret mode /
pure-jnp ops). Prints ``name,us_per_call,derived`` CSV rows; derived
carries the figure-level ratio the paper reports.

``--json PATH`` additionally writes the DETERMINISTIC serving metrics
(weave-activation rate, tokens/forward, prefix hit rate, spec acceptance
— counters, never wall clock) for the CI regression gate
(`scripts/check_bench.py` vs `benchmarks/baseline.json`).  Every gated
metric is sourced from a metrics-registry ``snapshot()`` (DESIGN.md §12)
and the JSON carries a ``__provenance__`` map recording where each value
came from — check_bench fails any baseline key it cannot trace back to
the registry.  The serve benchmarks additionally run with a
``TraceRecorder`` attached and assert the trace-derived weave counts
equal ``EngineStats`` EXACTLY; ``--trace PATH`` exports the merged
Chrome-trace/Perfetto JSON (inspect with scripts/trace_view.py).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only a,b] \
        [--strict] [--json BENCH_serve.json] [--trace BENCH_trace.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

# deterministic metrics collected during the run for --json (the CI
# regression gate compares them against benchmarks/baseline.json), the
# per-metric provenance map written alongside them, and the serve
# benchmarks' trace recorders (merged by --trace)
_METRICS: dict = {}
_PROVENANCE: dict = {}
_RECORDERS: list = []


def _metric(name, value, source="adhoc"):
    _METRICS[name] = round(float(value), 6)
    _PROVENANCE[name] = source


def _reg(name, snap, key):
    """Gated metric copied verbatim from a registry snapshot key."""
    _metric(name, snap[key], source=f"registry:{key}")


def _recorder(ns):
    """New TraceRecorder registered for the --trace export.  ``ns``
    namespaces request ids so merged traces keep one lifecycle thread
    per (benchmark, engine, rid)."""
    from repro.obs import TraceRecorder
    rec = TraceRecorder(request_ns=f"{ns}/")
    _RECORDERS.append(rec)
    return rec


def _assert_trace_matches(rec, stats, what, track=None):
    """The hard §12 invariant: weave counts recomputed from the trace's
    per-forward attribution spans equal the engine counters EXACTLY."""
    from repro.obs import weave_counts_from_trace
    w, n = weave_counts_from_trace(rec, track=track)
    assert (w, n) == (stats.weave_forwards, stats.forwards), (
        f"{what}: trace-derived weave counts ({w}/{n}) != EngineStats "
        f"({stats.weave_forwards}/{stats.forwards})")


def _row(name, us, derived=""):
    print(f"{name},{us if us == '' else f'{us:.2f}'},{derived}")


def _time_call(fn, *args, reps=5):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


# ---------------------------------------------------------------------------
def fig1_comm_overhead(quick=False):
    """Paper Fig.1: AllReduce overhead vs sequence length (sim, v5e)."""
    from repro.configs import get_config
    from repro.sim.overlap_sim import e2e_latency
    models = ["llama3.3-70b", "qwen2.5-72b", "mixtral-8x22b"]
    seqs = [1024, 4096, 16384] if quick else [1024, 2048, 4096, 8192, 16384]
    for m in models:
        cfg = get_config(m)
        for s in seqs:
            v = e2e_latency(cfg, "vanilla", s, tp=16)
            n = e2e_latency(cfg, "nocomm", s, tp=16)
            _row(f"fig1/{m}/seq{s}", v * 1e6,
                 f"comm_overhead={100*(v/n-1):.1f}%")


def fig4_fused_kernel(quick=False):
    """Paper Fig.4: AR+RMSNorm 3 ways (sim) + real CPU micro of the fused
    single-pass kernel vs the unfused reference."""
    from repro.sim.overlap_sim import HW, t_allreduce, t_norm, t_rs_or_ag
    hw = HW()
    d, n = 8192, 16
    for toks in ([1024, 8192] if quick else [1024, 2048, 4096, 8192, 16384]):
        vanilla = t_allreduce(toks, d, n, hw) + t_norm(toks, d, hw,
                                                       fused=False)
        reorder = (2 * t_rs_or_ag(toks, d, n, hw)
                   + t_norm(toks // n, d, hw, fused=False))
        fused = (2 * t_rs_or_ag(toks, d, n, hw)
                 + t_norm(toks // n, d, hw, fused=True))
        _row(f"fig4/sim/seq{toks}", vanilla * 1e6,
             f"reordered={reorder*1e6:.1f}us fused={fused*1e6:.1f}us "
             f"speedup={vanilla/fused:.2f}x")

    # CPU-real: fused single-pass vs unfused two-pass (jnp, jitted)
    from repro.kernels.ref import fused_residual_rmsnorm_ref
    from repro.layers.norms import residual_rmsnorm_unfused
    x = jnp.ones((2048, 1024), jnp.float32)
    r = jnp.ones((2048, 1024), jnp.float32)
    w = jnp.ones((1024,), jnp.float32)
    fused_us = _time_call(jax.jit(fused_residual_rmsnorm_ref), x, r, w)
    unfused_us = _time_call(jax.jit(residual_rmsnorm_unfused), x, r, w)
    _row("fig4/cpu_micro/fused_rmsnorm", fused_us,
         f"unfused={unfused_us:.1f}us ratio={unfused_us/fused_us:.2f}x")


def fig9_smart_split(quick=False):
    """Paper Fig.9: FFN latency — no-split vs equal vs smart split."""
    from repro.configs import get_config
    from repro.core.splitting import naive_split, smart_split, wave_count
    from repro.sim.overlap_sim import HW, t_ffn_layer
    cfg = get_config("llama3.3-70b")
    hw = HW()
    for toks in ([512, 1024, 4096] if quick else
                 [512, 768, 1024, 2048, 4096, 8192]):
        full = t_ffn_layer(cfg, toks, 16, hw)
        e0, e1 = naive_split(toks)
        equal = t_ffn_layer(cfg, e0, 16, hw) + t_ffn_layer(cfg, e1, 16, hw)
        sm = smart_split(toks, hw.tile)
        if sm:
            s0, s1 = sm
            smart = t_ffn_layer(cfg, s0, 16, hw) + t_ffn_layer(cfg, s1, 16,
                                                               hw)
        else:
            smart = full
        _row(f"fig9/seq{toks}", full * 1e6,
             f"equal_split={equal/full:.3f}x smart_split={smart/full:.3f}x "
             f"waves={wave_count(toks, hw.tile)}")


def fig11_latency(quick=False):
    """Paper Fig.11: prefill latency across models / seq / schemes."""
    from repro.configs import get_config
    from repro.sim.overlap_sim import e2e_latency
    models = ["llama3.3-70b"] if quick else \
        ["llama3.3-70b", "qwen2.5-72b", "mixtral-8x22b"]
    for m in models:
        cfg = get_config(m)
        for s in ([1024, 8192] if quick else [1024, 2048, 4096, 8192, 16384]):
            r = {md: e2e_latency(cfg, md, s, tp=16)
                 for md in ("vanilla", "fuseonly", "tokenweave", "nocomm")}
            _row(f"fig11/{m}/seq{s}", r["tokenweave"] * 1e6,
                 f"speedup_vs_vanilla={r['vanilla']/r['tokenweave']:.3f}x "
                 f"vs_nocomm={r['nocomm']/r['tokenweave']:.3f}x "
                 f"fuseonly={r['vanilla']/r['fuseonly']:.3f}x")


def fig12_throughput(quick=False):
    """Paper Fig.12/13: chunked-prefill throughput (sim; chunk sweep)."""
    from repro.configs import get_config
    from repro.sim.overlap_sim import e2e_latency
    cfg = get_config("llama3.3-70b")
    for chunk in ([2048] if quick else [1024, 2048, 4096, 8192]):
        tw = e2e_latency(cfg, "tokenweave", chunk, tp=16)
        va = e2e_latency(cfg, "vanilla", chunk, tp=16)
        _row(f"fig13/chunk{chunk}", tw * 1e6,
             f"tokens_per_s_tw={chunk/tw:,.0f} "
             f"throughput_gain={va/tw:.3f}x")


def fig12_engine_cpu(quick=False):
    """CPU-real end-to-end: tiny model through the continuous-batching
    engine, TokenWeave on vs off (correct outputs, measured steps/s)."""
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models.build import build_model
    from repro.runtime.engine import Engine
    from repro.runtime.requests import fixed_trace
    from repro.runtime.scheduler import SchedulerConfig

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    n_req = 4 if quick else 8
    results = {}
    for weave in (False, True):
        pcfg = ParallelConfig(tokenweave=weave, comm_mode="fused",
                              remat=False, split_unit=16,
                              tokenweave_min_tokens=32)
        api = build_model(cfg, pcfg, tp=1)
        params = api.init(jax.random.PRNGKey(0))
        eng = Engine(api, mesh, params,
                     SchedulerConfig(max_batch=4, chunk_tokens=64,
                                     max_len=256, prefill_bucket=32))
        for r in fixed_trace(n_req, 48, 8, vocab=128):
            eng.add_request(r)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        toks = eng.stats.prefill_tokens + eng.stats.decode_tokens
        results[weave] = (toks / dt, [r.output for r in done])
    assert results[True][1] == results[False][1], \
        "tokenweave changed outputs!"
    _row("fig12/cpu_engine", 1e6 / results[True][0],
         f"tokens_per_s={results[True][0]:.0f} outputs_identical=True")


def serve_prefix_cache(quick=False):
    """Paged-KV serving: multi-turn shared-system-prompt workload through
    the paged engine vs. the same trace cold (prefix cache off).  CPU-real;
    reports prefix-hit rate, preemptions, and prefill tokens saved —
    outputs are pinned token-identical between the two runs."""
    import sys as _sys
    import os as _os
    _sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), ".."))
    from examples.shared_prefix_serve import conversation_trace, run_trace
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models.build import build_model

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    users, turns = (2, 2) if quick else (4, 3)

    runs = {}
    for cached in (False, True):
        trace = conversation_trace(users, turns, vocab=cfg.vocab_size)
        eng, done, dt = run_trace(api, mesh, params, trace,
                                  prefix_caching=cached, paged=True,
                                  chunk=64, max_batch=2)
        runs[cached] = (eng, {r.rid: r.output for r in done}, dt)
    assert runs[True][1] == runs[False][1], "prefix cache changed outputs!"
    eng, _, dt = runs[True]
    cold_prefill = runs[False][0].stats.prefill_tokens
    st = eng.block_mgr.stats
    _row("serve/prefix_cache", dt * 1e6 / max(eng.stats.steps, 1),
         f"hit_rate={st.hit_rate:.2f} "
         f"prefill_saved={cold_prefill - eng.stats.prefill_tokens} "
         f"preemptions={st.preemptions} evictions={st.evictions} "
         f"outputs_identical=True")
    snap = eng.metrics_snapshot()
    cold_snap = runs[False][0].metrics_snapshot()
    _reg("serve/prefix_cache/hit_rate", snap, "paging/hit_rate")
    _metric("serve/prefix_cache/prefill_saved",
            cold_snap["engine/prefill_tokens"]
            - snap["engine/prefill_tokens"],
            source="derived:engine/prefill_tokens(cold-warm)")
    _reg("serve/prefix_cache/preemptions", snap, "paging/preemptions")


def serve_spec_decode(quick=False):
    """Speculative decoding through the paged engine (CPU-real): greedy
    n-gram-draft and model-self-draft runs vs. plain greedy decode on the
    same trace — outputs pinned token-identical; reports acceptance rate,
    committed tokens/step, and end-to-end speedup (engine iterations and
    wall clock) — plus the analytic weave-crossover row from the sim's
    spec mode."""
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models.build import build_model
    from repro.runtime.engine import Engine
    from repro.runtime.requests import repetitive_trace
    from repro.runtime.scheduler import SchedulerConfig
    from repro.runtime.spec import ModelDraft

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    n_req, n_new = (3, 16) if quick else (6, 32)
    gamma = 4

    def trace():
        # repeated-motif prompts: the prompt-lookup-friendly structure
        return repetitive_trace(n_req, motif_len=12, repeats=3,
                                output_len=n_new, vocab=cfg.vocab_size,
                                seed=7)

    def run(gamma_, draft=None):
        eng = Engine(api, mesh, params,
                     SchedulerConfig(max_batch=4, chunk_tokens=96,
                                     max_len=256, prefill_bucket=32,
                                     paged=True, spec_gamma=gamma_),
                     draft=draft)
        # pass 1 warms every jit cache; pass 2 is the timed, steady-state
        # run (its prompts also hit the prefix cache, so decode dominates —
        # the regime speculative decoding targets)
        for r in trace():
            eng.add_request(r)
        eng.run()
        s0 = eng.stats.steps
        for r in trace():
            eng.add_request(r)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        return eng, eng.stats.steps - s0, {r.rid: r.output for r in done}, dt

    eng0, steps0, ref, dt0 = run(0)
    runs = {"ngram": run(gamma),
            "model_draft": run(gamma, ModelDraft(api, mesh, params,
                                                 gamma=gamma, max_batch=4))}
    for name, (eng, steps, outs, dt) in runs.items():
        assert outs == ref, f"speculative ({name}) changed outputs!"
        st = eng.stats.spec
        assert st.acceptance_rate > 0, f"{name}: no draft token accepted"
        assert st.tokens_per_step > 1, f"{name}: spec not committing >1/step"
        _row(f"serve/spec_decode/{name}", dt * 1e6 / max(steps, 1),
             f"accept_rate={st.acceptance_rate:.2f} "
             f"tokens_per_step={st.tokens_per_step:.2f} "
             f"speedup_steps={steps0 / max(steps, 1):.2f}x "
             f"speedup_wall={dt0 / dt:.2f}x outputs_identical=True")
        snap = eng.metrics_snapshot()
        _reg(f"serve/spec_decode/{name}/accept_rate", snap,
             "spec/acceptance_rate")
        _reg(f"serve/spec_decode/{name}/tokens_per_step", snap,
             "spec/tokens_per_step")

    # analytic (sim spec mode): sub-wave decode batches commit E[tokens]
    # per step almost for free; large verify batches cross the weave
    # threshold so tokenweave beats the unsplit fused kernel
    from repro.configs import get_config
    from repro.sim.overlap_sim import spec_decode_summary
    big = get_config("llama3.3-70b")
    s32 = spec_decode_summary(big, batch=32, gamma=4, alpha=0.7, tp=16)
    _row("serve/spec_decode/sim_b32_g4", s32["spec/tokenweave"] * 1e6,
         f"spec_speedup={s32['plain/fuseonly']/s32['spec/tokenweave']:.2f}x "
         f"tokens_per_step={s32['tokens_per_step']:.2f}")
    s256 = spec_decode_summary(big, batch=256, gamma=4, alpha=0.7, tp=16)
    _row("serve/spec_decode/sim_b256_g4", s256["spec/tokenweave"] * 1e6,
         f"weave_gain_on_verify="
         f"{s256['spec/fuseonly']/s256['spec/tokenweave']:.3f}x "
         f"verify_tokens={s256['verify_tokens']:.0f} "
         f"tokens_per_step={s256['tokens_per_step']:.2f}")


def serve_packed(quick=False):
    """Packed hybrid batching (DESIGN.md §6, CPU-real): the same mixed
    prefill+decode trace through the two-dispatch engine and the packed
    engine — outputs pinned token-identical; reports weave-activation rate
    and tokens/forward for both (packed must weave strictly more often:
    mixed iterations whose decode and prefill halves are each below
    ``tokenweave_min_tokens`` jointly cross it), plus the sim's analytic
    packed crossover row."""
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models.build import build_model
    from repro.runtime.engine import Engine
    from repro.runtime.requests import repetitive_trace
    from repro.runtime.scheduler import SchedulerConfig

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    # genuine-crossover sizing (REAL tokens, not shape padding): four γ=3
    # verify windows carry 16 real tokens and the ragged prefill take adds
    # up to 16 more, so mixed packed iterations hit exactly the 32-token
    # threshold (asserted via max_forward_tokens below); the two-dispatch
    # engine judges the same halves apart — verify (4, 4) is far under the
    # row floor and its prefill chunk is capped at 32-16=16 tokens — and
    # only weaves on the rare pure-prefill iteration
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    n_req = 6 if quick else 10

    def run(packed):
        tag = "packed" if packed else "two_dispatch"
        rec = _recorder(f"packed:{tag}")
        eng = Engine(api, mesh, params,
                     SchedulerConfig(max_batch=4, chunk_tokens=32,
                                     max_len=256, prefill_bucket=16,
                                     paged=True, spec_gamma=3,
                                     packed=packed),
                     obs=rec, obs_track=f"packed/{tag}")
        for r in repetitive_trace(n_req, motif_len=12, repeats=3,
                                  output_len=10, vocab=cfg.vocab_size,
                                  seed=7):
            eng.add_request(r)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        return eng, {r.rid: r.output for r in done}, dt, rec

    two, ref, _, rec2 = run(False)
    pk, got, dt, recp = run(True)
    assert got == ref, "packed batching changed outputs!"
    _assert_trace_matches(rec2, two.stats, "serve/packed two_dispatch")
    _assert_trace_matches(recp, pk.stats, "serve/packed packed")
    assert pk.stats.weave_rate > two.stats.weave_rate, (
        f"packed weave rate {pk.stats.weave_rate:.2f} not above "
        f"two-dispatch {two.stats.weave_rate:.2f}")
    assert pk.stats.max_forward_tokens >= pcfg.tokenweave_min_tokens, (
        "packed crossover must be carried by real tokens, not padding")
    _row("serve/packed", dt * 1e6 / max(pk.stats.steps, 1),
         f"weave_rate={pk.stats.weave_rate:.2f} "
         f"weave_rate_two_dispatch={two.stats.weave_rate:.2f} "
         f"tokens_per_forward={pk.stats.tokens_per_forward:.1f} "
         f"vs_two_dispatch={two.stats.tokens_per_forward:.1f} "
         f"forwards={pk.stats.forwards} vs {two.stats.forwards} "
         f"max_real_tokens={pk.stats.max_forward_tokens} "
         f"outputs_identical=True")
    snap_pk, snap_two = pk.metrics_snapshot(), two.metrics_snapshot()
    _reg("serve/packed/weave_rate", snap_pk, "engine/weave_rate")
    _reg("serve/packed/weave_rate_two_dispatch", snap_two,
         "engine/weave_rate")
    _reg("serve/packed/tokens_per_forward", snap_pk,
         "engine/tokens_per_forward")
    _reg("serve/packed/tokens_per_forward_two_dispatch", snap_two,
         "engine/tokens_per_forward")
    _reg("serve/packed/max_forward_tokens", snap_pk,
         "engine/max_forward_tokens")

    # analytic (sim packed mode): the crossover cell — decode batch and
    # prefill chunk each under the wave/threshold floor (no split), the
    # packed iteration over it (splits, overlaps)
    from repro.configs import get_config
    from repro.sim.overlap_sim import packed_summary
    big = get_config("llama3.3-70b")
    s = packed_summary(big, decode_tokens=256, chunk_tokens=384, tp=16)
    _row("serve/packed/sim_d256_c384", s["packed/tokenweave"] * 1e6,
         f"packed_gain={s['packed/fuseonly']/s['packed/tokenweave']:.3f}x "
         f"two_dispatch_gain={s['two/fuseonly']/s['two/tokenweave']:.3f}x "
         f"halves_weave={s['halves_weave']:.0f} "
         f"packed_weaves={s['packed_weaves']:.0f}")


def serve_online(quick=False):
    """Online serving frontend (runtime/server.py, DESIGN.md §10,
    CPU-real): a seeded Poisson-arrival ShareGPT-like trace through the
    OnlineServer on BOTH dispatch schemes — emitted tokens pinned
    identical to the OFFLINE engine on the same trace (the continuous-
    batching guarantee transfers to arrival dynamics) — reporting virtual-
    time TTFT/TPOT percentiles, goodput under tight deadlines, and the
    load-dependent weave rate; plus the sim's analytic crossover row
    (offered-load window where only the packed iteration weaves)."""
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models.build import build_model
    from repro.runtime.engine import Engine
    from repro.runtime.requests import (poisson_arrivals,
                                        sharegpt_like_trace)
    from repro.runtime.scheduler import SchedulerConfig
    from repro.runtime.server import OnlineServer, ServerConfig, StepCost

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    n_req = 8 if quick else 16

    def trace():
        t = sharegpt_like_trace(n_req, vocab=cfg.vocab_size, seed=11,
                                max_in=48, max_out=8)
        for r in t:
            r.max_new_tokens = max(2, min(r.max_new_tokens, 8))
        return poisson_arrivals(t, rate=0.25, seed=5)

    def scfg(packed):
        return SchedulerConfig(max_batch=4, chunk_tokens=48, max_len=128,
                               prefill_bucket=16, paged=True, packed=packed)

    jit_caches = {False: {}, True: {}}

    def offline(packed):
        eng = Engine(api, mesh, params, scfg(packed),
                     jit_cache=jit_caches[packed])
        for r in trace():
            eng.add_request(r)
        done = eng.run()
        return eng, {r.rid: r.output for r in done}

    def online(packed, tag, deadline=None):
        rec = _recorder(f"online:{tag}")
        eng = Engine(api, mesh, params, scfg(packed),
                     jit_cache=jit_caches[packed],
                     obs=rec, obs_track=f"online/{tag}")
        srv = OnlineServer(eng, ServerConfig(
            step_cost=StepCost(base=1.0, per_token=0.05),
            expire_on_deadline=deadline is not None))
        for r in trace():
            if deadline is not None:
                r.deadline = r.arrival_time + deadline
            srv.submit(r)
        done = srv.run()
        return eng, srv, {r.rid: r.output for r in done}, rec

    # the offline reference engines run UNTRACED: got == ref below is the
    # §12 on/off identity check riding along with the dispatch-scheme one
    _, ref = offline(False)
    _, ref_pk = offline(True)
    assert ref_pk == ref, "offline packed diverged from two-dispatch!"
    eng2, _, got2, rec2 = online(False, "two_dispatch")
    engp, srvp, gotp, recp = online(True, "packed")
    assert got2 == ref, "online two-dispatch changed emitted tokens!"
    assert gotp == ref, "online packed changed emitted tokens!"
    _assert_trace_matches(rec2, eng2.stats, "serve/online two_dispatch")
    _assert_trace_matches(recp, engp.stats, "serve/online packed")
    lat = engp.stats.latency.summary()
    _row("serve/online", srvp.clock * 1e6 / max(engp.stats.steps, 1),
         f"goodput={lat['goodput']:.2f} ttft_p50={lat['ttft_p50']:.2f} "
         f"tpot_p50={lat['tpot_p50']:.2f} e2e_p99={lat['e2e_p99']:.2f} "
         f"weave_rate={engp.stats.weave_rate:.2f} "
         f"weave_rate_two_dispatch={eng2.stats.weave_rate:.2f} "
         f"outputs_identical=True")
    snapp, snap2 = engp.metrics_snapshot(), eng2.metrics_snapshot()
    _reg("serve/online/goodput", snapp, "latency/goodput")
    _reg("serve/online/ttft_p50", snapp, "latency/ttft/p50")
    _reg("serve/online/tpot_p50", snapp, "latency/tpot/p50")
    _reg("serve/online/e2e_p99", snapp, "latency/e2e/p99")
    _reg("serve/online/weave_rate", snapp, "engine/weave_rate")
    _reg("serve/online/weave_rate_two_dispatch", snap2,
         "engine/weave_rate")

    # tight e2e deadlines under the same load: some requests expire (their
    # blocks/prefix refs released mid-flight), goodput drops below 1 —
    # deterministic virtual-time counters, gated like the rest
    engd, srvd, _, recd = online(True, "slo", deadline=14.0)
    _assert_trace_matches(recd, engd.stats, "serve/online slo")
    latd = engd.stats.latency.summary()
    _row("serve/online/slo", srvd.clock * 1e6 / max(engd.stats.steps, 1),
         f"goodput={latd['goodput']:.2f} expired={engd.stats.expired} "
         f"completed={engd.stats.completed}")
    snapd = engd.metrics_snapshot()
    _reg("serve/online/slo_goodput", snapd, "latency/goodput")
    _reg("serve/online/slo_expired", snapd, "engine/expired")

    # analytic (sim online mode): the offered-load window where the packed
    # iteration crosses the split floor but the two-dispatch halves don't
    from repro.configs import get_config
    from repro.sim.overlap_sim import online_crossover_rate, online_summary
    big = get_config("llama3.3-70b")
    rates = [5.0, 15.0, 25.0, 30.0, 40.0]
    summ = online_summary(big, rates, tp=16)
    cross = online_crossover_rate(big, rates, tp=16)
    x = summ[cross] if cross is not None else summ[rates[-1]]
    _row("serve/online/sim_load_sweep",
         x["t_iter_packed"] * 1e6,
         f"crossover_rate={cross} decode_tokens={x['decode_tokens']:.0f} "
         f"chunk_tokens={x['chunk_tokens']:.0f} "
         f"packed_gain={x['packed_gain']:.3f} "
         f"halves_weave={x['halves_weave']:.0f}")


def serve_cluster(quick=False):
    """Cluster serving layer (runtime/cluster.py, DESIGN.md §11,
    CPU-real): N independent engine replicas behind a pluggable router.

    Part 1 — routing: a grouped shared-prefix trace through a 3-replica
    mixed fleet under every router (round_robin, least_loaded,
    prefix_affinity); outputs pinned token-identical to a SINGLE engine on
    the same seeded trace for each (greedy outputs are batch-composition-
    invariant, so where a request lands never changes what it generates);
    prefix_affinity must actually find hot blocks (affinity hit rate > 0).

    Part 2 — disaggregation: the same offered load through (a) a
    monolithic fleet of 3 mixed replicas and (b) 2 prefill + 1 decode
    replica with KV handoff.  Outputs pinned identical to the single
    engine again, every request migrates exactly once, and the decode
    fleet's merged batches must weave STRICTLY more often than the
    monolithic fleet's (the §11 payoff: concentrated decode traffic
    crosses ``tokenweave_min_tokens`` at loads where a monolithic
    engine's share sits below it) — plus the sim's analytic crossover
    row."""
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models.build import build_model
    from repro.runtime.cluster import ClusterConfig, ClusterServer, Replica
    from repro.runtime.engine import Engine
    from repro.runtime.requests import (grouped_prefix_trace,
                                        poisson_arrivals,
                                        sharegpt_like_trace)
    from repro.runtime.scheduler import SchedulerConfig

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=48)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))

    jit_cache = {}

    def engine(max_batch=16, chunk=64, obs=None):
        return Engine(api, mesh, params,
                      SchedulerConfig(max_batch=max_batch,
                                      chunk_tokens=chunk, max_len=96,
                                      prefill_bucket=16, paged=True,
                                      block_size=8, packed=True),
                      jit_cache=jit_cache, obs=obs)

    def single_ref(trace):
        eng = engine()
        for r in trace():
            eng.add_request(r)
        return {r.rid: r.output for r in eng.run()}

    # ---- part 1: routers on a shared-prefix workload ------------------
    per_group = 3 if quick else 4

    def affinity_trace():
        t = grouped_prefix_trace(3, per_group, prefix_len=24, tail_len=6,
                                 output_len=6, vocab=cfg.vocab_size, seed=3)
        return poisson_arrivals(t, rate=0.5, seed=5)

    ref = single_ref(affinity_trace)
    summaries, cs_aff = {}, None
    for router in ("round_robin", "least_loaded", "prefix_affinity"):
        reps = [Replica(f"r{i}", engine()) for i in range(3)]
        cs = ClusterServer(reps, ClusterConfig(router=router))
        for r in affinity_trace():
            cs.submit(r)
        got = {r.rid: r.output for r in cs.run()}
        assert got == ref, f"cluster ({router}) changed outputs!"
        cs.check_quiescent()
        summaries[router] = cs.summary()
        if router == "prefix_affinity":
            cs_aff = cs
    aff = summaries["prefix_affinity"]["affinity_hit_rate"]
    assert aff > 0, "prefix_affinity never found a hot block"

    # ---- part 2: disaggregated prefill/decode vs monolithic fleet -----
    n_req, rate = (36, 8.0) if quick else (48, 8.0)

    def load_trace():
        t = sharegpt_like_trace(n_req, vocab=cfg.vocab_size, seed=11,
                                max_in=32, max_out=32)
        for r in t:
            r.max_new_tokens = max(24, min(r.max_new_tokens, 32))
        return poisson_arrivals(t, rate=rate, seed=5)

    ref2 = single_ref(load_trace)

    rec_m = _recorder("cluster:mono")
    mono = [Replica(f"m{i}", engine(obs=rec_m)) for i in range(3)]
    cs_m = ClusterServer(mono, ClusterConfig(router="round_robin"))
    for r in load_trace():
        cs_m.submit(r)
    assert {r.rid: r.output for r in cs_m.run()} == ref2, \
        "monolithic fleet changed outputs!"
    cs_m.check_quiescent()
    mono_fwd = sum(r.engine.stats.forwards for r in mono)
    mono_wv = sum(r.engine.stats.weave_forwards for r in mono)
    mono_weave = mono_wv / max(mono_fwd, 1)
    from repro.obs import weave_counts_from_trace
    wm, nm = weave_counts_from_trace(rec_m)
    assert (wm, nm) == (mono_wv, mono_fwd), (
        f"serve/cluster mono fleet: trace weave counts ({wm}/{nm}) != "
        f"fleet counters ({mono_wv}/{mono_fwd})")

    rec_d = _recorder("cluster:disagg")
    disagg = [Replica("p0", engine(obs=rec_d), role="prefill"),
              Replica("p1", engine(obs=rec_d), role="prefill"),
              Replica("d0", engine(max_batch=48, obs=rec_d),
                      role="decode")]
    t0 = time.perf_counter()
    cs_d = ClusterServer(disagg, ClusterConfig(router="round_robin"))
    for r in load_trace():
        cs_d.submit(r)
    assert {r.rid: r.output for r in cs_d.run()} == ref2, \
        "disaggregated cluster changed outputs!"
    dt = time.perf_counter() - t0
    cs_d.check_quiescent()
    sd = cs_d.summary()
    d0 = disagg[2].engine.stats
    _assert_trace_matches(rec_d, d0, "serve/cluster d0", track="d0")
    wd, nd = weave_counts_from_trace(rec_d)
    dis_fwd = sum(r.engine.stats.forwards for r in disagg)
    dis_wv = sum(r.engine.stats.weave_forwards for r in disagg)
    assert (wd, nd) == (dis_wv, dis_fwd), (
        f"serve/cluster disagg fleet: trace weave counts ({wd}/{nd}) != "
        f"fleet counters ({dis_wv}/{dis_fwd})")
    assert sd["migrations"] == n_req, \
        f"expected {n_req} migrations, got {sd['migrations']}"
    assert sd["decode_fleet/weave_rate"] > mono_weave, (
        f"decode-fleet weave rate {sd['decode_fleet/weave_rate']:.2f} not "
        f"above the monolithic fleet's {mono_weave:.2f}")
    assert d0.max_forward_tokens >= pcfg.tokenweave_min_tokens - 16, (
        "decode-fleet crossover must be carried by merged real decode "
        "batches")
    steps = sum(r.engine.stats.steps for r in disagg)
    _row("serve/cluster", dt * 1e6 / max(steps, 1),
         f"affinity_hit_rate={aff:.2f} migrations={int(sd['migrations'])} "
         f"decode_fleet_weave={sd['decode_fleet/weave_rate']:.2f} "
         f"mono_fleet_weave={mono_weave:.2f} "
         f"d0_tokens_per_forward={d0.tokens_per_forward:.1f} "
         f"import_shared_blocks="
         f"{disagg[2].engine.block_mgr.stats.import_shared_blocks} "
         f"outputs_identical=True")
    snap_aff = cs_aff.metrics_snapshot()
    snap_d = cs_d.metrics_snapshot()
    _reg("serve/cluster/affinity_hit_rate", snap_aff,
         "summary/affinity_hit_rate")
    _reg("serve/cluster/migrations", snap_d, "summary/migrations")
    _metric("serve/cluster/mono_fleet_weave_rate", mono_weave,
            source="derived:engine/weave_forwards over engine/forwards "
                   "(mono fleet aggregate)")
    _reg("serve/cluster/decode_fleet_weave_rate", snap_d,
         "summary/decode_fleet/weave_rate")
    _reg("serve/cluster/p0_weave_rate", snap_d, "summary/p0/weave_rate")
    _reg("serve/cluster/p1_weave_rate", snap_d, "summary/p1/weave_rate")
    _reg("serve/cluster/d0_tokens_per_forward", snap_d,
         "summary/d0/tokens_per_forward")

    # analytic (sim cluster mode): the total-offered-load window where the
    # disaggregated decode fleet's merged batches weave while a monolithic
    # engine's 1/N share of the same traffic sits under the split floor
    from repro.configs import get_config
    from repro.sim.overlap_sim import cluster_crossover_rate, cluster_summary
    big = get_config("llama3.3-70b")
    rates = [10.0, 20.0, 30.0, 40.0, 60.0, 80.0]
    summ = cluster_summary(big, rates, n_replicas=4, tp=16)
    cross = cluster_crossover_rate(big, rates, 4, tp=16)
    x = summ[cross] if cross is not None else summ[rates[-1]]
    _row("serve/cluster/sim_fleet4", x["t_iter_decode_fleet"] * 1e6,
         f"crossover_rate={cross} "
         f"decode_fleet_tokens={x['decode_fleet_tokens']:.0f} "
         f"mono_iter_tokens={x['mono_iter_tokens']:.0f} "
         f"decode_fleet_gain={x['decode_fleet_gain']:.3f} "
         f"mono_weaves={x['mono_weaves']:.0f}")


def serve_cluster_wire(quick=False):
    """Wire transport + failure handling on the serving hot path
    (runtime/transport.py + runtime/cluster.py, DESIGN.md §15).

    A disaggregated fleet served over the LOOPBACK WIRE: every submit
    envelope and KV-migration payload crosses the versioned frame codec
    (the same bytes a socket would carry), with per-byte wire latency
    charged into migration delay.  Mid-trace one decode replica is
    KILLED; the heartbeat detector requeues everything it owned onto the
    survivors with recompute semantics.  Hard assertions: outputs stay
    token-identical to a never-failed single engine, the death/requeue
    counters fire, and the block-pool quiescence sweep passes afterwards.
    Gated metrics: wire frame/byte counts and the frame-size p50 straight
    from the ``cluster/wire/*`` registry instruments, plus the
    ``cluster/replica_deaths`` / ``cluster/requeued`` fault counters."""
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models.build import build_model
    from repro.runtime.cluster import ClusterConfig, ClusterServer, Replica
    from repro.runtime.engine import Engine
    from repro.runtime.requests import poisson_arrivals, sharegpt_like_trace
    from repro.runtime.scheduler import SchedulerConfig

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=48)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))

    jit_cache = {}

    def engine():
        return Engine(api, mesh, params,
                      SchedulerConfig(max_batch=8, chunk_tokens=64,
                                      max_len=96, prefill_bucket=16,
                                      paged=True, block_size=8),
                      jit_cache=jit_cache)

    n_req = 10 if quick else 16

    def trace():
        t = sharegpt_like_trace(n_req, vocab=cfg.vocab_size, seed=13,
                                max_in=32, max_out=24)
        for r in t:
            r.max_new_tokens = max(12, min(r.max_new_tokens, 24))
        return poisson_arrivals(t, rate=2.0, seed=7)

    ref_eng = engine()
    for r in trace():
        ref_eng.add_request(r)
    ref = {r.rid: r.output for r in ref_eng.run()}

    reps = [Replica("p0", engine(), role="prefill"),
            Replica("d0", engine(), role="decode"),
            Replica("d1", engine(), role="decode")]
    cs = ClusterServer(reps, ClusterConfig(
        router="round_robin", wire="loopback", wire_per_byte=1e-6,
        heartbeat_timeout=2.0))
    for r in trace():
        cs.submit(r)
    cs.kill_replica("d0", at=3.0)          # mid-trace decode-replica crash
    t0 = time.perf_counter()
    done = cs.run()
    dt = time.perf_counter() - t0
    assert {r.rid: r.output for r in done} == ref, \
        "wire cluster with replica kill changed outputs!"
    assert cs.stats.replica_deaths == 1, "the kill never landed"
    assert cs.stats.requeued >= 1, \
        "d0 died holding no work — the recovery path went unexercised"
    cs.check_quiescent()

    snap = cs.metrics_snapshot()
    steps = sum(r.engine.stats.steps for r in reps)
    _row("serve/cluster_wire", dt * 1e6 / max(steps, 1),
         f"frames={int(snap['cluster/wire/frames'])} "
         f"bytes={int(snap['cluster/wire/bytes'])} "
         f"frame_bytes_p50={snap['cluster/wire/frame_bytes/p50']:.0f} "
         f"replica_deaths={int(snap['cluster/replica_deaths'])} "
         f"requeued={int(snap['cluster/requeued'])} "
         f"outputs_identical=True")
    _reg("serve/cluster_wire/frames", snap, "cluster/wire/frames")
    _reg("serve/cluster_wire/bytes", snap, "cluster/wire/bytes")
    _reg("serve/cluster_wire/frame_bytes_p50", snap,
         "cluster/wire/frame_bytes/p50")
    _reg("serve/cluster_wire/replica_deaths", snap,
         "cluster/replica_deaths")
    _reg("serve/cluster_wire/requeued", snap, "cluster/requeued")


def serve_policy(quick=False):
    """Per-site overlap policy & tuned plan cache (core/policy.py +
    analysis/autotune.py, DESIGN.md §14).

    Part 1 — CPU-real: the tiny engine on the same seeded trace under the
    DEGENERATE global-threshold policy (plan id 0) vs the committed tuned
    plan cache (``benchmarks/plans/default.json``), on both dispatch
    schemes.  Emitted tokens are pinned identical across all four runs —
    the policy only reshapes HOW a forward overlaps, never what it
    computes — and the trace-derived weave counts must equal the engine
    counters on every traced run.  Per-site weave rates come from the
    engine's ``engine/site_weave_rate{site=...}`` gauges; since the plan
    routes every tiny bucket onto the ring fused kernel (method fused /
    fused-unsplit), the tuned engine's ``engine/site_fused_rate`` gauges
    must read 1.0 — on this CPU backend the ring mode gates down the
    fallback ladder, which is exactly why the tokens stay pinned.

    Part 2 — analytic (sim, 70B/tp8): the load sweep where the tuned
    plan must beat the degenerate policy — its fused entries dispatch
    the ring AllReduce-RMSNorm kernel on a half ring-lane grant
    (budget 0.5 -> 4 lanes, the paper's few-SM fused collective), so the
    overlapped fraction rises and the makespan drops at EVERY sweep
    point.  Both asserted strictly.

    Part 3 — analytic (sim, 70B/tp8): the fused-path crossover the
    paper claims (Fig. 8): the tuned fused configuration must STRICTLY
    beat both the unsplit fused-collective baseline (fuseonly — no
    weave) and the naive weave (tokenweave with composed collectives) at
    every sweep point; the minimum gains are gated in baseline.json."""
    import os

    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.core.policy import load_policy
    from repro.models.build import build_model
    from repro.runtime.engine import Engine
    from repro.runtime.requests import sharegpt_like_trace
    from repro.runtime.scheduler import SchedulerConfig

    plan_path = os.path.join(os.path.dirname(__file__), "plans",
                             "default.json")
    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    n_req = 8 if quick else 16
    jit_caches: dict = {}

    def trace():
        t = sharegpt_like_trace(n_req, vocab=cfg.vocab_size, seed=7,
                                max_in=56, max_out=8)
        for r in t:
            r.max_new_tokens = max(2, min(r.max_new_tokens, 8))
        return t

    def run(tag, packed, plan=None):
        rec = _recorder(f"policy:{tag}")
        eng = Engine(api, mesh, params,
                     SchedulerConfig(max_batch=4, chunk_tokens=64,
                                     max_len=256, prefill_bucket=16,
                                     paged=True, packed=packed,
                                     plan_path=plan),
                     jit_cache=jit_caches.setdefault((tag, packed), {}),
                     obs=rec, obs_track=f"policy/{tag}")
        for r in trace():
            eng.add_request(r)
        done = eng.run()
        return eng, {r.rid: r.output for r in done}, rec

    t0 = time.perf_counter()
    eng_d2, ref, rec_d2 = run("threshold", False)
    eng_dp, got_dp, rec_dp = run("threshold_packed", True)
    eng_t2, got_t2, rec_t2 = run("tuned", False, plan=plan_path)
    eng_tp, got_tp, rec_tp = run("tuned_packed", True, plan=plan_path)
    dt = time.perf_counter() - t0
    for what, got in (("threshold packed", got_dp), ("tuned", got_t2),
                      ("tuned packed", got_tp)):
        assert got == ref, f"serve/policy: {what} changed emitted tokens!"
    for rec, eng, what in ((rec_d2, eng_d2, "threshold"),
                           (rec_dp, eng_dp, "threshold packed"),
                           (rec_t2, eng_t2, "tuned"),
                           (rec_tp, eng_tp, "tuned packed")):
        _assert_trace_matches(rec, eng.stats, f"serve/policy {what}")

    snap_d2 = eng_d2.metrics_snapshot()
    snap_dp = eng_dp.metrics_snapshot()
    snap_tp = eng_tp.metrics_snapshot()
    tuned_id = int(snap_tp["engine/plan_id"])
    assert snap_d2["engine/plan_id"] == 0, \
        "degenerate engine must report plan id 0"
    assert tuned_id > 0, "tuned engine did not load the plan cache"
    # gated: the degenerate plan id is pinned 0; the tuned plan id is
    # content-derived (changes on every retune), so the GATE is only
    # that a plan loaded — the actual id is reported in the CSV row
    _reg("serve/policy/plan_id", snap_d2, "engine/plan_id")
    _metric("serve/policy/tuned_plan_loaded", 1.0,
            source="derived:engine/plan_id > 0 (tuned engine)")
    _reg("serve/policy/weave_rate", snap_dp, "engine/weave_rate")
    _reg("serve/policy/tuned_weave_rate", snap_tp, "engine/weave_rate")
    _reg("serve/policy/site_weave_rate_prefill", snap_d2,
         "engine/site_weave_rate{site=prefill}")
    _reg("serve/policy/site_weave_rate_decode", snap_d2,
         "engine/site_weave_rate{site=decode}")
    _reg("serve/policy/site_weave_rate_packed", snap_dp,
         "engine/site_weave_rate{site=packed}")
    _reg("serve/policy/tuned_site_weave_rate_packed", snap_tp,
         "engine/site_weave_rate{site=packed}")
    # fused-path routing: every decided site of the tuned engine rides a
    # fused (ring-mode) plan entry; the threshold engine rides none
    assert snap_tp["engine/site_fused_rate{site=packed}"] == 1.0, \
        "tuned plan did not route the packed site onto the fused path"
    assert snap_dp.get("engine/site_fused_rate{site=packed}", 0.0) == 0.0
    _reg("serve/policy/tuned_site_fused_rate_packed", snap_tp,
         "engine/site_fused_rate{site=packed}")

    # ---- part 2: tuned-vs-threshold on the sim load sweep (70B/tp8) ---
    from repro.configs import get_config
    from repro.core.splitting import plan_split
    from repro.obs import MetricsRegistry
    from repro.sim.overlap_sim import HW, step_attribution
    big = get_config("llama3.3-70b")
    unit = ParallelConfig().split_unit_for(8)
    hw = HW(tile=unit)
    policy = load_policy(plan_path)
    sim_mode = {"weave": "tokenweave", "fused": "ringweave",
                "fused-unsplit": "ring", "none": "vanilla"}
    toks = [512, 2048, 8192] if quick else [512, 1024, 2048, 4096, 8192]
    deg_mk = deg_ov = tun_mk = tun_ov = 0.0
    for n in toks:
        deg = step_attribution(big, "tokenweave", n, tp=8, hw=hw)
        plan = policy.plan_for("prefill", n, tp=8, family=big.family)
        assert plan is not None, f"plan cache misses 70B/tp8 at {n} tokens"
        tun = step_attribution(
            big, sim_mode[plan.method], n, tp=8, hw=hw,
            split=(plan_split(n, unit, plan.split_frac)
                   if plan.method in ("weave", "fused") else None),
            comm_budget=None if plan.budget == 1.0 else plan.budget)
        assert tun["makespan"] < deg["makespan"], (
            f"tuned plan slower than threshold at {n} tokens: "
            f"{tun['makespan']:.3e} vs {deg['makespan']:.3e}")
        assert tun["overlapped"] / tun["makespan"] > \
            deg["overlapped"] / deg["makespan"], (
            f"tuned overlap fraction not above threshold at {n} tokens")
        deg_mk += deg["makespan"]
        deg_ov += deg["overlapped"]
        tun_mk += tun["makespan"]
        tun_ov += tun["overlapped"]
    deg_frac, tun_frac = deg_ov / deg_mk, tun_ov / tun_mk
    assert tun_frac > deg_frac, (
        f"tuned aggregate overlap fraction {tun_frac:.4f} not above the "
        f"global threshold's {deg_frac:.4f}")
    # provenance: publish the sim fractions through a registry snapshot
    # like every other gated metric
    simreg = MetricsRegistry()
    simreg.gauge("sim/policy/overlap_frac", policy="threshold").set(deg_frac)
    simreg.gauge("sim/policy/overlap_frac", policy="tuned").set(tun_frac)
    snap_sim = simreg.snapshot()
    _reg("serve/policy/sim_overlap_frac_threshold", snap_sim,
         "sim/policy/overlap_frac{policy=threshold}")
    _reg("serve/policy/sim_overlap_frac_tuned", snap_sim,
         "sim/policy/overlap_frac{policy=tuned}")

    # ---- part 3: fused crossover — ring-fused vs unsplit vs naive weave
    gain_unsplit = gain_weave = float("inf")
    for n in toks:
        plan = policy.plan_for("prefill", n, tp=8, family=big.family)
        assert plan is not None and plan.method in ("fused",
                                                    "fused-unsplit"), (
            f"70B/tp8 plan entry at {n} tokens is {plan and plan.method!r}"
            f", expected a fused method")
        fused = step_attribution(
            big, sim_mode[plan.method], n, tp=8, hw=hw,
            split=(plan_split(n, unit, plan.split_frac)
                   if plan.method == "fused" else None),
            comm_budget=None if plan.budget == 1.0 else plan.budget)
        unsplit = step_attribution(big, "fuseonly", n, tp=8, hw=hw)
        naive = step_attribution(big, "tokenweave", n, tp=8, hw=hw)
        assert fused["makespan"] < unsplit["makespan"], (
            f"fused not beating unsplit at {n} tokens: "
            f"{fused['makespan']:.3e} vs {unsplit['makespan']:.3e}")
        assert fused["makespan"] < naive["makespan"], (
            f"fused not beating naive weave at {n} tokens: "
            f"{fused['makespan']:.3e} vs {naive['makespan']:.3e}")
        gain_unsplit = min(gain_unsplit,
                           unsplit["makespan"] / fused["makespan"])
        gain_weave = min(gain_weave, naive["makespan"] / fused["makespan"])
    simreg.gauge("sim/policy/fused_gain", vs="unsplit").set(gain_unsplit)
    simreg.gauge("sim/policy/fused_gain", vs="naive_weave").set(gain_weave)
    snap_sim = simreg.snapshot()
    _reg("serve/policy/sim_fused_gain_vs_unsplit", snap_sim,
         "sim/policy/fused_gain{vs=unsplit}")
    _reg("serve/policy/sim_fused_gain_vs_weave", snap_sim,
         "sim/policy/fused_gain{vs=naive_weave}")

    steps = eng_dp.stats.steps + eng_tp.stats.steps
    _row("serve/policy", dt * 1e6 / max(steps, 1),
         f"plan_id=0 tuned_plan_id={tuned_id} "
         f"weave_rate={eng_dp.stats.weave_rate:.2f} "
         f"tuned_weave_rate={eng_tp.stats.weave_rate:.2f} "
         f"outputs_identical=True")
    _row("serve/policy/sim_tp8_sweep", tun_mk / len(toks) * 1e6,
         f"overlap_frac_threshold={deg_frac:.3f} "
         f"overlap_frac_tuned={tun_frac:.3f} "
         f"makespan_gain={deg_mk / tun_mk:.3f}x")
    _row("serve/policy/sim_fused_crossover", tun_mk / len(toks) * 1e6,
         f"min_gain_vs_unsplit={gain_unsplit:.3f}x "
         f"min_gain_vs_naive_weave={gain_weave:.3f}x")


def fig14_overlap_comparison(quick=False):
    """Paper Fig.14 analogue: TokenWeave vs a TileLink-style GEMM-fused
    overlap (which can only hide comm inside GEMMs and pays split RS/AG)."""
    from repro.configs import get_config
    from repro.sim.overlap_sim import (HW, e2e_latency, t_attn_layer,
                                       t_ffn_layer, t_rs_or_ag)
    cfg = get_config("llama3.3-70b")
    hw = HW()
    tp = 16
    for toks in ([1024, 8192] if quick else [1024, 2048, 4096, 8192, 16384]):
        tw = e2e_latency(cfg, "tokenweave", toks, tp=tp)
        va = e2e_latency(cfg, "vanilla", toks, tp=tp)
        # TileLink-style: RS overlapped with producer GEMM (capped by GEMM
        # time), AG overlapped with next GEMM; norms unfused; per-CTA
        # streaming adds ~15% GEMM overhead (paper Fig.14 shows occupancy
        # loss); attention comm not overlappable.
        attn = t_attn_layer(cfg, toks, toks, tp, hw) * 1.15
        ffn = t_ffn_layer(cfg, toks, tp, hw) * 1.15
        rs = t_rs_or_ag(toks, cfg.d_model, tp, hw)
        from repro.sim.overlap_sim import t_norm
        norm = t_norm(toks, cfg.d_model, hw, fused=False)
        per_layer = (attn + max(rs - ffn, 0) + rs + norm
                     + ffn + max(rs - attn, 0) + rs + norm)
        tl = per_layer * cfg.num_layers
        _row(f"fig14/seq{toks}", tw * 1e6,
             f"tokenweave={va/tw:.3f}x tilelink_style={va/tl:.3f}x")


def fig16_ablation(quick=False):
    """Paper Fig.16: vllm-multimem vs fuseonly vs full TokenWeave."""
    from repro.configs import get_config
    from repro.sim.overlap_sim import e2e_latency
    for m in (["llama3.3-70b"] if quick else
              ["llama3.3-70b", "qwen2.5-72b", "mixtral-8x22b"]):
        cfg = get_config(m)
        for s in ([2048, 8192] if quick else [1024, 2048, 4096, 8192]):
            base = e2e_latency(cfg, "vanilla", s, tp=16)
            fo = e2e_latency(cfg, "fuseonly", s, tp=16)
            tw = e2e_latency(cfg, "tokenweave", s, tp=16)
            _row(f"fig16/{m}/seq{s}", tw * 1e6,
                 f"fuseonly={base/fo:.3f}x full={base/tw:.3f}x")


def kernels_micro(quick=False):
    """Interpret-mode kernel micro-latency (correctness-bearing, CPU)."""
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.fused_rmsnorm import fused_residual_rmsnorm_pallas
    x = jnp.ones((256, 512), jnp.float32)
    r = jnp.ones((256, 512), jnp.float32)
    w = jnp.ones((512,), jnp.float32)
    us = _time_call(
        jax.jit(lambda a, b, c: fused_residual_rmsnorm_pallas(
            a, b, c, interpret=True, block_tokens=64)), x, r, w, reps=2)
    _row("kernels/fused_rmsnorm_interpret", us, "pallas_interpret")
    q = jnp.ones((1, 64, 2, 2, 32))
    k = jnp.ones((1, 64, 2, 32))
    qp = jnp.broadcast_to(jnp.arange(64)[None], (1, 64))
    us = _time_call(
        jax.jit(lambda q_, k_: flash_attention(
            q_, k_, k_, qp, qp, causal=True, block_q=32, block_kv=32,
            interpret=True)), q, k, reps=2)
    _row("kernels/flash_attention_interpret", us, "pallas_interpret")


def profile_calibration(quick=False, report_path=None):
    """Measured-time profile smoke + cost-model calibration (§13, opt-in
    via --profile): run the tiny CPU model through the engine with a
    ``WallClockProfiler`` attached, assert the profiled run is token-
    identical to an unprofiled reference, fit the ``HW`` cost model from
    the steady samples, and publish everything under the ``measured:``
    provenance namespace — informational (machine-dependent), exempt
    from the ±15% determinism gate, but drift-gated in CI through
    scripts/check_calibration.py on the report this writes."""
    from repro.analysis.calibration import fit_calibration
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models.build import build_model
    from repro.obs import WallClockProfiler
    from repro.runtime.engine import Engine
    from repro.runtime.requests import sharegpt_like_trace
    from repro.runtime.scheduler import SchedulerConfig

    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    n_req = 8 if quick else 16
    jit_cache = {}

    def run(profiler, rec=None):
        eng = Engine(api, mesh, params,
                     SchedulerConfig(max_batch=4, chunk_tokens=64,
                                     max_len=256, prefill_bucket=16,
                                     paged=True),
                     jit_cache=jit_cache, profiler=profiler,
                     obs=rec, obs_track="profile")
        # varied prompt lengths -> several prefill buckets -> several
        # (method, tokens) calibration buckets
        for r in sharegpt_like_trace(n_req, vocab=cfg.vocab_size, seed=7,
                                     max_in=56, max_out=8):
            r.max_new_tokens = max(2, min(r.max_new_tokens, 8))
            eng.add_request(r)
        done = eng.run()
        return eng, {r.rid: tuple(r.output) for r in done}

    _, ref = run(None)                        # also pre-compiles the cache
    prof = WallClockProfiler()
    # the recorder gives the measured spans a home in the merged --trace
    # export (virtual spans on "profile", wall time on "profile [measured]")
    eng, got = run(prof, rec=_recorder("profile"))
    assert got == ref, "profiling changed tokens!"

    steady = prof.steady_samples()
    rep = fit_calibration(api.cfg, steady, tp=1,
                          tile=pcfg.split_unit_for(1))
    rep.export_to(eng.metrics)
    snap = eng.metrics.snapshot()
    for key in sorted(snap):
        if key.startswith("profile/"):
            _reg(f"measured:{key}", snap, key)
    _row("profile/calibration", rep.overhead * 1e6,
         f"n_steady={len(steady)} mfu_cap={rep.mfu_cap:.3g} "
         f"ici_gbps={rep.ici / 1e9:.3g} worst_rel_err={rep.worst_rel_err:.3f} "
         f"outputs_identical=True")
    for mode in sorted(rep.per_mode_rel_err):
        _row(f"profile/predicted_vs_measured/{mode}",
             rep.per_mode_rel_err[mode] * 1e6,
             f"rel_err={rep.per_mode_rel_err[mode]:.3f}")
    if report_path:
        rep.save(report_path)
        print(f"wrote calibration report to {report_path}", file=sys.stderr)


FIGS = [fig1_comm_overhead, fig4_fused_kernel, fig9_smart_split,
        fig11_latency, fig12_throughput, fig12_engine_cpu,
        serve_prefix_cache, serve_spec_decode, serve_packed, serve_online,
        serve_cluster, serve_cluster_wire, serve_policy,
        fig14_overlap_comparison,
        fig16_ablation, kernels_micro]


def _select_figs(only: str | None):
    """Resolve ``--only`` (comma-separated section names, substring match
    per entry) to a figure list.  An entry matching NOTHING is an error —
    a typo'd filter used to silently run zero figures, which would make
    the CI gate vacuously green."""
    if not only:
        return list(FIGS)
    valid = [f.__name__ for f in FIGS]
    selected, seen = [], set()
    for entry in only.split(","):
        entry = entry.strip()
        matches = [f for f in FIGS if entry and entry in f.__name__]
        if not matches:
            raise SystemExit(
                f"--only entry {entry!r} matches no benchmark section; "
                f"valid names: {', '.join(valid)}")
        for f in matches:
            if f.__name__ not in seen:
                seen.add(f.__name__)
                selected.append(f)
    return selected


def main() -> None:
    p = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog="Scenario-by-scenario docs and the semantics of every "
               "gated metric: benchmarks/README.md.  Baseline update "
               "workflow: README.md (top level).")
    p.add_argument("--quick", action="store_true")
    p.add_argument("--only", default=None,
                   help="comma-separated section names (substring match); "
                        "unknown names error with the valid choices")
    p.add_argument("--strict", action="store_true",
                   help="exit non-zero if any figure errors (CI gate; the "
                        "default keeps the full local sweep robust)")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write the deterministic serving metrics as JSON "
                        "(compared against benchmarks/baseline.json by "
                        "scripts/check_bench.py), with a __provenance__ "
                        "map recording each metric's registry source")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="export the serve benchmarks' merged Chrome-trace/"
                        "Perfetto JSON (inspect or --validate it with "
                        "scripts/trace_view.py; load at "
                        "https://ui.perfetto.dev)")
    p.add_argument("--profile", action="store_true",
                   help="run the measured-time profile smoke + calibration "
                        "fit (DESIGN.md §13); wall-clock results land in "
                        "the JSON under the measured: namespace "
                        "(provenance-required, tolerance-exempt)")
    p.add_argument("--calibration-out", default=None, metavar="PATH",
                   help="write the CalibrationReport JSON (implies "
                        "--profile; gate it with "
                        "scripts/check_calibration.py)")
    args = p.parse_args()
    figs = _select_figs(args.only)
    if args.profile or args.calibration_out:
        def _profile(quick=False):
            profile_calibration(quick=quick,
                                report_path=args.calibration_out)
        _profile.__name__ = "profile_calibration"
        figs.append(_profile)
    print("name,us_per_call,derived")
    errors = 0
    for fig in figs:
        try:
            fig(quick=args.quick)
        except Exception as e:  # keep the harness robust
            errors += 1
            _row(f"{fig.__name__}/ERROR", 0.0, f"{type(e).__name__}: {e}")
            import traceback
            traceback.print_exc(file=sys.stderr)
    if args.json:
        payload = dict(_METRICS)
        payload["__provenance__"] = dict(_PROVENANCE)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(_METRICS)} metrics to {args.json}",
              file=sys.stderr)
    if args.trace:
        if _RECORDERS:
            from repro.obs import export_chrome_trace
            doc = export_chrome_trace(_RECORDERS, path=args.trace)
            print(f"wrote trace ({len(doc['traceEvents'])} events, "
                  f"{len(_RECORDERS)} recorders) to {args.trace}",
                  file=sys.stderr)
        else:
            print("no trace recorded (no serve benchmark ran)",
                  file=sys.stderr)
    if args.strict and errors:
        sys.exit(1)


if __name__ == "__main__":
    main()
