"""Layer-level correctness: attention impls, MoE vs dense oracle, SSM
chunking/decode consistency — all on a 1x1 mesh (same code path as the
production mesh; collectives over size-1 axes are identities)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.layers import ssm as S
from repro.layers import moe as M
from repro.layers.attention import attention_layout, multihead_attention


def test_attention_chunked_matches_ref():
    key = jax.random.PRNGKey(0)
    b, sq, sk, h, kv, dh = 2, 48, 80, 8, 2, 16
    q = jax.random.normal(key, (b, sq, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, kv, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, kv, dh))
    qpos = jnp.broadcast_to(jnp.arange(32, 32 + sq)[None], (b, sq))
    kpos = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))
    for causal in (True, False):
        for window in (0, 24):
            o_ref = multihead_attention(q, k, v, qpos, kpos, causal=causal,
                                        window=window, impl="ref")
            o_ch = multihead_attention(q, k, v, qpos, kpos, causal=causal,
                                       window=window, impl="chunked",
                                       block_q=16, block_kv=32)
            np.testing.assert_allclose(o_ref, o_ch, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("tp,h,kv,expect", [
    (16, 4, 1, (4, 1, 1, 4)),     # gemma3: replicas
    (16, 20, 20, (4, 5, 5, 4)),   # qwen1.5 MHA
    (16, 64, 8, (16, 4, 1, 1)),   # deepseek GQA
    (16, 64, 4, (16, 4, 1, 1)),   # qwen3-moe
    (8, 8, 8, (8, 1, 1, 1)),      # whisper at tp=8
    (1, 4, 2, (1, 4, 2, 1)),      # single device
])
def test_attention_layout(tp, h, kv, expect):
    lay = attention_layout(tp, h, kv, 128)
    assert (lay.attn_tp, lay.h_loc, lay.kv_store, lay.replicas) == expect
    # every shard covers h_loc q-heads; attn_tp * h_loc == num_heads
    assert lay.attn_tp * lay.h_loc == h
    assert lay.attn_tp * lay.replicas == tp


def _dense_moe_oracle(params, x, cfg):
    wg, wu, wd = (params["w_gate"][0], params["w_up"][0],
                  params["w_down"][0])
    t = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(t @ params["router"][0], -1)
    topw, topi = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    if cfg.norm_topk_prob:
        topw = topw / topw.sum(-1, keepdims=True)
    out = jnp.zeros_like(t)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(t @ wg[e]) * (t @ wu[e])
        w_e = jnp.where(topi == e, topw, 0.0).sum(-1)
        out = out + w_e[:, None] * (h @ wd[e])
    return out.reshape(x.shape)


def test_moe_expert_mode_matches_dense(mesh11):
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                      num_experts=8, num_experts_per_tok=2, moe_d_ff=16,
                      capacity_factor=8.0, dtype="float32")
    p = M.init_moe_params(jax.random.PRNGKey(0), cfg, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    f = jax.jit(jax.shard_map(lambda: M.moe_forward(p, x, cfg)[0],
                              mesh=mesh11, in_specs=(), out_specs=P(None),
                              check_vma=False))
    np.testing.assert_allclose(f(), _dense_moe_oracle(p, x, cfg), rtol=1e-4,
                               atol=1e-5)


def test_moe_capacity_drops_tokens(mesh11):
    """With capacity_factor << 1 tokens get dropped, outputs stay finite."""
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                      num_experts=4, num_experts_per_tok=2, moe_d_ff=16,
                      capacity_factor=0.25, dtype="float32")
    p = M.init_moe_params(jax.random.PRNGKey(0), cfg, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    f = jax.jit(jax.shard_map(lambda: M.moe_forward(p, x, cfg)[0],
                              mesh=mesh11, in_specs=(), out_specs=P(None),
                              check_vma=False))
    out = f()
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("version", [1, 2])
def test_ssm_chunked_equals_decode(version, mesh11):
    cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=64,
                      ssm_state=8, ssm_dt_rank=8, ssm_expand=2,
                      ssm_version=version, ssm_heads=4 if version == 2 else 0,
                      dtype="float32")
    mod_fwd = S.mamba1_forward if version == 1 else S.mamba2_forward
    mod_dec = S.mamba1_decode if version == 1 else S.mamba2_decode
    init = S.init_mamba1_params if version == 1 else S.init_mamba2_params
    p = init(jax.random.PRNGKey(3), cfg, 1)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 24, 32))

    def run(chunk):
        def f():
            return mod_fwd(p, x, cfg=cfg, chunk=chunk)[0]
        return jax.jit(jax.shard_map(f, mesh=mesh11, in_specs=(),
                                     out_specs=P(None), check_vma=False))()

    o_full, o_small = run(24), run(5)
    np.testing.assert_allclose(o_full, o_small, rtol=1e-4, atol=1e-4)

    def run_decode():
        def f():
            if version == 1:
                st = (jnp.zeros((2, cfg.ssm_conv - 1, 64)),
                      jnp.zeros((2, 64, 8)))
            else:
                st = ((jnp.zeros((2, 3, 64)), jnp.zeros((2, 3, 16))),
                      jnp.zeros((2, 4, 16, 8)))
            outs = []
            for t in range(24):
                o, st = mod_dec(p, x[:, t:t + 1], st, cfg=cfg)
                outs.append(o)
            return jnp.concatenate(outs, 1)
        return jax.jit(jax.shard_map(f, mesh=mesh11, in_specs=(),
                                     out_specs=P(None), check_vma=False))()

    np.testing.assert_allclose(o_full, run_decode(), rtol=1e-4, atol=1e-4)


def test_ssm_prefix_state_handoff(mesh11):
    """TokenWeave split dependency: suffix starting from the prefix's final
    state equals the unsplit scan (DESIGN.md §4, falcon-mamba row)."""
    cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=64,
                      ssm_state=8, ssm_dt_rank=8, dtype="float32")
    p = S.init_mamba1_params(jax.random.PRNGKey(3), cfg, 1)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, 32))

    def f():
        o_full, _ = S.mamba1_forward(p, x, cfg=cfg, chunk=8)
        o0, st0 = S.mamba1_forward(p, x[:, :20], cfg=cfg, chunk=8)
        o1, _ = S.mamba1_forward(p, x[:, 20:], cfg=cfg, init_state=st0,
                                 chunk=8)
        return o_full, jnp.concatenate([o0, o1], axis=1)

    a, b = jax.jit(jax.shard_map(f, mesh=mesh11, in_specs=(),
                                 out_specs=(P(None), P(None)),
                                 check_vma=False))()
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
