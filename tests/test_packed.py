"""Packed hybrid batching (DESIGN.md §6).

Equivalence suite: the packed engine (one forward per iteration over the
concatenated prefill/decode/verify token axis) must produce TOKEN-IDENTICAL
greedy outputs to the two-dispatch engine across

* both KV backends (legacy slots and the paged block pool),
* prefix-cache hits (admission starts mid-context),
* recompute preemption (pool starvation),
* sliding-window layer patterns on the paged backend, and
* speculative-decoding verify windows (gamma > 0) on both backends,

while weaving strictly MORE often on mixed prefill+decode traffic — the
whole point of packing.  Plus scheduler properties: a packed plan's token
accounting never exceeds ``chunk_tokens`` and always carries every
decoding request.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.build import build_model
from repro.runtime.engine import Engine
from repro.runtime.requests import Request, repetitive_trace
from repro.runtime.scheduler import (PackedPlan, Scheduler, SchedulerConfig,
                                     State)


def _prompts(vocab, sizes=(23, 57, 40, 18), seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(0, vocab, size=n)) for n in sizes]


def _run(api, mesh, params, prompts, *, packed, n_new=6, draft=None,
         **scfg_kw):
    scfg_kw.setdefault("max_batch", 3)
    scfg_kw.setdefault("chunk_tokens", 48)
    scfg_kw.setdefault("max_len", 128)
    scfg_kw.setdefault("prefill_bucket", 16)
    eng = Engine(api, mesh, params, SchedulerConfig(packed=packed,
                                                    **scfg_kw), draft=draft)
    for i, p in enumerate(prompts):
        eng.add_request(Request(rid=i, prompt=list(p), max_new_tokens=n_new))
    done = eng.run()
    return eng, {r.rid: r.output for r in done}


@pytest.fixture(scope="module")
def tiny(tiny_model):
    """Alias of the shared session-scoped tiny model (conftest.py)."""
    return tiny_model


# --------------------------------------------------------------------------
# token identity vs the two-dispatch engine
# --------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True], ids=["legacy", "paged"])
def test_packed_token_identical(paged, tiny, tiny_cfg):
    """More requests than slots: iterations mix decode with the next
    admission's prefill chunks — the regime packing exists for."""
    api, mesh, params = tiny
    prompts = _prompts(tiny_cfg.vocab_size)
    two, ref = _run(api, mesh, params, prompts, packed=False, paged=paged)
    pk, got = _run(api, mesh, params, prompts, packed=True, paged=paged)
    assert got == ref, (got, ref)
    assert len(got) == len(prompts)
    # packing can only reduce dispatch count: one forward per iteration
    assert pk.stats.forwards <= two.stats.forwards
    assert pk.stats.forwards <= pk.stats.steps


def test_packed_weave_rate_strictly_higher(tiny, tiny_cfg):
    """Mixed spec+prefill traffic sized so packed iterations cross
    tokenweave_min_tokens (32) with REAL tokens — four γ=3 verify windows
    (16) plus a 16-token ragged prefill take — while the two-dispatch
    engine judges the halves apart (verify (4, 4) under the row floor,
    prefill capped at 16 by the verify charge) and all but never weaves."""
    api, mesh, params = tiny
    trace = repetitive_trace(6, motif_len=12, repeats=3, output_len=10,
                             vocab=tiny_cfg.vocab_size, seed=7)
    prompts = [r.prompt for r in trace]
    kw = dict(max_batch=4, chunk_tokens=32, max_len=256, paged=True,
              spec_gamma=3, n_new=10)
    two, ref = _run(api, mesh, params, prompts, packed=False, **kw)
    pk, got = _run(api, mesh, params, prompts, packed=True, **kw)
    assert got == ref
    assert pk.stats.weave_rate > two.stats.weave_rate
    assert pk.stats.tokens_per_forward > two.stats.tokens_per_forward
    # the crossover is carried by real tokens, not static-shape padding
    assert pk.stats.max_forward_tokens >= 32


def test_packed_prefix_cache_identity(tiny, tiny_cfg):
    """Shared-prefix prompts over two admission waves: packed prefill
    segments start mid-context at the hit length and still reproduce the
    cold outputs."""
    api, mesh, params = tiny
    rng = np.random.RandomState(1)
    shared = list(rng.randint(0, tiny_cfg.vocab_size, size=40))
    prompts = [shared + list(rng.randint(0, tiny_cfg.vocab_size, size=8))
               for _ in range(5)]
    kw = dict(max_batch=2, chunk_tokens=64, paged=True, prefix_caching=True,
              n_new=5)
    _, ref = _run(api, mesh, params, prompts, packed=False, **kw)
    pk, got = _run(api, mesh, params, prompts, packed=True, **kw)
    assert got == ref
    assert pk.block_mgr.stats.hit_rate > 0


def test_packed_preemption_identity(tiny, tiny_cfg):
    """Starved pool: recompute preemption mid-plan drops the victim's
    segment and the readmission re-prefills through packed chunks."""
    api, mesh, params = tiny
    prompts = _prompts(tiny_cfg.vocab_size, sizes=(30, 30, 30, 30), seed=2)
    kw = dict(max_batch=4, chunk_tokens=64, paged=True, num_blocks=11,
              block_size=16, prefix_caching=False, n_new=12)
    _, ref = _run(api, mesh, params, prompts, packed=False, **kw)
    pk, got = _run(api, mesh, params, prompts, packed=True, **kw)
    assert got == ref
    assert pk.block_mgr.stats.preemptions > 0


def test_packed_sliding_window_paged(mesh11, tiny_pcfg):
    """gemma3-style local/global pattern (unrolled per-layer caches) on
    the paged backend: windows are mask-enforced, so packed scatter is
    safe there."""
    cfg = ModelConfig(name="t", family="dense", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, sliding_window=16,
                      local_global_period=3, dtype="float32")
    api = build_model(cfg, tiny_pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg.vocab_size, sizes=(23, 40, 31), seed=4)
    _, ref = _run(api, mesh11, params, prompts, packed=False, paged=True)
    _, got = _run(api, mesh11, params, prompts, packed=True, paged=True)
    assert got == ref


# --------------------------------------------------------------------------
# speculative decoding through the packed plan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True], ids=["legacy", "paged"])
def test_packed_spec_identity(paged, tiny, tiny_cfg):
    """Verify windows ride the packed axis: greedy spec output stays
    token-identical to plain greedy AND to two-dispatch spec, on both
    backends.  (Acceptance COUNTERS may differ legitimately: ragged packed
    prefill shifts iteration boundaries, so per-step draft contexts
    diverge — greedy rejection sampling keeps the committed stream
    invariant regardless.)"""
    api, mesh, params = tiny
    trace = repetitive_trace(4, motif_len=12, repeats=3, output_len=12,
                             vocab=tiny_cfg.vocab_size, seed=7)
    prompts = [r.prompt for r in trace]
    kw = dict(max_batch=4, chunk_tokens=96, max_len=256, paged=paged,
              n_new=12)
    _, ref = _run(api, mesh, params, prompts, packed=False, **kw)
    _, got2 = _run(api, mesh, params, prompts, packed=False, spec_gamma=3,
                   **kw)
    pk, got = _run(api, mesh, params, prompts, packed=True, spec_gamma=3,
                   **kw)
    assert got == ref and got2 == ref
    assert pk.stats.spec.acceptance_rate > 0
    assert pk.stats.spec.verify_steps > 0
    assert pk.stats.spec.tokens_per_step >= 1.0


# --------------------------------------------------------------------------
# configuration gates
# --------------------------------------------------------------------------

def test_packed_rejects_unsupported(mesh11, tiny_pcfg):
    ssm_cfg = ModelConfig(name="s", family="ssm", num_layers=2, d_model=64,
                          num_heads=0, num_kv_heads=0, d_ff=0,
                          vocab_size=128, ssm_state=8, ssm_dt_rank=8,
                          dtype="float32")
    api = build_model(ssm_cfg, tiny_pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="token axis"):
        Engine(api, mesh11, params,
               SchedulerConfig(max_batch=2, chunk_tokens=32, max_len=64,
                               packed=True))

    win_cfg = ModelConfig(name="w", family="dense", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=128, sliding_window=16, dtype="float32")
    wapi = build_model(win_cfg, tiny_pcfg, tp=1)
    wparams = wapi.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged backend"):
        Engine(wapi, mesh11, wparams,
               SchedulerConfig(max_batch=2, chunk_tokens=32, max_len=64,
                               packed=True))

    import dataclasses
    shard_pcfg = dataclasses.replace(tiny_pcfg, seq_shard_kv=True)
    sapi = build_model(win_cfg, shard_pcfg, tp=1)
    with pytest.raises(ValueError, match="seq_shard_kv"):
        Engine(sapi, mesh11, wparams,
               SchedulerConfig(max_batch=2, chunk_tokens=32, max_len=64,
                               paged=True, packed=True))

    with pytest.raises(ValueError, match="chunk_tokens"):
        SchedulerConfig(max_batch=8, chunk_tokens=4, packed=True)
    with pytest.raises(ValueError, match="chunk_tokens"):
        SchedulerConfig(max_batch=4, chunk_tokens=8, spec_gamma=3,
                        packed=True)


# --------------------------------------------------------------------------
# scheduler packed-plan accounting (no model needed)
# --------------------------------------------------------------------------

def _check_plan(plan, scfg):
    """Invariants of one freshly emitted plan (checked BEFORE committing
    it — states mutate afterwards)."""
    w = scfg.spec_gamma + 1 if scfg.spec_gamma else 1
    # THE accounting invariant: budgeted tokens never exceed the chunk
    assert plan.total_tokens <= scfg.chunk_tokens, plan
    assert plan.total_tokens == sum(s.n_tokens for s in plan.segments)
    slots = [s.req.slot for s in plan.segments]
    assert len(set(slots)) == len(slots)           # one segment per slot
    for seg in plan.segments:
        if seg.kind == "prefill":
            assert seg.req.state == State.PREFILL
            assert seg.n_tokens >= 1
        else:
            assert seg.req.state == State.DECODE
            assert seg.kind == ("verify" if scfg.spec_gamma else "decode")
            assert seg.n_tokens == w


def _drive_plans(scfg, requests, max_iters=500):
    """Drive the scheduler's packed planning with an engine-less commit
    loop (prefill advances, decode appends a fake token), checking every
    plan's invariants at emission time."""
    sched = Scheduler(scfg)
    for r in requests:
        sched.add(r)
    plans = []
    for _ in range(max_iters):
        plan = sched.next_step()
        if plan is None:
            break
        assert isinstance(plan, PackedPlan)
        _check_plan(plan, scfg)
        n_decoding = sum(1 for r in sched.active
                         if r is not None and r.state == State.DECODE)
        assert sum(1 for s in plan.segments if s.kind != "prefill") \
            == n_decoding
        plans.append(plan)
        for seg in plan.segments:
            r = seg.req
            if seg.kind == "prefill":
                r.prefill_pos += seg.n_tokens
                if r.prefill_done:
                    r.output.append(1)
                    r.state = State.DECODE
            else:
                r.output.append(1)
            if len(r.output) >= r.max_new_tokens:
                sched.finish(r, 0)
    assert sched.all_done()
    return plans


@pytest.mark.parametrize("gamma", [0, 3])
def test_packed_plan_accounting(gamma):
    scfg = SchedulerConfig(max_batch=3, chunk_tokens=40, max_len=512,
                           prefill_bucket=16, packed=True, spec_gamma=gamma)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, prompt=list(rng.randint(0, 99, size=n)),
                    max_new_tokens=4)
            for i, n in enumerate((100, 7, 63, 31, 1, 200))]
    plans = _drive_plans(scfg, reqs)
    assert any(s.kind == "prefill" for p in plans for s in p.segments)


def test_packed_plan_accounting_props():
    """Property sweep (hypothesis-style but deterministic): random
    max_batch/chunk/gamma/prompt mixes never violate the budget."""
    rng = np.random.RandomState(42)
    for trial in range(25):
        gamma = int(rng.choice([0, 0, 2, 4]))
        max_batch = int(rng.randint(1, 6))
        floor = max_batch * (gamma + 1)
        chunk = int(rng.randint(floor, floor + 120))
        scfg = SchedulerConfig(max_batch=max_batch, chunk_tokens=chunk,
                               max_len=1024, prefill_bucket=16, packed=True,
                               spec_gamma=gamma)
        n_req = int(rng.randint(1, 9))
        reqs = [Request(rid=i,
                        prompt=list(rng.randint(0, 99,
                                                size=rng.randint(1, 300))),
                        max_new_tokens=int(rng.randint(1, 6)))
                for i in range(n_req)]
        _drive_plans(scfg, reqs, max_iters=20000)
