"""Shared fixtures. Tests in this process see ONE CPU device; multi-device
semantics are exercised via subprocess helpers (run_distributed) so the
512-device dry-run flag never leaks into the main test process."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_distributed(code: str, n_devices: int = 8, timeout: int = 420):
    """Run a python snippet in a subprocess with n fake CPU devices.
    The snippet should raise/assert on failure and print 'PASS' on success.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (
        f"distributed snippet failed\nSTDOUT:\n{proc.stdout[-3000:]}\n"
        f"STDERR:\n{proc.stderr[-3000:]}")
    assert "PASS" in proc.stdout, proc.stdout[-2000:]
    return proc.stdout


@pytest.fixture(scope="session")
def mesh11():
    import jax
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def tiny_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=128, dtype="float32")


@pytest.fixture(scope="session")
def tiny_pcfg():
    from repro.configs.base import ParallelConfig
    return ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)


@pytest.fixture(scope="session")
def model_builder(tiny_pcfg):
    """Session-memoized ``(cfg[, pcfg, tp]) -> (api, params)``: the tiny
    models test modules used to rebuild per test are built ONCE and shared
    (params are never mutated — engines only read them)."""
    import jax
    from repro.models.build import build_model

    cache = {}

    def build(cfg, pcfg=None, tp=1):
        key = (repr(cfg), repr(pcfg), tp)
        if key not in cache:
            api = build_model(cfg, pcfg if pcfg is not None else tiny_pcfg,
                              tp=tp)
            cache[key] = (api, api.init(jax.random.PRNGKey(0)))
        return cache[key]

    return build


@pytest.fixture(scope="session")
def tiny_model(mesh11, tiny_cfg, model_builder):
    """The standard tiny dense transformer: ``(api, mesh, params)`` —
    the shape every engine test wants."""
    api, params = model_builder(tiny_cfg)
    return api, mesh11, params


@pytest.fixture(scope="session")
def tiny_engine_builder(tiny_model):
    """Factory for tiny engines over the shared model.  Engines with the
    same scheduler/sampling signature share a jit cache, so replaying many
    short traces (the differential harness, lifecycle tests) compiles each
    step shape once per configuration instead of once per engine."""
    from repro.runtime.engine import Engine
    from repro.runtime.scheduler import SchedulerConfig

    jit_caches = {}

    def build(*, draft=None, seed=0, temperature=0.0, top_k=0, top_p=1.0,
              obs=None, obs_track="engine", profiler=None, **scfg_kw):
        api, mesh, params = tiny_model
        scfg_kw.setdefault("max_batch", 4)
        scfg_kw.setdefault("chunk_tokens", 48)
        scfg_kw.setdefault("max_len", 128)
        scfg_kw.setdefault("prefill_bucket", 16)
        # obs/profiler are deliberately NOT in the jit-cache key: tracing
        # and measured-time profiling must not change compilation (on/off
        # identity, DESIGN.md §12/§13)
        key = tuple(sorted(scfg_kw.items())) + (temperature, top_k, top_p)
        cache = jit_caches.setdefault(key, {})
        return Engine(api, mesh, params, SchedulerConfig(**scfg_kw),
                      temperature=temperature, top_k=top_k, top_p=top_p,
                      draft=draft, seed=seed, jit_cache=cache,
                      obs=obs, obs_track=obs_track, profiler=profiler)

    return build
