"""Shared fixtures. Tests in this process see ONE CPU device; multi-device
semantics are exercised via subprocess helpers (run_distributed) so the
512-device dry-run flag never leaks into the main test process."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_distributed(code: str, n_devices: int = 8, timeout: int = 420):
    """Run a python snippet in a subprocess with n fake CPU devices.
    The snippet should raise/assert on failure and print 'PASS' on success.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (
        f"distributed snippet failed\nSTDOUT:\n{proc.stdout[-3000:]}\n"
        f"STDERR:\n{proc.stderr[-3000:]}")
    assert "PASS" in proc.stdout, proc.stdout[-2000:]
    return proc.stdout


@pytest.fixture(scope="session")
def mesh11():
    import jax
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def tiny_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=128, dtype="float32")


@pytest.fixture(scope="session")
def tiny_pcfg():
    from repro.configs.base import ParallelConfig
    return ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)
