"""Serving engine correctness: continuous batching with chunked prefill +
decode must reproduce full-context greedy generation token-for-token, for
both dense (KV cache) and ssm (state cache) families, plus the gemma3-style
sliding-window ring buffer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.build import build_model
from repro.runtime.engine import Engine
from repro.runtime.requests import Request, fixed_trace, sharegpt_like_trace
from repro.runtime.scheduler import Scheduler, SchedulerConfig


def _full_greedy(api, params, mesh, prompt, n_new):
    from repro.layers import embedding as E
    toks = list(prompt)
    for _ in range(n_new):
        def f(params, t):
            if api.cfg.family == "ssm":
                from repro.models import mamba_model as MM
                h, _, _ = MM.forward(params, t, cfg=api.cfg, pcfg=api.pcfg,
                                     return_kv=False)
            else:
                from repro.models import transformer as T
                h, _, _ = T.forward(params, t, cfg=api.cfg, pcfg=api.pcfg,
                                    return_kv=False)
            lg = E.lm_head_logits(params["embedding"], h[:, -1:])
            return E.sharded_argmax(lg, vocab_size=api.cfg.vocab_size)
        g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=(api.specs(), P()),
                                  out_specs=P(), check_vma=False))
        toks.append(int(g(params, jnp.asarray([toks]))[0, 0]))
    return toks[len(prompt):]


@pytest.mark.parametrize("family", ["dense", "ssm", "sliding"])
def test_engine_matches_full_context(family, mesh11):
    if family == "dense":
        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=128, dtype="float32")
    elif family == "ssm":
        cfg = ModelConfig(name="t", family="ssm", num_layers=2, d_model=64,
                          num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=128,
                          ssm_state=8, ssm_dt_rank=8, dtype="float32")
    else:  # gemma3-style: sliding window + local/global, unrolled layers
        cfg = ModelConfig(name="t", family="dense", num_layers=3, d_model=64,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=128, sliding_window=16,
                          local_global_period=3, dtype="float32")
    pcfg = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                          split_unit=16, tokenweave_min_tokens=32)
    api = build_model(cfg, pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(0, 128, size=n)) for n in (23, 57, 40)]
    refs = [_full_greedy(api, params, mesh11, p, 6) for p in prompts]

    eng = Engine(api, mesh11, params,
                 SchedulerConfig(max_batch=4, chunk_tokens=32, max_len=128,
                                 prefill_bucket=16))
    for i, p in enumerate(prompts):
        eng.add_request(Request(rid=i, prompt=p, max_new_tokens=6))
    done = eng.run()
    outs = {r.rid: r.output for r in done}
    for i, ref in enumerate(refs):
        assert outs[i] == ref, (family, i, outs[i], ref)


def test_scheduler_chunked_prefill_budget():
    sched = Scheduler(SchedulerConfig(max_batch=2, chunk_tokens=64,
                                      max_len=512, prefill_bucket=16))
    for r in fixed_trace(4, input_len=100, output_len=4, vocab=100):
        sched.add(r)
    step = sched.next_step()
    assert step is not None and step.prefill is not None
    group, chunk = step.prefill
    assert chunk <= 64 and chunk % 16 == 0
    assert len(group) * chunk <= 64 or len(group) == 1
    # only max_batch requests admitted
    assert sum(r is not None for r in sched.active) == 2


def test_trace_generators_reproducible():
    """Satellite: generators take an explicit seed OR a Random instance —
    same seed => identical trace; a shared instance threads its state."""
    import random
    a = sharegpt_like_trace(20, vocab=100, seed=5)
    b = sharegpt_like_trace(20, vocab=100, seed=5)
    assert [(r.prompt, r.max_new_tokens) for r in a] == \
        [(r.prompt, r.max_new_tokens) for r in b]
    c = fixed_trace(5, 10, 3, vocab=50, seed=random.Random(9))
    d = fixed_trace(5, 10, 3, vocab=50, seed=random.Random(9))
    assert [r.prompt for r in c] == [r.prompt for r in d]
    rng = random.Random(9)
    fixed_trace(5, 10, 3, vocab=50, seed=rng)
    e = fixed_trace(5, 10, 3, vocab=50, seed=rng)   # state advanced
    assert [r.prompt for r in e] != [r.prompt for r in c]


def test_sharegpt_trace_statistics():
    reqs = sharegpt_like_trace(200, vocab=1000, seed=1)
    ins = [len(r.prompt) for r in reqs]
    outs = [r.max_new_tokens for r in reqs]
    assert 50 < np.mean(ins) < 400
    assert 100 < np.mean(outs) < 600
    assert max(ins) <= 1024 and max(outs) <= 1024


def test_engine_continuous_batching_slot_reuse(mesh11, tiny_cfg, tiny_pcfg):
    """More requests than slots: slots must be reused after completion."""
    api = build_model(tiny_cfg, tiny_pcfg, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    eng = Engine(api, mesh11, params,
                 SchedulerConfig(max_batch=2, chunk_tokens=32, max_len=128,
                                 prefill_bucket=16))
    for r in fixed_trace(5, input_len=20, output_len=3,
                         vocab=tiny_cfg.vocab_size):
        eng.add_request(r)
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in done)
