"""Speculative decoding (runtime/spec.py, DESIGN.md §8).

* greedy spec output is token-identical to plain greedy decoding on BOTH
  KV backends (paged block pool and legacy slots), for n-gram and
  model-self drafts;
* the stochastic rejection rule emits tokens distributed exactly like the
  (filtered) target distribution regardless of what the draft proposes;
* KV rollback after partial acceptance leaves the block table / pool
  refcounts / prefix cache consistent at every step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.build import build_model
from repro.runtime import spec as SP
from repro.runtime.engine import Engine
from repro.runtime.requests import Request, repetitive_trace
from repro.runtime.scheduler import SchedulerConfig


def _prompts(vocab, sizes=(23, 57, 40), seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(0, vocab, size=n)) for n in sizes]


def _run(api, mesh, params, prompts, *, paged, gamma, n_new=12, draft=None,
         **scfg_kw):
    eng = Engine(api, mesh, params,
                 SchedulerConfig(max_batch=4, chunk_tokens=64, max_len=128,
                                 prefill_bucket=16, paged=paged,
                                 spec_gamma=gamma, **scfg_kw),
                 draft=draft)
    for i, p in enumerate(prompts):
        eng.add_request(Request(rid=i, prompt=list(p), max_new_tokens=n_new))
    done = eng.run()
    return eng, {r.rid: r.output for r in done}


# --------------------------------------------------------------------------
# greedy token-identity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True], ids=["legacy", "paged"])
def test_greedy_spec_token_identical_ngram(paged, mesh11, tiny_cfg,
                                           tiny_model):
    api, _, params = tiny_model
    prompts = _prompts(tiny_cfg.vocab_size)
    _, ref = _run(api, mesh11, params, prompts, paged=paged, gamma=0)
    eng, got = _run(api, mesh11, params, prompts, paged=paged, gamma=3)
    assert got == ref, (got, ref)
    assert eng.stats.spec.verify_steps > 0
    assert eng.stats.spec.tokens_per_step >= 1.0


def test_greedy_spec_token_identical_model_draft(mesh11, tiny_cfg,
                                                 tiny_model):
    """Self-draft (target model drafts for itself): acceptance must be 1.0
    and output still identical — the strongest identity check because every
    window commits gamma+1 tokens through the rollback machinery."""
    api, _, params = tiny_model
    prompts = _prompts(tiny_cfg.vocab_size)
    _, ref = _run(api, mesh11, params, prompts, paged=True, gamma=0)
    draft = SP.ModelDraft(api, mesh11, params, gamma=3, max_batch=4)
    eng, got = _run(api, mesh11, params, prompts, paged=True, gamma=3,
                    draft=draft)
    assert got == ref
    assert eng.stats.spec.acceptance_rate == pytest.approx(1.0)
    assert eng.stats.spec.tokens_per_step > 2.0


def test_spec_respects_max_new_tokens(mesh11, tiny_cfg, tiny_model):
    """Drafting is capped so verify never overshoots max_new_tokens."""
    api, _, params = tiny_model
    draft = SP.ModelDraft(api, mesh11, params, gamma=4, max_batch=4)
    eng, got = _run(api, mesh11, params, _prompts(tiny_cfg.vocab_size),
                    paged=True, gamma=4, n_new=5, draft=draft)
    assert all(len(o) == 5 for o in got.values())


def test_spec_rejected_on_unsupported_configs(mesh11, tiny_cfg, tiny_pcfg,
                                              tiny_model):
    import dataclasses
    api, _, params = tiny_model
    slide = dataclasses.replace(tiny_cfg, sliding_window=16)
    api_s = build_model(slide, tiny_pcfg, tp=1)
    with pytest.raises(ValueError, match="sliding-window"):
        Engine(api_s, mesh11, params,
               SchedulerConfig(max_batch=2, paged=False, spec_gamma=2))
    # paged backend masks windows instead of ring-buffering: allowed
    Engine(api_s, mesh11, api_s.init(jax.random.PRNGKey(0)),
           SchedulerConfig(max_batch=2, max_len=64, paged=True,
                           spec_gamma=2))


# --------------------------------------------------------------------------
# rejection-sampling distribution sanity
# --------------------------------------------------------------------------

def test_rejection_sampling_matches_target_distribution(mesh11):
    """The first committed token of a verify window must be distributed as
    softmax(logits[0]) EXACTLY, no matter what the draft proposes (the
    deterministic-proposal rule is unbiased for any draft)."""
    vocab, gamma = 16, 2
    rng = np.random.RandomState(1)
    logits = jnp.asarray(rng.randn(1, gamma + 1, vocab) * 1.5,
                         jnp.float32)
    draft = jnp.asarray([[3, 5]], jnp.int32)   # fixed, adversarially wrong

    def fn(lg, dr, key):
        return SP.verify_tokens(lg, dr, key, vocab_size=vocab,
                                tp_axis="model", temperature=1.0)

    sm = jax.jit(jax.shard_map(
        fn, mesh=mesh11, in_specs=(P(), P(), P()), out_specs=(P(), P()),
        check_vma=False))

    n_draws = 4000
    counts = np.zeros(vocab)
    keys = jax.random.split(jax.random.PRNGKey(0), n_draws)
    for i in range(n_draws):
        n_acc, emit = sm(logits, draft, keys[i])
        first = int(draft[0, 0]) if int(n_acc[0]) >= 1 else int(emit[0])
        counts[first] += 1
    emp = counts / n_draws
    tgt = np.asarray(jax.nn.softmax(logits[0, 0]))
    tv = 0.5 * np.abs(emp - tgt).sum()
    assert tv < 0.05, (tv, emp, tgt)


def test_greedy_verify_math():
    """Pure accept/emit logic: mismatch at position j commits draft[:j] and
    emits the target argmax at j; full match emits the bonus."""
    vocab = 8
    tgt_tokens = np.array([[2, 4, 6, 1]])
    logits = np.full((1, 4, vocab), -5.0, np.float32)
    for i, t in enumerate(tgt_tokens[0]):
        logits[0, i, t] = 5.0
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    def fn(lg, dr):
        return SP.verify_greedy(lg, dr, vocab_size=vocab, tp_axis="model")

    sm = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=(P(), P()),
                               out_specs=(P(), P()), check_vma=False))
    cases = [
        ([2, 4, 6], 3, 1),     # all accepted -> bonus
        ([2, 4, 0], 2, 6),     # mismatch at 2 -> correction = argmax there
        ([0, 4, 6], 0, 2),     # immediate mismatch
        ([2, -1, -1], 1, 4),   # short draft: padding never accepts
        ([-1, -1, -1], 0, 2),  # no draft: plain decode semantics
    ]
    for dr, want_n, want_emit in cases:
        n, emit = sm(jnp.asarray(logits),
                     jnp.asarray([dr], jnp.int32))
        assert (int(n[0]), int(emit[0])) == (want_n, want_emit), \
            (dr, int(n[0]), int(emit[0]))


# --------------------------------------------------------------------------
# KV rollback / pool consistency
# --------------------------------------------------------------------------

def _assert_pool_consistent(eng):
    mgr = eng.block_mgr
    alloc = mgr.alloc
    refs = [0] * alloc.num_blocks
    for table in mgr.tables.values():
        for b in table:
            refs[b] += 1
    for b in range(alloc.num_blocks):
        assert alloc.ref[b] == refs[b], (b, alloc.ref[b], refs[b])
        in_free = b in alloc.free
        in_cached = b in alloc.cached_free
        assert not (in_free and in_cached), b
        if refs[b]:
            assert not in_free and not in_cached, b
        else:
            assert in_free or in_cached, f"block {b} leaked"
    # every DECODE request's table covers exactly its committed context
    from repro.runtime.requests import State
    for r in eng.sched.active:
        if r is not None and r.state == State.DECODE:
            want = mgr.blocks_needed(r.length - 1)
            assert len(mgr.tables[r.rid]) >= want, (r.rid, r.length)


def test_paged_spec_rollback_consistency(mesh11, tiny_cfg, tiny_model):
    """Partial acceptance every step (ngram draft on low-entropy prompts)
    with a tight pool: after every engine iteration the block table, the
    refcounts, and the free/cached lists must agree; at the end all blocks
    are released."""
    api, _, params = tiny_model
    eng = Engine(api, mesh11, params,
                 SchedulerConfig(max_batch=3, chunk_tokens=48, max_len=96,
                                 prefill_bucket=16, paged=True, block_size=4,
                                 spec_gamma=4))
    for r in repetitive_trace(4, motif_len=6, repeats=4, output_len=10,
                              vocab=tiny_cfg.vocab_size, seed=3):
        eng.add_request(r)
    steps = 0
    while eng.step():
        steps += 1
        _assert_pool_consistent(eng)
        assert steps < 500
    assert not eng.block_mgr.tables
    st = eng.stats.spec
    assert st.draft_proposed > 0
    # partial acceptance actually happened (not all-or-nothing)
    assert 0 < st.draft_accepted < st.draft_proposed


def test_paged_spec_with_prefix_cache_identical(mesh11, tiny_cfg,
                                                tiny_model):
    """Spec decoding composes with prefix caching: shared-prefix prompts,
    outputs identical to the non-spec paged run, registered blocks
    survive truncation."""
    api, _, params = tiny_model
    base = _prompts(tiny_cfg.vocab_size, sizes=(40,))[0]
    # more requests than slots (max_batch=4): the late admissions hit the
    # blocks the early ones registered
    prompts = [base, base[:32] + [1, 2, 3], base, list(base), list(base)]
    _, ref = _run(api, mesh11, params, prompts, paged=True, gamma=0,
                  block_size=8)
    eng, got = _run(api, mesh11, params, prompts, paged=True, gamma=3,
                    block_size=8)
    assert got == ref
    assert eng.block_mgr.stats.hit_tokens > 0


def test_spec_stats_accounting(mesh11, tiny_cfg, tiny_model):
    """All decoded tokens are accounted for: verify-committed tokens plus
    plain-decode fallback steps (iterations where nothing was drafted);
    acceptance/tokens-per-step are internally consistent."""
    api, _, params = tiny_model
    eng, got = _run(api, mesh11, params, _prompts(tiny_cfg.vocab_size),
                    paged=True, gamma=3)
    st = eng.stats.spec
    assert eng.stats.decode_tokens >= st.emitted > 0
    n_seq_steps = st.emitted - st.draft_accepted
    assert st.tokens_per_step == pytest.approx(st.emitted / n_seq_steps)
    # every decoded token arrived via prefill-sample, fallback decode, or
    # verify commit
    total_out = sum(len(o) for o in got.values())
    assert total_out == eng.stats.decode_tokens + len(got)


def test_stochastic_spec_engine_reproducible(mesh11, tiny_cfg, tiny_model):
    """temperature/top-k/top-p run end-to-end through prefill, fallback
    decode, AND verify (one PRNG stream, seeded): same seed => identical
    outputs, different seed => different."""
    api, _, params = tiny_model
    prompts = _prompts(tiny_cfg.vocab_size, sizes=(20, 33))

    def run(seed):
        eng = Engine(api, mesh11, params,
                     SchedulerConfig(max_batch=2, chunk_tokens=48,
                                     max_len=96, prefill_bucket=16,
                                     paged=True, spec_gamma=2),
                     temperature=0.8, top_k=20, top_p=0.95, seed=seed)
        for i, p in enumerate(prompts):
            eng.add_request(Request(rid=i, prompt=list(p),
                                    max_new_tokens=6))
        return {r.rid: r.output for r in eng.run()}

    a, b, c = run(0), run(0), run(1)
    assert a == b
    assert a != c
    assert all(len(o) == 6 for o in a.values())


def test_verify_weave_split_matches_unsplit(mesh11, tiny_cfg):
    """A verify batch large enough to cross the weave threshold (32 rows x
    3 tokens >= tokenweave_min_tokens) must produce the same logits as the
    unsplit forward — the batch-dim split slices the slot cache and the
    multi-token rows consistently."""
    import dataclasses

    from repro.configs.base import ParallelConfig
    from repro.models import transformer as T

    b, s_v, max_len = 32, 3, 16
    pcfg_on = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                             split_unit=16, tokenweave_min_tokens=32)
    pcfg_off = dataclasses.replace(pcfg_on, tokenweave=False)
    api = build_model(tiny_cfg, pcfg_on, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(b, max_len)
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, tiny_cfg.vocab_size, (b, s_v)),
                         jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(s_v, dtype=jnp.int32)[None],
                                 (b, s_v))

    outs = {}
    for name, pcfg in (("weave", pcfg_on), ("unsplit", pcfg_off)):
        def fn(p, c, t, pos, pcfg=pcfg):
            return T.verify_step(p, t, c, cfg=tiny_cfg, pcfg=pcfg,
                                 positions=pos)
        sm = jax.jit(jax.shard_map(
            fn, mesh=mesh11,
            in_specs=(api.specs(), api.cache_specs(), P(), P()),
            out_specs=(P(), api.cache_specs()), check_vma=False))
        logits, new_cache = sm(params, cache, tokens, positions)
        outs[name] = (np.asarray(logits), np.asarray(new_cache["k"]))
    np.testing.assert_allclose(outs["weave"][0], outs["unsplit"][0],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs["weave"][1], outs["unsplit"][1],
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# scheduler accounting
# --------------------------------------------------------------------------

def test_scheduler_charges_verify_tokens():
    from repro.runtime.requests import State, fixed_trace
    from repro.runtime.scheduler import Scheduler
    scfg = SchedulerConfig(max_batch=4, chunk_tokens=64, max_len=512,
                           prefill_bucket=16, spec_gamma=7)
    sched = Scheduler(scfg)
    # two decoding requests occupy 2*(7+1)=16 tokens of the 64 budget
    for r in fixed_trace(2, input_len=8, output_len=4, vocab=50):
        sched.add(r)
    sched.next_step()
    for r in sched.active:
        if r is not None:
            r.state = State.DECODE
            r.prefill_pos = len(r.prompt)
            r.output.append(1)
    big = fixed_trace(1, input_len=100, output_len=4, vocab=50)[0]
    big.rid = 99
    sched.add(big)
    step = sched.next_step()
    assert step is not None and step.prefill is not None
    group, chunk = step.prefill
    assert len(group) * chunk <= 64 - 2 * 8, (chunk, len(group))
