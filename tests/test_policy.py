"""Per-site overlap policy & tuned plan cache (core/policy.py,
analysis/autotune.py, DESIGN.md §14).

The load-bearing invariant: the DEGENERATE ``ThresholdPolicy`` must
reproduce ``core/splitting.split_decision`` field-for-field over a
randomized (tokens, unit, min_tokens, row_multiple) sweep — engines
without a tuned plan behave exactly as before the policy object existed.
The differential sweep at the bottom replays 25 seeded random traces
through engines WITH and WITHOUT the committed tuned plan on both KV
backends and asserts greedy token-identity: a plan reshapes HOW a
forward overlaps, never what it computes.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.policy import (DEFAULT_POLICY, PLAN_VERSION, SITES,
                               OverlapPlan, PlanEntry, ThresholdPolicy,
                               TunedPolicy, load_policy)
from repro.core.splitting import (DEFAULT_BUCKET_EDGES, plan_split,
                                  ring_channels, smart_split, split_decision,
                                  token_bucket, wave_count)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_PLAN = os.path.join(REPO, "benchmarks", "plans", "default.json")


# --------------------------------------------------------------------------
# the degenerate policy IS split_decision
# --------------------------------------------------------------------------

def test_threshold_policy_reproduces_split_decision_randomized():
    """Satellite invariant: over a randomized sweep of every argument the
    legacy threshold decision takes, the degenerate policy returns the
    IDENTICAL SplitDecision (same split, reason, threshold, plan id 0)."""
    rng = np.random.RandomState(42)
    pol = ThresholdPolicy()
    for _ in range(500):
        n = int(rng.randint(1, 5000))
        unit = int(rng.randint(1, 512))
        min_tokens = int(rng.randint(0, 4096))
        rows = int(rng.randint(1, 64))
        site = SITES[int(rng.randint(0, len(SITES)))]
        legacy = split_decision(n, unit=unit, min_tokens=min_tokens,
                                row_multiple=rows)
        got = pol.decide(site, n, unit=unit, min_tokens=min_tokens,
                         row_multiple=rows, tp=int(rng.randint(1, 16)),
                         family="dense")
        assert got == legacy, (n, unit, min_tokens, rows, site)
        assert got.plan_id == 0
    assert pol.plan_for("prefill", 4096) is None
    assert DEFAULT_POLICY == ThresholdPolicy()   # frozen/hashable default


def test_threshold_policy_bucket_tokens_restamps_bucket_only():
    """decode/verify split on ROWS but bucket on TOKENS: bucket_tokens
    must only relabel the bucket, never change the split decision."""
    pol = ThresholdPolicy()
    d_rows = pol.decide("verify", 12, unit=4, min_tokens=8, row_multiple=3)
    d_tok = pol.decide("verify", 12, unit=4, min_tokens=8, row_multiple=3,
                       bucket_tokens=300)
    assert (d_tok.split, d_tok.reason) == (d_rows.split, d_rows.reason)
    assert d_tok.bucket == token_bucket(300)
    assert d_rows.bucket == token_bucket(12)


# --------------------------------------------------------------------------
# plan_split: the tuner's parameterized wave split
# --------------------------------------------------------------------------

def test_plan_split_invariants():
    rng = np.random.RandomState(7)
    for _ in range(500):
        n = int(rng.randint(1, 100_000))
        unit = int(rng.randint(1, 1024))
        frac = float(rng.choice([0.25, 0.5, 0.75, 0.1, 0.9]))
        s = plan_split(n, unit, frac)
        if s is None:
            assert n < 2 * unit          # fewer than two full waves
            continue
        l1, l2 = s
        assert l1 + l2 == n and l1 > 0 and l2 > 0
        assert l1 % unit == 0            # prefix is full waves only
        assert wave_count(l1, unit) + wave_count(l2, unit) \
            == wave_count(n, unit)       # wave conservation (paper §3.1.1)


def test_plan_split_half_is_smart_split():
    rng = np.random.RandomState(8)
    for _ in range(300):
        n = int(rng.randint(1, 50_000))
        unit = int(rng.randint(1, 512))
        assert plan_split(n, unit, 0.5) == smart_split(n, unit)


def test_token_bucket_labels():
    edges = (0, 16, 32, 64)
    assert token_bucket(0, edges) == "0-15"
    assert token_bucket(15, edges) == "0-15"
    assert token_bucket(16, edges) == "16-31"
    assert token_bucket(63, edges) == "32-63"
    assert token_bucket(64, edges) == "64+"
    assert token_bucket(10_000, edges) == "64+"
    assert token_bucket(48) == token_bucket(48, DEFAULT_BUCKET_EDGES)


# --------------------------------------------------------------------------
# TunedPolicy: lookup, fallback, serialization
# --------------------------------------------------------------------------

def _toy_policy():
    entries = (
        PlanEntry("prefill", "64-127", 1, "dense", "weave",
                  split_frac=0.75, budget=1.0),
        PlanEntry("prefill", "32-63", 1, "dense", "fused-unsplit"),
        PlanEntry("packed", "128-255", 1, "dense", "none"),
    )
    return TunedPolicy(plan_id=77, bucket_edges=(0, 16, 32, 64, 128, 256),
                       entries=entries)


def test_tuned_policy_weave_entry_decides_plan_split():
    pol = _toy_policy()
    d = pol.decide("prefill", 96, unit=16, min_tokens=10_000)
    # min_tokens is the LEGACY threshold — a tuned weave entry overrides it
    assert d.reason == "plan_split"
    assert d.split == plan_split(96, 16, 0.75)
    assert d.plan_id == 77 and d.bucket == "64-127"
    plan = pol.plan_for("prefill", 96)
    assert plan == OverlapPlan("prefill", "64-127", "weave", 0.75, 1.0, 77)


def test_tuned_policy_unsplit_entries():
    pol = _toy_policy()
    # fused-unsplit: no split even though the legacy threshold would split
    d = pol.decide("prefill", 48, unit=16, min_tokens=32)
    assert d.split is None and d.reason == "plan_unsplit"
    assert split_decision(48, unit=16, min_tokens=32).split is not None
    # method none at a packed site
    d = pol.decide("packed", 200, unit=16, min_tokens=32)
    assert d.split is None and d.reason == "plan_unsplit"


def test_tuned_policy_infeasible_weave_reports_wave_floor():
    pol = TunedPolicy(plan_id=5, bucket_edges=(0, 16),
                      entries=(PlanEntry("prefill", "16+", 1, "dense",
                                         "weave"),))
    # bucket says weave but 24 tokens < 2 waves at unit 16
    d = pol.decide("prefill", 24, unit=16, min_tokens=0)
    assert d.split is None and d.reason == "below_wave_floor"
    assert d.plan_id == 5


def test_tuned_policy_miss_falls_back_to_threshold():
    pol = _toy_policy()
    legacy = split_decision(500, unit=16, min_tokens=32)
    d = pol.decide("decode", 500, unit=16, min_tokens=32)   # no decode entry
    assert (d.split, d.reason) == (legacy.split, legacy.reason)
    assert d.plan_id == 77                 # ...but stamped as consulted
    assert pol.plan_for("decode", 500) is None


def test_tuned_policy_row_multiple_uses_effective_unit():
    pol = TunedPolicy(plan_id=9, bucket_edges=(0,),
                      entries=(PlanEntry("verify", "0+", 1, "dense",
                                         "weave"),))
    d = pol.decide("verify", 24, unit=4, min_tokens=0, row_multiple=3)
    assert d.split is not None
    l1, _ = d.split
    assert l1 % 12 == 0                    # lcm(unit=4, rows=3)


def test_plan_cache_json_round_trip(tmp_path):
    pol = _toy_policy()
    path = str(tmp_path / "plan.json")
    pol.save(path, note="round-trip")
    back = TunedPolicy.load(path)
    assert back.plan_id == pol.plan_id
    assert back.bucket_edges == pol.bucket_edges
    assert back.entries == pol.entries
    assert load_policy(path).plan_id == 77
    assert load_policy(None) is DEFAULT_POLICY


def test_plan_cache_version_and_schema_rejection(tmp_path):
    doc = _toy_policy().to_doc()
    bad = dict(doc, version=PLAN_VERSION + 1)
    with pytest.raises(ValueError, match="regenerate"):
        TunedPolicy.from_doc(bad)
    bad = json.loads(json.dumps(doc))
    bad["entries"][0]["method"] = "telepathy"
    with pytest.raises(ValueError, match="method"):
        TunedPolicy.from_doc(bad)
    assert PlanEntry("prefill", "0+", 1, "dense", "weave",
                     split_frac=1.5).validate() is not None
    assert PlanEntry("prefill", "0+", 1, "dense", "weave",
                     budget=0.0).validate() is not None


def test_committed_default_plan_loads_and_covers_tiny():
    """The plan cache every engine can point at must load and cover the
    CI-tiny deployment the serve benchmarks run."""
    pol = load_policy(DEFAULT_PLAN)
    assert pol.plan_id > 0
    for site in SITES:
        assert pol.plan_for(site, 64, tp=1, family="dense") is not None
        assert pol.plan_for(site, 2048, tp=8, family="dense") is not None


# --------------------------------------------------------------------------
# autotuner determinism
# --------------------------------------------------------------------------

def test_autotune_is_deterministic_and_prefers_fused_weave():
    from repro.analysis.autotune import build_default_plan
    p1 = build_default_plan()
    p2 = build_default_plan()
    assert p1.plan_id == p2.plan_id
    assert p1.entries == p2.entries
    # committed cache == a fresh defaults run (the CI drift gate's claim)
    committed = TunedPolicy.load(DEFAULT_PLAN)
    assert committed.plan_id == p1.plan_id
    assert committed.entries == p1.entries
    # comm-free regime (tp=1 small buckets) must NOT split — splitting
    # only adds weight-read passes when there is nothing to hide; the
    # one-kernel ring path still wins on its cheaper norm epilogue
    tiny_small = [e for e in p1.entries
                  if e.tp == 1 and e.bucket in ("0-15", "16-31", "32-63")]
    assert tiny_small and all(e.method == "fused-unsplit"
                              for e in tiny_small)
    # comm-bound regime (tp=8 large buckets) must run the full TokenWeave
    # configuration: ring kernel + wave-aware split, with a sub-full ring
    # lane grant (the paper's few-SM fused collective)
    big = [e for e in p1.entries if e.tp == 8 and e.bucket == "4096-8191"]
    assert big and all(e.method == "fused" for e in big)
    assert all(ring_channels(e.budget) >= 1 for e in big)
    # and nowhere does the composed weave beat the ring-fused one
    assert all(e.method != "weave" for e in p1.entries)


# --------------------------------------------------------------------------
# engine integration: loading a plan cannot change tokens
# --------------------------------------------------------------------------

def test_engine_loads_plan_and_stamps_attribution(tiny_engine_builder):
    from repro.obs import TraceRecorder
    from repro.runtime.requests import Request

    def run(plan_path, rec=None):
        eng = tiny_engine_builder(paged=True, packed=True,
                                  plan_path=plan_path, obs=rec)
        for i in range(3):
            eng.add_request(Request(rid=i, prompt=list(range(20 + 8 * i)),
                                    max_new_tokens=4))
        done = eng.run()
        return eng, {r.rid: tuple(r.output) for r in done}

    eng0, ref = run(None)
    assert eng0.metrics.get("engine/plan_id").value == 0
    rec = TraceRecorder()
    eng1, got = run(DEFAULT_PLAN, rec=rec)
    assert got == ref, "loading a tuned plan changed emitted tokens!"
    tuned_id = load_policy(DEFAULT_PLAN).plan_id
    assert eng1.metrics.get("engine/plan_id").value == tuned_id
    # every per-forward attribution span names the plan that decided it
    fwd = [e for e in rec.events
           if e["kind"] == "span" and e["cat"] == "forward"]
    assert fwd and all(e["args"]["plan_id"] == tuned_id for e in fwd)
    assert all(e["args"]["bucket"] for e in fwd)
    # per-site counters exist for the packed dispatch
    snap = eng1.metrics_snapshot()
    assert snap["engine/site_forwards{site=packed}"] == len(fwd)


def test_install_overlap_policy_swaps_and_resets(tiny_engine_builder):
    eng = tiny_engine_builder(paged=True)
    pol = load_policy(DEFAULT_PLAN)
    eng.install_overlap_policy(pol)
    assert eng.api.pcfg.overlap_policy is pol
    assert eng.metrics.get("engine/plan_id").value == pol.plan_id
    eng.install_overlap_policy(None)
    assert eng.metrics.get("engine/plan_id").value == 0


# --------------------------------------------------------------------------
# differential: tuned plan vs legacy threshold, both KV backends
# --------------------------------------------------------------------------

@pytest.mark.parametrize("trial", range(25))
def test_policy_differential_trace(trial, tiny_engine_builder):
    """25 seeded random traces (mixed prefill, shared prefixes, spec
    windows, cancellations) through the legacy-threshold engine and the
    tuned-plan engine on BOTH KV backends: greedy token-identity across
    all four.  Reuses the test_differential harness so the same invariant
    sweeps (packed budget, slot reuse, block refcounts) ride along."""
    from test_differential import _drive, _gen_trace

    rng = np.random.RandomState(9000 + trial)
    prompts, outs, gamma, cancels = _gen_trace(rng)
    kw = dict(max_batch=3, chunk_tokens=48, max_len=128, prefill_bucket=16,
              block_size=16, spec_gamma=gamma)

    results = {}
    for name, cfg in (
            ("legacy_paged", dict(paged=True)),
            ("tuned_paged", dict(paged=True, plan_path=DEFAULT_PLAN)),
            ("legacy_slots", dict(paged=False)),
            ("tuned_slots", dict(paged=False, plan_path=DEFAULT_PLAN))):
        eng = tiny_engine_builder(**kw, **cfg)
        results[name] = _drive(eng, prompts, outs, cancels)

    ref = results["legacy_paged"]
    for name in ("tuned_paged", "legacy_slots", "tuned_slots"):
        assert results[name] == ref, (trial, gamma, cancels, name)
