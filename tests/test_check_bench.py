"""Unit tests for the CI benchmark-regression gate
(scripts/check_bench.py): key-set disagreement must fail with the full
list of missing/extra metric names, zero baselines must stay zero,
tolerance breaches must be reported per metric, and — when the run
carries a ``__provenance__`` map (DESIGN.md §12) — every gated key must
originate from a metrics-registry snapshot."""
import importlib.util
import os

_SCRIPT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "scripts", "check_bench.py")
_spec = importlib.util.spec_from_file_location("check_bench", _SCRIPT)
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


BASE = {"serve/a": 1.0, "serve/b": 0.0, "serve/c": 10.0}


def test_agreeing_run_passes():
    assert check_bench.run_checks(dict(BASE), BASE, tol=0.15) == []


def test_within_tolerance_passes():
    cur = {"serve/a": 1.1, "serve/b": 0.0, "serve/c": 9.0}
    assert check_bench.run_checks(cur, BASE, tol=0.15) == []


def test_missing_key_fails_and_names_it():
    cur = {"serve/a": 1.0, "serve/b": 0.0}
    failures = check_bench.run_checks(cur, BASE, tol=0.15)
    assert len(failures) == 1
    assert "MISSING" in failures[0] and "serve/c" in failures[0]


def test_extra_key_fails_and_names_it_unless_allowed():
    cur = dict(BASE, **{"serve/new1": 5.0, "serve/new2": 6.0})
    failures = check_bench.run_checks(cur, BASE, tol=0.15)
    assert len(failures) == 1
    assert "NOT in the baseline" in failures[0]
    assert "serve/new1" in failures[0] and "serve/new2" in failures[0]
    assert check_bench.run_checks(cur, BASE, tol=0.15,
                                  allow_extra=True) == []


def test_missing_and_extra_both_reported():
    cur = {"serve/a": 1.0, "serve/b": 0.0, "serve/d": 2.0}
    failures = check_bench.run_checks(cur, BASE, tol=0.15)
    assert len(failures) == 2
    assert any("serve/c" in f for f in failures)
    assert any("serve/d" in f for f in failures)


def test_zero_baseline_must_stay_zero():
    cur = {"serve/a": 1.0, "serve/b": 0.01, "serve/c": 10.0}
    failures = check_bench.run_checks(cur, BASE, tol=0.15)
    assert len(failures) == 1 and "serve/b" in failures[0]


def test_tolerance_breach_reports_rel_diff():
    cur = {"serve/a": 2.0, "serve/b": 0.0, "serve/c": 10.0}
    failures = check_bench.run_checks(cur, BASE, tol=0.15)
    assert len(failures) == 1
    assert "serve/a" in failures[0] and "rel_diff" in failures[0]


# --------------------------------------------------------------------------
# provenance gate (DESIGN.md §12)
# --------------------------------------------------------------------------

def test_registry_and_derived_provenance_pass():
    prov = {"serve/a": "registry:engine/weave_rate",
            "serve/b": "derived:engine/prefill_tokens(cold-warm)",
            "serve/c": "registry:latency/ttft/p50"}
    assert check_bench.run_checks(dict(BASE), BASE, tol=0.15,
                                  provenance=prov) == []


def test_adhoc_metric_is_an_orphan_and_named():
    prov = {"serve/a": "registry:engine/weave_rate",
            "serve/b": "adhoc",
            "serve/c": "registry:latency/ttft/p50"}
    failures = check_bench.run_checks(dict(BASE), BASE, tol=0.15,
                                      provenance=prov)
    assert len(failures) == 1
    assert "orphan" in failures[0] and "serve/b" in failures[0]
    assert "serve/a" not in failures[0]


def test_missing_provenance_entry_is_an_orphan():
    prov = {"serve/a": "registry:x", "serve/c": "registry:y"}
    failures = check_bench.run_checks(dict(BASE), BASE, tol=0.15,
                                      provenance=prov)
    assert len(failures) == 1 and "serve/b" in failures[0]


def test_no_provenance_map_is_backward_compatible():
    # a pre-provenance metrics file (no __provenance__ key) still passes
    assert check_bench.run_checks(dict(BASE), BASE, tol=0.15,
                                  provenance=None) == []
    assert check_bench.provenance_failures(None, BASE) == []


# --------------------------------------------------------------------------
# measured: namespace (DESIGN.md §13) — tolerance-exempt but
# provenance-required
# --------------------------------------------------------------------------

_MEAS = "measured:profile/forward_us{mode=decode,weave=off}/p50"
_PROV = {"serve/a": "registry:a", "serve/b": "registry:b",
         "serve/c": "registry:c"}


def test_measured_keys_exempt_from_keyset_and_tolerance():
    """A measured key absent from the baseline, with an arbitrarily wild
    value, passes — as long as its provenance is registry-sourced."""
    cur = dict(BASE, **{_MEAS: 1e9})
    prov = dict(_PROV, **{
        _MEAS: "registry:profile/forward_us{mode=decode,weave=off}/p50"})
    assert check_bench.run_checks(cur, BASE, tol=0.15,
                                  provenance=prov) == []


def test_orphan_measured_key_still_fails():
    """The exemption is from determinism gates ONLY: a measured key the
    registry cannot vouch for fails with its name listed."""
    cur = dict(BASE, **{_MEAS: 42.0})
    failures = check_bench.run_checks(
        cur, BASE, tol=0.15, provenance=dict(_PROV, **{_MEAS: "adhoc"}))
    assert len(failures) == 1
    assert "orphan" in failures[0] and _MEAS in failures[0]
    # ... and a measured key missing from the provenance map entirely
    failures = check_bench.run_checks(cur, BASE, tol=0.15,
                                      provenance=dict(_PROV))
    assert len(failures) == 1 and _MEAS in failures[0]


def test_measured_keys_require_a_provenance_map():
    """Unlike baseline-gated keys (backward compatibility), measured keys
    with NO provenance map at all are a failure — nothing vouches for
    them."""
    cur = dict(BASE, **{_MEAS: 42.0})
    failures = check_bench.run_checks(cur, BASE, tol=0.15, provenance=None)
    assert len(failures) == 1
    assert check_bench.PROVENANCE_KEY in failures[0] and _MEAS in failures[0]


def test_measured_keys_in_baseline_are_ignored():
    """A measured key accidentally committed to the baseline must not
    resurrect the key-set gate for measured metrics."""
    base = dict(BASE, **{_MEAS: 10.0})
    assert check_bench.run_checks(dict(BASE), base, tol=0.15,
                                  provenance=None) == []
