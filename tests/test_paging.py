"""Paged KV-cache subsystem: allocator invariants, prefix-cache hits,
copy-on-write, LRU eviction, preemption round-trips, and end-to-end
token-identity of the paged engine vs. the legacy slot engine."""
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.runtime.engine import Engine
from repro.runtime.paging import BlockAllocator, BlockManager
from repro.runtime.prefix_cache import PrefixCache, chain_hashes
from repro.runtime.requests import Request, State
from repro.runtime.scheduler import Scheduler, SchedulerConfig


# ==========================================================================
# host-side unit tests (no jax compute)
# ==========================================================================

def test_allocator_alloc_free_refcount():
    a = BlockAllocator(4)
    blocks = [a.alloc() for _ in range(4)]
    assert sorted(blocks) == [0, 1, 2, 3]
    assert a.alloc() is None                      # exhausted
    assert all(a.refcount(b) == 1 for b in blocks)
    # share/decref round trip
    a.share(blocks[0])
    assert a.refcount(blocks[0]) == 2
    assert not a.decref(blocks[0], cached=False)  # still referenced
    assert a.decref(blocks[0], cached=False)      # now free
    assert a.refcount(blocks[0]) == 0
    b = a.alloc()
    assert b == blocks[0]                         # recycled
    a.decref(blocks[1], cached=False)
    with pytest.raises(AssertionError):
        a.decref(blocks[1], cached=False)         # double free


def test_allocator_lru_eviction_order_and_hook():
    evicted = []
    a = BlockAllocator(3, on_evict=evicted.append)
    b0, b1, b2 = a.alloc(), a.alloc(), a.alloc()
    # free in order b1, b0 as cached (prefix-registered) blocks
    a.decref(b1, cached=True)
    a.decref(b0, cached=True)
    assert a.num_available() == 2
    # alloc must evict the LEAST recently freed cached block first (b1)
    got = a.alloc()
    assert got == b1 and evicted == [b1]
    got2 = a.alloc()
    assert got2 == b0 and evicted == [b1, b0]
    assert a.alloc() is None                      # b2 still referenced


def test_eviction_never_frees_refcounted_shared_block():
    evicted = []
    a = BlockAllocator(2, on_evict=evicted.append)
    b0, b1 = a.alloc(), a.alloc()
    a.share(b0)                                   # shared: ref == 2
    a.decref(b0, cached=True)                     # one reader left
    a.decref(b1, cached=True)                     # ref 0 -> evictable
    assert a.alloc() == b1                        # must pick b1, not b0
    assert evicted == [b1]
    assert a.alloc() is None                      # b0 protected by its ref
    assert a.refcount(b0) == 1


def test_prefix_cache_chain_hash_and_match():
    toks = list(range(40))
    hs = chain_hashes(toks, 16)
    assert len(hs) == 2                           # only full blocks
    # chain property: changing block 0 changes block 1's hash
    toks2 = [99] + toks[1:]
    assert chain_hashes(toks2, 16)[1] != hs[1]
    pc = PrefixCache()
    assert pc.register(hs[0], 7)
    assert not pc.register(hs[0], 8)              # first writer wins
    assert pc.match(hs) == [7]                    # prefix only
    pc.register(hs[1], 9)
    assert pc.match(hs) == [7, 9]
    pc.drop_block(7)
    assert pc.match(hs) == []                     # chain broken at block 0


def test_block_manager_prompt_sharing_and_cow():
    m = BlockManager(num_blocks=8, block_size=4, max_blocks_per_req=8)
    ctx = list(range(10))                         # 2 full blocks + tail
    hit = m.allocate_prompt(1, ctx)
    assert hit == 0 and len(m.tables[1]) == 3
    m.register_filled(1, ctx, 10)                 # registers blocks 0,1
    # identical prompt shares both full blocks
    hit2 = m.allocate_prompt(2, ctx)
    assert hit2 == 8
    assert m.tables[2][:2] == m.tables[1][:2]
    assert m.alloc.refcount(m.tables[1][0]) == 2
    # force a write into the shared block: COW must split it
    shared = m.tables[2][0]
    assert m.ensure_writable(2, 0)
    assert m.tables[2][0] != shared               # private copy
    assert m.tables[1][0] == shared               # other reader untouched
    assert m.alloc.refcount(shared) == 1
    assert m.take_pending_copies() == [(shared, m.tables[2][0])]
    assert m.stats.cow_copies == 1


def test_block_manager_full_match_leaves_one_token():
    m = BlockManager(num_blocks=8, block_size=4, max_blocks_per_req=8)
    ctx = list(range(8))                          # exactly 2 full blocks
    m.allocate_prompt(1, ctx)
    m.register_filled(1, ctx, 8)
    hit = m.allocate_prompt(2, ctx)               # 100% match capped
    assert hit == 4                               # last block recomputed
    assert m.alloc.refcount(m.tables[2][1]) == 1  # private tail


def test_block_manager_free_queues_resets_only_for_uncached():
    m = BlockManager(num_blocks=8, block_size=4, max_blocks_per_req=8)
    ctx = list(range(10))
    m.allocate_prompt(1, ctx)
    m.register_filled(1, ctx, 8)                  # blocks 0,1 cached
    t = list(m.tables[1])
    m.free_request(1)
    resets = m.take_pending_resets()
    assert resets == [t[2]]                       # only the uncached tail
    # cached blocks are still hittable after the free
    assert m.allocate_prompt(2, ctx) == 8


def test_scheduler_admission_blocked_by_pool_budget():
    m = BlockManager(num_blocks=3, block_size=4, max_blocks_per_req=8)
    cfg = SchedulerConfig(max_batch=4, chunk_tokens=64, max_len=32,
                          prefill_bucket=16, paged=True, block_size=4,
                          num_blocks=3)
    sched = Scheduler(cfg, block_mgr=m)
    big = Request(rid=0, prompt=list(range(8)), max_new_tokens=4)
    small = Request(rid=1, prompt=list(range(4)), max_new_tokens=4)
    sched.add(big)
    sched.add(small)
    step = sched.next_step()
    # big needs 2 blocks + 1 decode = 3 -> admitted; small must wait
    # (FIFO head-of-line, no skipping)
    group, _ = step.prefill
    assert [r.rid for r in group] == [0]
    assert small.state == State.WAITING


def test_request_preemption_bookkeeping():
    r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=8)
    r.state, r.slot, r.prefill_pos = State.DECODE, 0, 3
    r.output = [10, 11, 12]
    sched = Scheduler(SchedulerConfig(max_batch=1))
    sched.active[0] = r
    sched.preempt(r)
    assert r.state == State.WAITING and r.resumed and r.preemptions == 1
    assert r.context_tokens == [1, 2, 3, 10, 11]  # last output is pending
    assert sched.waiting[0] is r                  # front of the queue


# ==========================================================================
# end-to-end: paged engine vs legacy slot engine (greedy, token-identical)
# ==========================================================================

# the tiny dense model + parallel config now live in conftest.py
# (tiny_cfg / tiny_pcfg / model_builder): built once per session, shared
# with test_packed.py / test_spec.py / test_differential.py


def _run_engine(model, mesh, prompts, n_new=6, **scfg_kw):
    api, params = model
    kw = dict(max_batch=4, chunk_tokens=32, max_len=128, prefill_bucket=16,
              block_size=16)
    kw.update(scfg_kw)
    eng = Engine(api, mesh, params, SchedulerConfig(**kw))
    for i, p in enumerate(prompts):
        eng.add_request(Request(rid=i, prompt=list(p), max_new_tokens=n_new))
    done = eng.run()
    assert len(done) == len(prompts)
    return {r.rid: r.output for r in done}, eng


@pytest.mark.parametrize("family", ["dense", "sliding", "moe"])
def test_paged_engine_token_identical(family, mesh11, tiny_cfg,
                                      model_builder):
    if family == "dense":
        cfg = tiny_cfg
    elif family == "sliding":
        cfg = ModelConfig(name="t", family="dense", num_layers=3, d_model=64,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=128, sliding_window=16,
                          local_global_period=3, dtype="float32")
    else:
        cfg = ModelConfig(name="t", family="moe", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=128, num_experts=4,
                          num_experts_per_tok=2, moe_d_ff=64,
                          dtype="float32")
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(0, 128, size=n)) for n in (23, 57, 40)]
    model = model_builder(cfg)
    ref, _ = _run_engine(model, mesh11, prompts, paged=False)
    got, eng = _run_engine(model, mesh11, prompts, paged=True)
    assert got == ref, (family, got, ref)
    assert not eng.block_mgr.tables                # all blocks released


def test_prefix_cache_hit_token_identical(mesh11, tiny_cfg, model_builder):
    """Second wave of shared-system-prompt requests must hit the prefix
    cache AND produce exactly the cold-prefill logits path's tokens."""
    model = model_builder(tiny_cfg)
    rng = np.random.RandomState(1)
    sys_p = list(rng.randint(0, 128, size=48))
    prompts = [sys_p + list(rng.randint(0, 128, size=8)) for _ in range(4)]
    ref, _ = _run_engine(model, mesh11, prompts, paged=False, max_batch=2)
    got, eng = _run_engine(model, mesh11, prompts, paged=True, max_batch=2)
    assert got == ref
    st = eng.block_mgr.stats
    assert st.hit_tokens >= 2 * 48, st             # wave 2: both hit
    assert st.hit_rate > 0
    # effective prefill shrank by exactly the hit tokens
    assert eng.stats.prefill_tokens <= sum(len(p) for p in prompts) \
        - st.hit_tokens + 2 * 16                   # + bucket padding slack


def test_preemption_round_trip_same_output(mesh11, tiny_cfg, model_builder):
    """Pool too small for all decodes: requests must be preempted
    (DECODE -> WAITING), readmitted via recompute, and still produce
    exactly the legacy engine's tokens."""
    model = model_builder(tiny_cfg)
    rng = np.random.RandomState(2)
    prompts = [list(rng.randint(0, 128, size=30)) for _ in range(4)]
    ref, _ = _run_engine(model, mesh11, prompts, paged=False, n_new=10)
    got, eng = _run_engine(model, mesh11, prompts, paged=True, n_new=10,
                           num_blocks=9, prefix_caching=False)
    assert got == ref
    assert eng.block_mgr.stats.preemptions > 0
    assert max(r.preemptions for r in eng.sched.finished) > 0


def test_eviction_under_memory_pressure_token_identical(mesh11, tiny_cfg,
                                                        model_builder):
    """Prefix caching + a pool with no headroom: cached-free blocks must
    be evicted (LRU) without ever corrupting live requests."""
    model = model_builder(tiny_cfg)
    rng = np.random.RandomState(3)
    prompts = [list(rng.randint(0, 128, size=34)) for _ in range(5)]
    ref, _ = _run_engine(model, mesh11, prompts, paged=False, n_new=8,
                         max_batch=2)
    got, eng = _run_engine(model, mesh11, prompts, paged=True, n_new=8,
                           max_batch=2, num_blocks=8)
    assert got == ref
    assert eng.block_mgr.stats.evictions > 0


def test_context_ceiling_truncates_instead_of_overflowing(mesh11, tiny_cfg,
                                                          model_builder):
    """A request whose generation would outgrow max_len must finish with
    a truncated output, not overflow the block table; an unservable
    prompt is rejected at add_request."""
    rng = np.random.RandomState(5)
    api, params = model_builder(tiny_cfg)
    eng = Engine(api, mesh11, params,
                 SchedulerConfig(max_batch=2, chunk_tokens=32, max_len=64,
                                 prefill_bucket=16, paged=True,
                                 block_size=16))
    eng.add_request(Request(rid=0, prompt=list(rng.randint(0, 128, size=60)),
                            max_new_tokens=10))
    done = eng.run()
    assert len(done) == 1
    assert 0 < len(done[0].output) <= 64 - 60 + 1   # truncated at ceiling
    with pytest.raises(ValueError):
        eng.add_request(Request(rid=1,
                                prompt=list(rng.randint(0, 128, size=64)),
                                max_new_tokens=1))


def test_unservable_request_is_rejected_or_raises(mesh11, tiny_cfg,
                                                  model_builder):
    """A request the pool can never hold must be rejected up front; a
    stuck queue (e.g. after preemption regrowth) must raise, not silently
    drop requests."""
    api, params = model_builder(tiny_cfg)
    eng = Engine(api, mesh11, params,
                 SchedulerConfig(max_batch=2, chunk_tokens=32, max_len=64,
                                 prefill_bucket=16, paged=True,
                                 block_size=4, num_blocks=3,
                                 prefix_caching=False))
    with pytest.raises(ValueError):   # needs 3 blocks + headroom > 3
        eng.add_request(Request(rid=0, prompt=list(range(12)),
                                max_new_tokens=2))
    # admissible at first, but decode growth exhausts the pool, the request
    # self-preempts, and its regrown context (prompt + 9 generated) no
    # longer fits 3 blocks + headroom: run() must raise, not drop it
    eng.add_request(Request(rid=1, prompt=list(range(4)),
                            max_new_tokens=12))
    with pytest.raises(RuntimeError, match="unservable"):
        eng.run()


def test_legacy_slot_reset_on_finish(mesh11, tiny_cfg, model_builder):
    """Regression: a finished long request's stale cache rows must not
    leak into a short request reusing its slot (Engine now resets slots
    on finish)."""
    rng = np.random.RandomState(4)
    long_p = list(rng.randint(0, 128, size=60))
    short_p = list(rng.randint(0, 128, size=9))
    api, params = model_builder(tiny_cfg)
    # reference: short prompt alone in a fresh engine
    ref, _ = _run_engine((api, params), mesh11, [short_p], max_batch=1,
                         paged=False)
    eng = Engine(api, mesh11, params,
                 SchedulerConfig(max_batch=1, chunk_tokens=32, max_len=128,
                                 prefill_bucket=16))
    eng.add_request(Request(rid=0, prompt=list(long_p), max_new_tokens=6))
    eng.add_request(Request(rid=1, prompt=list(short_p), max_new_tokens=6))
    done = eng.run()
    outs = {r.rid: r.output for r in done}
    assert outs[1] == ref[0], (outs[1], ref[0])
