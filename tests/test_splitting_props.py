"""Hypothesis property tests for wave-aware smart-splitting (paper §3.1.1).

Skipped entirely when hypothesis is not installed; the deterministic
counterparts in test_splitting.py always run."""
import math

import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.splitting import (naive_split, pad_to_multiple,  # noqa: E402
                                  smart_split, split_sizes_for_batch,
                                  wave_count)


@given(n=st.integers(1, 10_000_000), unit=st.integers(1, 4096))
@settings(max_examples=300, deadline=None)
def test_smart_split_invariants(n, unit):
    s = smart_split(n, unit)
    if s is None:
        assert n < 2 * unit
        return
    l1, l2 = s
    assert l1 + l2 == n
    assert l1 > 0 and l2 > 0
    # prefix split is full waves only
    assert l1 % unit == 0
    # the paper's wave-conservation property
    assert wave_count(l1, unit) + wave_count(l2, unit) == wave_count(n, unit)


@given(n=st.integers(2, 1_000_000), unit=st.integers(1, 2048))
@settings(max_examples=200, deadline=None)
def test_naive_split_can_add_waves_smart_never(n, unit):
    e1, e2 = naive_split(n)
    naive_waves = wave_count(e1, unit) + wave_count(e2, unit)
    assert naive_waves >= wave_count(n, unit)  # never fewer
    s = smart_split(n, unit)
    if s is not None:
        l1, l2 = s
        assert wave_count(l1, unit) + wave_count(l2, unit) <= naive_waves


@given(n=st.integers(1, 500_000), unit=st.integers(8, 512),
       rows=st.integers(1, 64), min_tokens=st.integers(0, 4096))
@settings(max_examples=200, deadline=None)
def test_split_sizes_for_batch(n, unit, rows, min_tokens):
    s = split_sizes_for_batch(n, unit=unit, min_tokens=min_tokens,
                              row_multiple=rows)
    if s is None:
        return
    l1, l2 = s
    assert l1 + l2 == n
    assert l1 % math.lcm(unit, rows) == 0
    assert n >= min_tokens


@given(n=st.integers(0, 1_000_000), m=st.integers(1, 4096))
@settings(max_examples=100, deadline=None)
def test_pad_to_multiple(n, m):
    p = pad_to_multiple(n, m)
    assert p >= n and p % m == 0 and p - n < m


@given(n=st.integers(1, 500_000), unit=st.integers(1, 512),
       rows=st.integers(1, 64), min_tokens=st.integers(0, 4096),
       site=st.sampled_from(("prefill", "decode", "verify", "packed")),
       tp=st.integers(1, 16))
@settings(max_examples=300, deadline=None)
def test_threshold_policy_is_split_decision(n, unit, rows, min_tokens,
                                            site, tp):
    """DESIGN.md §14: the degenerate ThresholdPolicy must be the legacy
    global-threshold decision FIELD-FOR-FIELD, for every site/tp key —
    engines without a tuned plan cannot change behavior."""
    from repro.core.policy import ThresholdPolicy
    from repro.core.splitting import split_decision
    got = ThresholdPolicy().decide(site, n, unit=unit,
                                   min_tokens=min_tokens,
                                   row_multiple=rows, tp=tp)
    assert got == split_decision(n, unit=unit, min_tokens=min_tokens,
                                 row_multiple=rows)


@given(n=st.integers(1, 500_000), unit=st.integers(1, 1024),
       frac=st.floats(0.01, 0.99))
@settings(max_examples=300, deadline=None)
def test_plan_split_conserves_waves(n, unit, frac):
    """The tuner's parameterized split keeps the paper's wave-conservation
    property at EVERY fraction, and frac=0.5 is exactly smart_split."""
    from repro.core.splitting import plan_split
    s = plan_split(n, unit, frac)
    if s is None:
        assert n < 2 * unit
        return
    l1, l2 = s
    assert l1 + l2 == n and l1 > 0 and l2 > 0 and l1 % unit == 0
    assert wave_count(l1, unit) + wave_count(l2, unit) == wave_count(n, unit)
    assert plan_split(n, unit, 0.5) == smart_split(n, unit)
