"""Per-kernel interpret-mode validation against the pure-jnp oracles,
swept over shapes and dtypes (assignment requirement c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_rmsnorm import fused_residual_rmsnorm_pallas
from repro.kernels.ref import (flash_attention_ref,
                               fused_residual_rmsnorm_ref)


@pytest.mark.parametrize("t,d", [(8, 64), (64, 128), (128, 384), (56, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm_matches_oracle(t, d, dtype):
    key = jax.random.PRNGKey(t + d)
    x = jax.random.normal(key, (t, d), dtype)
    r = jax.random.normal(jax.random.PRNGKey(1), (t, d), dtype)
    w = (jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (d,))) + 0.5
         ).astype(dtype)
    o_ref, r_ref = fused_residual_rmsnorm_ref(x, r, w)
    o_k, r_k = fused_residual_rmsnorm_pallas(x, r, w, interpret=True,
                                             block_tokens=32)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_ref, np.float32), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(r_k, np.float32),
                               np.asarray(r_ref, np.float32), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize(
    "b,sq,sk,kvh,g,dh,causal,window,off",
    [
        (2, 40, 72, 2, 3, 16, True, 0, 32),   # GQA + chunked offset
        (1, 64, 64, 1, 4, 32, True, 24, 0),   # sliding window
        (2, 33, 65, 2, 1, 16, False, 0, 0),   # bidirectional, ragged blocks
        (1, 16, 128, 4, 2, 64, True, 0, 112),  # decode-ish long kv
    ])
def test_flash_attention_matches_oracle(b, sq, sk, kvh, g, dh, causal,
                                        window, off):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, sq, kvh, g, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, kvh, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, kvh, dh))
    qpos = jnp.broadcast_to(jnp.arange(off, off + sq)[None], (b, sq))
    kpos = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))
    o = flash_attention(q, k, v, qpos, kpos, causal=causal, window=window,
                        block_q=16, block_kv=32, interpret=True)
    refs = [flash_attention_ref(q[i].reshape(sq, kvh * g, dh), k[i], v[i],
                                causal=causal, window=window, q_offset=off
                                ).reshape(sq, kvh, g, dh)
            for i in range(b)]
    np.testing.assert_allclose(o, jnp.stack(refs), rtol=2e-5, atol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("n,t,d", [(4, 16, 32), (8, 32, 64), (2, 8, 128)])
def test_ring_ar_rmsnorm_multidevice(n, t, d, tmp_path):
    """The paper's fused AllReduce-RMSNorm kernel, validated on n simulated
    devices via the Pallas TPU interpret machinery (subprocess)."""
    import jax.experimental.pallas.tpu as pltpu
    if not hasattr(pltpu, "InterpretParams"):
        pytest.skip("pre-0.5 pallas interpreter cannot emulate the "
                    "remote-DMA ring kernel (semaphore tracer bug)")
    from conftest import run_distributed
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.kernels.ring_ar_rmsnorm import ring_fused_ar_rmsnorm
from repro.kernels.ref import ring_ar_rmsnorm_ref
N, T, D = {n}, {t}, {d}
mesh = jax.make_mesh((N,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
xs = jax.random.normal(jax.random.PRNGKey(0), (N, T, D), jnp.float32)
res = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)
w = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (D,))) + 0.5
def f(x_shard, res_shard):
    return ring_fused_ar_rmsnorm(x_shard[0], res_shard, w, axis_name='x',
                                 n_dev=N, interpret=True)
g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=(P('x'), P('x')),
                          out_specs=(P(None), P('x')), check_vma=False))
out, new_res = g(xs, res)
ref_outs, ref_res = ring_ar_rmsnorm_ref(
    [xs[i] for i in range(N)],
    [res.reshape(N, T // N, D)[i] for i in range(N)], w)
np.testing.assert_allclose(out, ref_outs[0], rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(new_res, jnp.concatenate(ref_res, 0),
                           rtol=2e-5, atol=2e-5)
print('PASS')
"""
    run_distributed(code, n_devices=n)
