"""Sampling over vocab-sharded logits: top-k / top-p filtering and the
Gumbel-max sampler (runtime/sampler.py) against dense numpy references."""
import jax
import jax.numpy as jnp
import pytest
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.runtime import sampler as S


def _shmap(mesh, fn, n_in):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=(P(),) * n_in,
                                 out_specs=P(), check_vma=False))


def _kept(filtered):
    return set(np.flatnonzero(np.asarray(filtered[0, 0]) > -1e29))


def test_top_k_keeps_k_largest(mesh11):
    rng = np.random.RandomState(0)
    lg = jnp.asarray(rng.randn(1, 1, 32), jnp.float32)
    for k in (1, 3, 7, 32, 100):
        out = _shmap(mesh11, lambda x, k=k: S.apply_top_k(x, k), 1)(lg)
        want = set(np.argsort(np.asarray(lg[0, 0]))[::-1][:min(k, 32)])
        assert _kept(out) == want, k


def test_top_p_matches_sorted_cumsum_reference(mesh11):
    rng = np.random.RandomState(1)
    lg = jnp.asarray(rng.randn(1, 1, 64) * 2.0, jnp.float32)
    probs = np.asarray(jax.nn.softmax(lg[0, 0]))
    order = np.argsort(probs)[::-1]
    csum = np.cumsum(probs[order])
    for p in (0.1, 0.5, 0.9, 0.99):
        # nucleus = smallest prefix reaching p, crossing token included
        cut = int(np.searchsorted(csum, p)) + 1
        want = set(order[:cut])
        out = _shmap(mesh11, lambda x, p=p: S.apply_top_p(x, p), 1)(lg)
        assert _kept(out) == want, p


def test_top_p_one_is_identity(mesh11):
    lg = jnp.asarray(np.random.RandomState(2).randn(2, 3, 16), jnp.float32)
    out = _shmap(mesh11, lambda x: S.apply_top_p(x, 1.0), 1)(lg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(lg))


def test_greedy_sample_is_argmax(mesh11):
    lg = jnp.asarray(np.random.RandomState(3).randn(4, 1, 32), jnp.float32)
    out = _shmap(mesh11,
                 lambda x: S.sample(x, vocab_size=32, temperature=0.0), 1)(lg)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.argmax(np.asarray(lg[:, 0]), axis=-1))


def test_stochastic_sample_distribution(mesh11):
    """Gumbel-max + temperature + top-k must empirically match the
    renormalized truncated softmax."""
    vocab, k, temp = 16, 5, 0.7
    lg = jnp.asarray(np.random.RandomState(4).randn(1, 1, vocab) * 1.5,
                     jnp.float32)

    def fn(x, key):
        return S.sample(x, vocab_size=vocab, temperature=temp, top_k=k,
                        key=key)

    sm = _shmap(mesh11, fn, 2)
    n = 4000
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    counts = np.zeros(vocab)
    for i in range(n):
        counts[int(sm(lg, keys[i])[0])] += 1
    emp = counts / n

    scaled = np.asarray(lg[0, 0]) / temp
    top = np.argsort(scaled)[::-1][:k]
    ref = np.zeros(vocab)
    e = np.exp(scaled[top] - scaled[top].max())
    ref[top] = e / e.sum()
    tv = 0.5 * np.abs(emp - ref).sum()
    assert tv < 0.05, (tv, emp, ref)
    assert set(np.flatnonzero(counts)) <= set(top)   # never off-nucleus


@pytest.mark.slow
def test_sharded_topk_matches_dense(mesh11):
    """top-k/top-p under real vocab sharding equals the single-shard
    reference (4 fake CPU devices, vocab split 4 ways)."""
    from conftest import run_distributed
    run_distributed("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.runtime import sampler as S
mesh = jax.make_mesh((4,), ('model',),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(0)
lg = jnp.asarray(rng.randn(2, 1, 32), jnp.float32)

def f(x):
    k = S.apply_top_k(x, 5, tp_axis='model')
    p = S.apply_top_p(x, 0.8, tp_axis='model')
    return k, p

sharded = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=(P(None, None, 'model'),),
                                out_specs=(P(None, None, 'model'),) * 2,
                                check_vma=False))
k_s, p_s = sharded(lg)
mesh1 = jax.make_mesh((1,), ('model',),
                      axis_types=(jax.sharding.AxisType.Auto,))
single = jax.jit(jax.shard_map(f, mesh=mesh1, in_specs=(P(),),
                               out_specs=(P(), P()), check_vma=False))
k_1, p_1 = single(lg)
kept = lambda a: [set(np.flatnonzero(np.asarray(a)[b, 0] > -1e29))
                  for b in range(2)]
assert kept(k_s) == kept(k_1), (kept(k_s), kept(k_1))
assert kept(p_s) == kept(p_1), (kept(p_s), kept(p_1))
print('PASS')
""", n_devices=4)
