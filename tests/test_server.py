"""Online serving frontend (runtime/server.py, DESIGN.md §10).

Lifecycle-edge coverage the offline engine cannot express: virtual-clock
arrival/admission, streaming callbacks, cancellation mid-prefill and
mid-verify (with full block / prefix-cache-ref release), deadline expiry
semantics (goodput-accounting, never a failure), admission-policy
ordering (FCFS vs EDF), and no-starvation of admitted decodes under late
arrival floods — plus online-vs-offline token identity, the §10 pin."""
import numpy as np
import pytest

from repro.runtime.requests import (Request, State, bursty_arrivals,
                                    poisson_arrivals, replay_arrivals)
from repro.runtime.scheduler import SchedulerConfig
from repro.runtime.server import OnlineServer, ServerConfig, StepCost


def _reqs(rng, n, in_lo=8, in_hi=30, out=4, arrival=None):
    reqs = [Request(rid=i,
                    prompt=list(rng.randint(0, 128,
                                            size=rng.randint(in_lo, in_hi))),
                    max_new_tokens=out) for i in range(n)]
    if arrival is not None:
        replay_arrivals(reqs, arrival)
    return reqs


def _leak_check(eng):
    mgr = eng.block_mgr
    if mgr is None:
        return
    assert not mgr.tables, list(mgr.tables)
    leaked = [b for b in range(mgr.alloc.num_blocks) if mgr.alloc.ref[b]]
    assert not leaked, leaked


# --------------------------------------------------------------------------
# arrival-process generators
# --------------------------------------------------------------------------

def test_arrival_generators_deterministic_and_sorted():
    rng = np.random.RandomState(0)
    a = poisson_arrivals(_reqs(rng, 10), rate=0.5, seed=3)
    rng = np.random.RandomState(0)
    b = poisson_arrivals(_reqs(rng, 10), rate=0.5, seed=3)
    assert [r.arrival_time for r in a] == [r.arrival_time for r in b]
    assert all(x.arrival_time <= y.arrival_time for x, y in zip(a, a[1:]))

    rng = np.random.RandomState(1)
    c = bursty_arrivals(_reqs(rng, 12), rate=5.0, burst=4, off_time=50.0,
                        seed=9)
    gaps = [y.arrival_time - x.arrival_time for x, y in zip(c, c[1:])]
    # the inter-burst gaps dwarf the intra-burst ones
    assert max(gaps) > 10 * min(gaps)

    rng = np.random.RandomState(2)
    d = replay_arrivals(_reqs(rng, 3), [5.0, 1.0, 3.0])
    assert [r.arrival_time for r in d] == [1.0, 3.0, 5.0]
    with pytest.raises(ValueError):
        replay_arrivals(d, [1.0])


# --------------------------------------------------------------------------
# token identity + streaming
# --------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [False, True], ids=["two", "packed"])
def test_online_token_identical_to_offline(packed, tiny_engine_builder):
    kw = dict(paged=True, packed=packed, block_size=16)
    rng = np.random.RandomState(3)
    arrivals = [0.0, 2.0, 2.5, 9.0, 11.0]
    eng = tiny_engine_builder(**kw)
    for r in _reqs(rng, 5, arrival=arrivals):
        eng.add_request(r)
    ref = {r.rid: r.output for r in eng.run()}
    _leak_check(eng)

    rng = np.random.RandomState(3)
    eng2 = tiny_engine_builder(**kw)
    srv = OnlineServer(eng2, ServerConfig(
        step_cost=StepCost(base=1.0, per_token=0.02)))
    streamed = []
    for r in _reqs(rng, 5, arrival=arrivals):
        srv.submit(r, on_token=lambda rq, t, at: streamed.append(
            (rq.rid, t, at)))
    done = srv.run()
    got = {r.rid: r.output for r in done}
    assert got == ref
    _leak_check(eng2)
    # streaming delivered every token, in per-request order, time-stamped
    # with a nondecreasing clock
    per_rid = {}
    for rid, tok, at in streamed:
        per_rid.setdefault(rid, []).append(tok)
    assert per_rid == ref
    times = [at for _, _, at in streamed]
    assert times == sorted(times)
    # TTFT/e2e recorded for every request, goodput 1 (no deadlines)
    lat = eng2.stats.latency
    assert len(lat.ttft) == 5 and len(lat.e2e) == 5
    assert lat.goodput == 1.0
    for r in done:
        assert r.ttft is not None and r.ttft >= 0
        assert r.e2e_latency >= (r.ttft or 0)


# --------------------------------------------------------------------------
# cancellation: mid-prefill and mid-verify release everything
# --------------------------------------------------------------------------

def test_cancel_mid_prefill_releases_blocks_and_prefix_refs(
        tiny_engine_builder):
    """A long prompt sharing a cached prefix is cancelled while still
    PREFILL: its private blocks AND its references on prefix-cache-shared
    blocks must be dropped (the shared blocks stay cached for others)."""
    rng = np.random.RandomState(5)
    shared = list(rng.randint(0, 128, size=32))
    eng = tiny_engine_builder(paged=True, block_size=16, chunk_tokens=16,
                              prefix_caching=True)
    srv = OnlineServer(eng)
    warm = Request(rid=0, prompt=shared + [1, 2], max_new_tokens=2)
    warm.arrival_time = 0.0
    srv.submit(warm)
    victim = Request(rid=1, prompt=shared + list(range(3, 40)),
                     max_new_tokens=4)
    victim.arrival_time = 6.0
    srv.submit(victim)
    # chunk_tokens=16 => victim's ~37-token miss suffix needs 3 prefill
    # steps; cancel it one step after arrival, mid-prefill
    srv.cancel(1, at=7.5)
    done = srv.run()
    assert [r.rid for r in done] == [0]
    assert victim.finish_reason == "cancelled"
    assert victim.state == State.DONE
    assert not victim.output                    # never reached DECODE
    assert 0 < victim.prefill_pos < len(victim.prompt)   # truly mid-prefill
    assert victim.prompt_hit_tokens > 0         # it DID share the prefix
    _leak_check(eng)
    assert eng.stats.cancelled == 1
    # shared blocks survive in the prefix cache (cached-free, hittable)
    assert len(eng.block_mgr.prefix) > 0


@pytest.mark.parametrize("packed", [False, True], ids=["two", "packed"])
def test_cancel_mid_verify_releases_blocks(packed, tiny_engine_builder):
    """Cancellation while a spec-decode request is mid-verify (DECODE with
    committed tokens and a γ-window in flight between steps): rollback
    state, grown draft blocks, and the table must all release."""
    eng = tiny_engine_builder(paged=True, packed=packed, block_size=16,
                              spec_gamma=3, max_len=256)
    srv = OnlineServer(eng)
    rng = np.random.RandomState(6)
    motif = list(rng.randint(0, 128, size=10))

    # cancel rid 1 from inside its own stream after its 3rd token — the
    # cancel lands between steps while verify windows are active
    def on_token(rq, tok, at):
        if len(rq.output) == 3:
            srv.cancel(1)

    for i in range(3):
        r = Request(rid=i, prompt=motif * 3, max_new_tokens=12)
        r.arrival_time = 0.0
        srv.submit(r, on_token=on_token if i == 1 else None)
    done = srv.run()
    assert {r.rid for r in done} == {0, 2}
    victim = srv.aborted[0]
    assert victim.rid == 1 and victim.finish_reason == "cancelled"
    assert 3 <= len(victim.output) < 12        # cancelled mid-generation
    assert eng.stats.spec.verify_steps > 0     # spec path actually ran
    for r in done:
        assert len(r.output) == 12             # peers unaffected
    _leak_check(eng)


def test_cancel_before_arrival_never_reaches_engine(tiny_engine_builder):
    eng = tiny_engine_builder(paged=True)
    srv = OnlineServer(eng)
    rng = np.random.RandomState(7)
    a, b = _reqs(rng, 2, arrival=[0.0, 50.0])
    srv.submit(a)
    srv.submit(b)
    srv.cancel(1, at=10.0)       # long before rid 1's arrival at t=50
    done = srv.run()
    assert [r.rid for r in done] == [0]
    assert b.finish_reason == "cancelled" and b.admit_time is None
    assert eng.stats.cancelled == 1
    # regression: a never-arrived cancel must not poison the latency
    # percentiles (its clock-now "finish" precedes its arrival)
    assert len(eng.stats.latency.e2e) == 1
    assert all(x >= 0 for x in eng.stats.latency.e2e)
    _leak_check(eng)


# --------------------------------------------------------------------------
# deadlines: goodput accounting, not failures
# --------------------------------------------------------------------------

def test_deadline_expiry_counts_against_goodput_not_failure(
        tiny_engine_builder):
    """expire_on_deadline: a hopeless request is aborted at its deadline
    (resources released), counted in goodput's denominator — and the run
    completes normally; peers are untouched."""
    eng = tiny_engine_builder(paged=True)
    srv = OnlineServer(eng, ServerConfig(expire_on_deadline=True))
    rng = np.random.RandomState(8)
    reqs = _reqs(rng, 4, out=6, arrival=[0.0, 0.5, 1.0, 1.5])
    reqs[2].deadline = reqs[2].arrival_time + 2.0     # hopeless
    for r in reqs:
        srv.submit(r)
    done = srv.run()                                  # no exception
    assert {r.rid for r in done} == {0, 1, 3}
    assert reqs[2].finish_reason == "expired"
    assert not reqs[2].slo_ok
    assert eng.stats.expired == 1 and eng.stats.cancelled == 0
    lat = eng.stats.latency
    assert lat.slo_total == 4 and lat.slo_met == 3
    assert lat.goodput == pytest.approx(0.75)
    _leak_check(eng)


def test_deadline_late_finish_without_expiry(tiny_engine_builder):
    """Default policy: a past-deadline request still runs to completion
    (full output), but its slo_ok is False and goodput drops — late
    service is an SLO miss, not a dropped request."""
    eng = tiny_engine_builder(paged=True)
    srv = OnlineServer(eng)     # expire_on_deadline=False
    rng = np.random.RandomState(9)
    reqs = _reqs(rng, 2, out=5, arrival=[0.0, 0.0])
    reqs[1].deadline = 0.5                            # will finish late
    for r in reqs:
        srv.submit(r)
    done = srv.run()
    assert {r.rid for r in done} == {0, 1}
    assert len(reqs[1].output) == 5                   # served in full
    assert reqs[1].finish_reason == "stop" and not reqs[1].slo_ok
    assert eng.stats.expired == 0
    assert eng.stats.latency.goodput == pytest.approx(0.5)
    _leak_check(eng)


# --------------------------------------------------------------------------
# admission policy + starvation
# --------------------------------------------------------------------------

def test_edf_policy_admits_by_deadline(tiny_engine_builder):
    """Three requests queue behind a full engine; EDF admits them in
    deadline order (tightest first), not arrival order."""
    rng = np.random.RandomState(10)
    outcomes = {}
    for policy in ("fcfs", "edf"):
        eng = tiny_engine_builder(paged=True, max_batch=1, policy=policy)
        srv = OnlineServer(eng)
        blocker = Request(rid=0, prompt=list(range(8)), max_new_tokens=8)
        blocker.arrival_time = 0.0
        srv.submit(blocker)
        # all three arrive while the blocker occupies the only slot
        deadlines = {1: 200.0, 2: 50.0, 3: 100.0}
        for rid in (1, 2, 3):
            r = Request(rid=rid,
                        prompt=list(rng.randint(0, 128, size=10)),
                        max_new_tokens=2, deadline=deadlines[rid])
            r.arrival_time = 1.0 + 0.1 * rid
            srv.submit(r)
        srv.run()
        outcomes[policy] = [rid for _, rid in
                            sorted((r.first_token_time, r.rid)
                                   for r in srv.completed if r.rid != 0)]
        _leak_check(eng)
    assert outcomes["fcfs"] == [1, 2, 3]
    assert outcomes["edf"] == [2, 3, 1]


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="admission policy"):
        SchedulerConfig(policy="sjf")


def test_late_arrivals_never_starve_admitted_decodes(tiny_engine_builder):
    """An admitted decode keeps its slot and decodes every iteration no
    matter how many later requests arrive (even tighter-deadline ones
    under EDF): admission is slot-gated, never slot-stealing."""
    for policy in ("fcfs", "edf"):
        eng = tiny_engine_builder(paged=True, max_batch=2, policy=policy)
        srv = OnlineServer(eng)
        rng = np.random.RandomState(11)
        early = _reqs(rng, 2, out=10, arrival=[0.0, 0.0])
        for r in early:
            srv.submit(r)
        # a flood of later arrivals with aggressive deadlines
        flood = [Request(rid=10 + i,
                         prompt=list(rng.randint(0, 128, size=12)),
                         max_new_tokens=2, deadline=6.0 + i)
                 for i in range(6)]
        for i, r in enumerate(flood):
            r.arrival_time = 2.0 + 0.1 * i
            srv.submit(r)
        done = srv.run()
        assert len(done) == 8
        for r in early:
            assert len(r.output) == 10          # full budget, no eviction
            assert r.preemptions == 0
        # the early decodes finished BEFORE the last flood request —
        # they were never parked to make room
        last_flood_finish = max(r.finish_time for r in flood)
        for r in early:
            assert r.finish_time <= last_flood_finish
        _leak_check(eng)


# --------------------------------------------------------------------------
# streaming HTTP/websocket API end-to-end (runtime/http_api.py, §15): a
# spawned server process, a raw-socket client, token identity vs offline
# --------------------------------------------------------------------------

def _spawn_api(step_delay=0.0):
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.runtime.http_api", "--port", "0",
         "--step-delay", str(step_delay)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
    line = proc.stdout.readline()
    assert line.startswith("LISTENING"), (line, proc.stderr.read()[-2000:])
    _, host, port = line.split()
    return proc, host, int(port)


def _http_json(host, port, method, path, body=None):
    import http.client
    import json
    conn = http.client.HTTPConnection(host, port, timeout=120)
    payload = json.dumps(body) if body is not None else None
    conn.request(method, path, body=payload,
                 headers={"Content-Type": "application/json"} if payload
                 else {})
    resp = conn.getresponse()
    out = json.loads(resp.read().decode("utf-8"))
    conn.close()
    return resp.status, out


def _open_stream(host, port, body):
    """POST a streaming completion over a raw socket; return (sock, file)
    positioned after the response headers."""
    import json
    import socket
    payload = json.dumps(body).encode("utf-8")
    s = socket.create_connection((host, port), timeout=120)
    s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
               f"Content-Type: application/json\r\n"
               f"Content-Length: {len(payload)}\r\n\r\n").encode("ascii")
              + payload)
    f = s.makefile("rb")
    status = f.readline()
    assert b"200" in status, status
    while f.readline() not in (b"\r\n", b"\n", b""):
        pass
    return s, f


def _sse_events(f):
    """Yield decoded ``data:`` payloads until ``[DONE]`` or EOF."""
    import json
    while True:
        line = f.readline()
        if not line:
            return
        line = line.strip()
        if not line.startswith(b"data: "):
            continue
        data = line[len(b"data: "):]
        if data == b"[DONE]":
            return
        yield json.loads(data.decode("utf-8"))


@pytest.mark.slow
def test_http_api_stream_token_identical_to_offline(tiny_engine_builder):
    # the API worker builds transport.DEFAULT_SPEC == this local twin
    rng = np.random.RandomState(17)
    prompts = [[int(t) for t in rng.randint(0, 128, size=rng.randint(8, 24))]
               for _ in range(3)]
    outs = [6, 4, 8]
    eng = tiny_engine_builder(max_len=96, paged=True, block_size=8)
    for i, (p, n) in enumerate(zip(prompts, outs)):
        eng.add_request(Request(rid=i, prompt=list(p), max_new_tokens=n))
    ref = {r.rid: r.output for r in eng.run()}

    proc, host, port = _spawn_api()
    try:
        status, health = _http_json(host, port, "GET", "/v1/health")
        assert status == 200 and health["ok"]

        # rid 0: streamed SSE — tokens arrive one event apiece, in order
        s, f = _open_stream(host, port, {"prompt": prompts[0],
                                         "max_new_tokens": outs[0],
                                         "stream": True})
        events = list(_sse_events(f))
        s.close()
        toks = [e["token"] for e in events if "token" in e]
        assert toks == ref[0]
        assert events[-1].get("done") and events[-1]["finish_reason"]

        # rid 1: non-streamed — one JSON body after completion
        status, body = _http_json(host, port, "POST", "/v1/completions",
                                  {"prompt": prompts[1],
                                   "max_new_tokens": outs[1]})
        assert status == 200 and body["tokens"] == ref[1]

        # rid 2: websocket — one text frame per token event
        ws_toks = _ws_collect(host, port, {"prompt": prompts[2],
                                           "max_new_tokens": outs[2]})
        assert ws_toks == ref[2]

        # bad request rejected without touching the engine
        status, err = _http_json(host, port, "POST", "/v1/completions",
                                 {"prompt": "not a token list"})
        assert status == 400 and "prompt" in err["error"]

        status, stats = _http_json(host, port, "GET", "/v1/stats")
        assert stats["completed"] == 3 and stats["live_streams"] == 0
        assert stats["tables"] == 0 and stats["leaked_blocks"] == 0
    finally:
        proc.terminate()
        proc.wait(timeout=30)


def _ws_collect(host, port, body):
    """Minimal RFC6455 client: upgrade, send one masked text frame, read
    unmasked server frames until the done event / close frame."""
    import base64
    import json
    import os as _os
    import socket
    from repro.runtime.http_api import ws_read  # server-side reader reused

    key = base64.b64encode(_os.urandom(16)).decode("ascii")
    s = socket.create_connection((host, port), timeout=120)
    s.sendall((f"GET /v1/stream HTTP/1.1\r\nHost: t\r\n"
               f"Upgrade: websocket\r\nConnection: Upgrade\r\n"
               f"Sec-WebSocket-Key: {key}\r\n"
               f"Sec-WebSocket-Version: 13\r\n\r\n").encode("ascii"))
    f = s.makefile("rb")
    assert b"101" in f.readline()
    while f.readline() not in (b"\r\n", b"\n", b""):
        pass
    payload = json.dumps(body).encode("utf-8")
    mask = _os.urandom(4)
    masked = bytes(b ^ mask[i % 4] for i, b in enumerate(payload))
    s.sendall(bytes([0x81, 0x80 | len(payload)]) + mask + masked)

    toks = []
    while True:
        opcode, data = _read_ws_frame(f)
        if opcode == 0x8:                       # close
            break
        ev = json.loads(data.decode("utf-8"))
        if "token" in ev:
            toks.append(ev["token"])
        if ev.get("done"):
            break
    s.close()
    return toks


def _read_ws_frame(f):
    head = f.read(2)
    assert len(head) == 2
    opcode = head[0] & 0x0F
    n = head[1] & 0x7F
    if n == 126:
        n = int.from_bytes(f.read(2), "big")
    elif n == 127:
        n = int.from_bytes(f.read(8), "big")
    return opcode, f.read(n)


@pytest.mark.slow
def test_http_api_disconnect_releases_blocks():
    import time
    # pace the engine so the stream is observably partial at disconnect
    proc, host, port = _spawn_api(step_delay=0.05)
    try:
        rng = np.random.RandomState(23)
        prompt = [int(t) for t in rng.randint(0, 128, size=20)]
        s, f = _open_stream(host, port, {"prompt": prompt,
                                         "max_new_tokens": 64,
                                         "stream": True})
        seen = 0
        for ev in _sse_events(f):
            if "token" in ev:
                seen += 1
            if seen >= 2:
                break                           # walk away mid-stream
        # makefile() holds a duplicate handle: shutdown() is what actually
        # sends the FIN the server's EOF-race is waiting on
        import socket as _socket
        s.shutdown(_socket.SHUT_RDWR)
        f.close()
        s.close()
        assert seen == 2                        # partial, not finished

        deadline = time.time() + 60
        while time.time() < deadline:
            _, stats = _http_json(host, port, "GET", "/v1/stats")
            if stats["cancelled"] >= 1 and stats["live_streams"] == 0:
                break
            time.sleep(0.1)
        assert stats["cancelled"] >= 1          # EOF → cancel → abort
        assert stats["live_streams"] == 0
        assert stats["tables"] == 0             # blocks all released
        assert stats["leaked_blocks"] == 0
        assert stats["completed"] == 0
    finally:
        proc.terminate()
        proc.wait(timeout=30)
