"""Multi-device semantics (8 fake CPU devices via subprocess): the paper's
central equivalences — fused/reordered/vanilla comm_norm identity, dense
model loss identity across comm modes and the weave, MoE partitionings vs
the dense oracle."""
import pytest

from conftest import run_distributed


@pytest.mark.slow
def test_comm_norm_modes_equal():
    run_distributed("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.context import CommCtx
from repro.core import fused_collectives as fc
mesh = jax.make_mesh((1, 8), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
T, d, tp = 64, 32, 8
xs = jax.random.normal(jax.random.PRNGKey(0), (tp, T, d), jnp.float32)
res = jax.random.normal(jax.random.PRNGKey(3), (T, d), jnp.float32)
w = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (d,))) + 0.5
def run(mode):
    ctx = CommCtx(mode=mode)
    sharded = mode in ('fused', 'reordered')
    def f(xsh, r):
        return fc.comm_norm(xsh[0], r if sharded else r[0], w, ctx=ctx)
    res_in = res if sharded else jnp.broadcast_to(res[None], (tp, T, d))
    g = jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=(P('model'), P('model')),
        out_specs=(P(None), P('model') if sharded else P(None)),
        check_vma=False))
    return g(xs, res_in)
o_v, r_v = run('vanilla')
o_f, r_f = run('fused')
o_r, r_r = run('reordered')
np.testing.assert_allclose(o_v, o_f, rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(o_v, o_r, rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(r_v, r_f.reshape(T, d), rtol=2e-5, atol=2e-5)
print('PASS')
""")


@pytest.mark.slow
def test_dense_model_modes_and_weave_equal_tp4():
    run_distributed("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import transformer as T
cfg = ModelConfig(name='tiny', family='dense', num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256, dtype='float32')
mesh = jax.make_mesh((2, 4), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
tok = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, 256)
lab = jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0, 256)
base = ParallelConfig(tokenweave=False, comm_mode='vanilla', remat=False,
                      split_unit=32, tokenweave_min_tokens=64)
params = T.init_params(jax.random.PRNGKey(0), cfg, base, 4)
losses = {}
for name, over in {
    'vanilla': {}, 'fused': dict(comm_mode='fused'),
    'reordered': dict(comm_mode='reordered'),
    'weave': dict(comm_mode='fused', tokenweave=True),
    'weave_reordered': dict(comm_mode='reordered', tokenweave=True),
}.items():
    pcfg = dataclasses.replace(base, **over)
    def loss_fn(params, tokens, labels):
        ls, dn, _ = T.train_loss(params, {'tokens': tokens,
                                          'labels': labels},
                                 cfg=cfg, pcfg=pcfg)
        return jax.lax.psum(ls, 'data') / jax.lax.psum(dn, 'data')
    f = jax.jit(jax.shard_map(
        loss_fn, mesh=mesh,
        in_specs=(T.param_specs(cfg, pcfg), P('data'), P('data')),
        out_specs=P(), check_vma=False))
    losses[name] = float(f(params, tok, lab))
ref = losses['vanilla']
for k, v in losses.items():
    np.testing.assert_allclose(v, ref, rtol=1e-5), (k, v, ref)
print('PASS', losses)
""")


@pytest.mark.slow
def test_moe_partitionings_match_dense_oracle():
    run_distributed("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.base import ModelConfig
from repro.layers import moe as M
cfg = ModelConfig(name='t', family='moe', num_layers=1, d_model=32,
                  num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                  num_experts=8, num_experts_per_tok=2, moe_d_ff=16,
                  capacity_factor=8.0, dtype='float32')
p1 = M.init_moe_params(jax.random.PRNGKey(0), cfg, 1)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
def dense(params, x):
    wg, wu, wd = params['w_gate'][0], params['w_up'][0], params['w_down'][0]
    t = x.reshape(-1, 32)
    probs = jax.nn.softmax(t @ params['router'][0], -1)
    topw, topi = jax.lax.top_k(probs, 2)
    topw = topw / topw.sum(-1, keepdims=True)
    out = jnp.zeros_like(t)
    for e in range(8):
        h = jax.nn.silu(t @ wg[e]) * (t @ wu[e])
        out += jnp.where(topi == e, topw, 0.).sum(-1)[:, None] * (h @ wd[e])
    return out.reshape(x.shape)
o_ref = dense(p1, x)
mesh4 = jax.make_mesh((1, 4), ('data', 'model'),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
# expert mode tp=4
wg4 = p1['w_gate'][0].reshape(4, 2, 32, 16)
wu4 = p1['w_up'][0].reshape(4, 2, 32, 16)
wd4 = p1['w_down'][0].reshape(4, 2, 16, 32)
def f4(wg, wu, wd):
    out, _ = M.moe_forward({'router': p1['router'], 'w_gate': wg,
                            'w_up': wu, 'w_down': wd}, x, cfg)
    return jax.lax.psum(out, 'model')
g4 = jax.jit(jax.shard_map(f4, mesh=mesh4, in_specs=(P('model'),) * 3,
                           out_specs=P(None), check_vma=False))
np.testing.assert_allclose(g4(wg4, wu4, wd4), o_ref, rtol=1e-4, atol=1e-5)
# ffn mode tp=4
cfg_f = dataclasses.replace(cfg, moe_partition='ffn')
wgf = jnp.stack(jnp.split(p1['w_gate'][0], 4, axis=-1))
wuf = jnp.stack(jnp.split(p1['w_up'][0], 4, axis=-1))
wdf = jnp.stack(jnp.split(p1['w_down'][0], 4, axis=1))
def ff(wg, wu, wd):
    out, _ = M.moe_forward({'router': p1['router'], 'w_gate': wg,
                            'w_up': wu, 'w_down': wd}, x, cfg_f)
    return jax.lax.psum(out, 'model')
gf = jax.jit(jax.shard_map(ff, mesh=mesh4, in_specs=(P('model'),) * 3,
                           out_specs=P(None), check_vma=False))
np.testing.assert_allclose(gf(wgf, wuf, wdf), o_ref, rtol=1e-4, atol=1e-5)
# ep2d on 2x2
mesh22 = jax.make_mesh((2, 2), ('data', 'model'),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg_e = dataclasses.replace(cfg, moe_partition='ep2d')
wge = jnp.stack(jnp.split(p1['w_gate'][0].reshape(2, 4, 32, 16), 2, -1), 1)
wue = jnp.stack(jnp.split(p1['w_up'][0].reshape(2, 4, 32, 16), 2, -1), 1)
wde = jnp.stack(jnp.split(p1['w_down'][0].reshape(2, 4, 16, 32), 2, 2), 1)
def fe(wg, wu, wd):
    out, _ = M.moe_forward({'router': p1['router'], 'w_gate': wg,
                            'w_up': wu, 'w_down': wd}, x, cfg_e)
    return jax.lax.psum(out, 'model')
ge = jax.jit(jax.shard_map(fe, mesh=mesh22,
                           in_specs=(P('data', 'model'),) * 3,
                           out_specs=P(None), check_vma=False))
np.testing.assert_allclose(ge(wge, wue, wde), o_ref, rtol=1e-4, atol=1e-5)
print('PASS')
""")


@pytest.mark.slow
def test_context_parallel_decode():
    """Flash-decoding combine across a context-parallel KV cache equals the
    single-shard computation."""
    run_distributed("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.base import ModelConfig
from repro.layers import attention as A
cfg = ModelConfig(name='t', family='dense', num_layers=1, d_model=32,
                  num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                  vocab_size=64, dtype='float32')
mesh = jax.make_mesh((4, 1), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
lay = A.attention_layout(1, 4, 2, 8)
params = A.init_attention_params(jax.random.PRNGKey(0), cfg, 1)
B, C = 2, 64   # global cache length; 16 slots per shard
k = jax.random.normal(jax.random.PRNGKey(1), (B, C, 2, 8))
v = jax.random.normal(jax.random.PRNGKey(2), (B, C, 2, 8))
pos = jnp.broadcast_to(jnp.arange(C)[None], (B, C)).astype(jnp.int32)
pos = jnp.where(pos < 50, pos, -1)   # 50 valid positions
x = jax.random.normal(jax.random.PRNGKey(3), (B, 1, 32))
positions = jnp.full((B, 1), 50, jnp.int32)
def single(k, v, pos):
    out, _ = A.attn_decode(params, x, {'k': k, 'v': v, 'pos': pos},
                           positions=positions, cfg=cfg, lay=lay, theta=1e4)
    return out
ref = jax.jit(jax.shard_map(
    lambda: single(k, v, pos), mesh=mesh, in_specs=(), out_specs=P(None),
    check_vma=False))()
def cp(k, v, pos):
    out, _ = A.attn_decode(params, x, {'k': k, 'v': v, 'pos': pos},
                           positions=positions, cfg=cfg, lay=lay, theta=1e4,
                           seq_axis=('data',))
    return out
got = jax.jit(jax.shard_map(
    cp, mesh=mesh, in_specs=(P(None, 'data'), P(None, 'data'),
                             P(None, 'data')),
    out_specs=P(None), check_vma=False))(k, v, pos)
np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)
print('PASS')
""", n_devices=4)
