"""scripts/check_design_refs.py — the DESIGN.md §-reference gate.

The checker must (1) resolve every ``DESIGN.md §N`` citation against real
``## §N`` headings, (2) ignore paper-section citations (bare ``§N``), and
(3) enforce that every runtime/ and core/ module docstring carries a
citation — including passing on THIS repo (the state CI gates)."""
import os
import sys
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "scripts"))
from check_design_refs import (COVERED_PACKAGES, check,  # noqa: E402
                               find_citations, module_docstring_cites,
                               parse_headings)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def cite(n):
    """Build a citation string without embedding one literally in THIS
    file (the repo-wide sweep in test_this_repo_is_clean scans tests/)."""
    return "DESIGN.md \u00a7%d" % n


DESIGN = textwrap.dedent("""\
    # DESIGN
    ## §1 Overview
    body
    ## §2 Core
    ## §12 Future
    """)


def _repo(tmp_path, design=DESIGN, files=()):
    (tmp_path / "DESIGN.md").write_text(design)
    for rel, text in files:
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    # the covered packages must exist (empty is fine for pure-resolution
    # tests that create their own)
    for pkg in COVERED_PACKAGES:
        (tmp_path / pkg).mkdir(parents=True, exist_ok=True)
    return tmp_path


def test_parse_headings_and_citations():
    assert parse_headings(DESIGN) == {1, 2, 12}
    text = ('"""Good (%s).\npaper §3.1 is NOT ours\n"""\n'
            "# %s in a comment\n") % (cite(2), cite(12))
    assert find_citations(text) == [(1, 2), (4, 12)]
    assert module_docstring_cites(text)
    assert not module_docstring_cites('"""bare §2 only."""\n')
    assert not module_docstring_cites("x = 1\n")


def test_clean_tree_passes(tmp_path):
    root = _repo(tmp_path, files=[
        ("src/repro/runtime/a.py", '"""A (%s)."""\n' % cite(1)),
        ("src/repro/core/b.py", '"""B (%s)."""\n' % cite(2)),
        ("tests/t.py", "# exercises %s\n" % cite(12)),
    ])
    assert check(root) == []


def test_unresolved_citation_fails_with_location(tmp_path):
    root = _repo(tmp_path, files=[
        ("src/repro/runtime/a.py",
         '"""A (%s)."""\nx = 1  # see %s\n' % (cite(1), cite(99))),
    ])
    fails = check(root)
    assert len(fails) == 1
    assert "a.py:2" in fails[0] and "§99" in fails[0]


def test_citation_wrapped_across_a_line_break_is_still_resolved(tmp_path):
    # docstring wrapping puts the § on the next line; the citation must
    # still reach the resolution check (regression: a line-by-line scan
    # satisfied coverage but never validated the section number)
    wrapped_bad = '"""A cites DESIGN.md\n§99 after a wrap."""\n'
    assert find_citations(wrapped_bad) == [(1, 99)]
    root = _repo(tmp_path, files=[
        ("src/repro/runtime/a.py", wrapped_bad),
    ])
    fails = check(root)
    assert len(fails) == 1
    assert "a.py:1" in fails[0] and "§99" in fails[0]


def test_paper_sections_are_not_flagged(tmp_path):
    root = _repo(tmp_path, files=[
        ("src/repro/runtime/a.py",
         '"""A (%s): implements paper §3.1 / §99."""\n' % cite(1)),
    ])
    assert check(root) == []


def test_missing_module_citation_fails(tmp_path):
    root = _repo(tmp_path, files=[
        ("src/repro/runtime/bare.py", '"""No citation here."""\n'),
        ("src/repro/core/none.py", "x = 1\n"),
        # subpackages are covered too (rglob, not a flat glob)
        ("src/repro/runtime/routers/custom.py", '"""No cite."""\n'),
    ])
    fails = check(root)
    assert len(fails) == 3
    assert any("bare.py" in f for f in fails)
    assert any("none.py" in f for f in fails)
    assert any("custom.py" in f for f in fails)


def test_citation_outside_module_docstring_does_not_satisfy_coverage(
        tmp_path):
    # a cite buried in a function docstring resolves fine but does not
    # count as the module-level map entry
    root = _repo(tmp_path, files=[
        ("src/repro/runtime/deep.py",
         'def f():\n    """%s."""\n' % cite(1)),
    ])
    fails = check(root)
    assert len(fails) == 1 and "deep.py" in fails[0]


def test_missing_design_md_reported(tmp_path):
    for pkg in ("src/repro/runtime",):
        (tmp_path / pkg).mkdir(parents=True)
    fails = check(tmp_path)
    assert fails and "DESIGN.md not found" in fails[0]


def test_this_repo_is_clean():
    assert check(__import__("pathlib").Path(REPO)) == []
