"""Per-assigned-architecture smoke tests (assignment requirement f):
instantiate the REDUCED same-family config and run one forward/train step on
CPU, asserting output shapes and finiteness. Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, PAPER_MODELS, get_config
from repro.configs.base import ParallelConfig

PCFG = ParallelConfig(tokenweave=True, comm_mode="fused", remat=False,
                      split_unit=16, tokenweave_min_tokens=32)
B, S = 2, 64


def _batch(cfg, key=0):
    tok = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                             cfg.vocab_size)
    lab = jax.random.randint(jax.random.PRNGKey(key + 1), (B, S), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": lab}
    if cfg.family == "vlm":
        batch["extra_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, 16, cfg.d_model)) * 0.02
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S + 16)[None, None], (B, 3, S + 16)).astype(jnp.int32)
        batch["labels"] = jnp.pad(lab, ((0, 0), (16, 0)))[:, :S]
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, 32, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch, mesh11):
    from repro.models.build import build_model
    cfg = get_config(arch).reduced()
    api = build_model(cfg, PCFG, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(params, batch):
        ls, dn, aux = api.train_loss(params, batch)
        return ls / jnp.maximum(dn, 1)

    f = jax.jit(jax.shard_map(loss_fn, mesh=mesh11,
                              in_specs=(api.specs(), P()), out_specs=P(),
                              check_vma=False))
    loss = float(f(params, batch))
    assert np.isfinite(loss)
    # random init, uniform-ish prediction: loss near log(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < loss < 2.5 * np.log(cfg.vocab_size)

    from repro import compat
    if cfg.is_moe and not compat.HAS_VMA:
        pytest.skip("pre-VMA shard_map mis-stages scalar residuals when "
                    "transposing the MoE aux path (_SpecError); loss "
                    "forward above is still asserted")

    # gradient step sanity: grads exist and are finite
    g = jax.jit(jax.grad(lambda p: jax.shard_map(
        loss_fn, mesh=mesh11, in_specs=(api.specs(), P()), out_specs=P(),
        check_vma=False)(p, batch)))(params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat)


@pytest.mark.parametrize("arch", PAPER_MODELS)
def test_paper_model_reduced_forward(arch, mesh11):
    from repro.models.build import build_model
    cfg = get_config(arch).reduced()
    api = build_model(cfg, PCFG, tp=1)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(params, batch):
        ls, dn, _ = api.train_loss(params, batch)
        return ls / jnp.maximum(dn, 1)

    f = jax.jit(jax.shard_map(loss_fn, mesh=mesh11,
                              in_specs=(api.specs(), P()), out_specs=P(),
                              check_vma=False))
    assert np.isfinite(float(f(params, batch)))


def test_gemma3_local_global_pattern():
    from repro.models.transformer import layer_kinds, uniform_kinds
    cfg = get_config("gemma3-1b")
    kinds = layer_kinds(cfg)
    assert len(kinds) == 26
    assert not uniform_kinds(cfg)
    globals_ = [i for i, k in enumerate(kinds) if k.window == 0]
    assert globals_ == [5, 11, 17, 23]            # 5:1 local:global
    assert all(kinds[i].window == 512 for i in range(5))
    assert kinds[5].theta == 1_000_000.0
    assert kinds[0].theta == 10_000.0


def test_tokenweave_equivalence_dense(tiny_cfg, mesh11):
    """Two-split weave == unsplit forward, exactly (same params/batch)."""
    import dataclasses
    from repro.models import transformer as T
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 128)
    outs = {}
    for weave in (False, True):
        pcfg = dataclasses.replace(PCFG, tokenweave=weave)
        params = T.init_params(jax.random.PRNGKey(0), tiny_cfg, pcfg, 1)

        def f(params):
            h, _, _ = T.forward(params, tok, cfg=tiny_cfg, pcfg=pcfg,
                                return_kv=False)
            return h
        outs[weave] = jax.jit(jax.shard_map(
            f, mesh=mesh11, in_specs=(T.param_specs(tiny_cfg, pcfg),),
            out_specs=P(), check_vma=False))(params)
    np.testing.assert_allclose(outs[False], outs[True], rtol=2e-5,
                               atol=2e-5)
