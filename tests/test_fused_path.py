"""Fused AllReduce-RMSNorm serving hot path, pinned fused-vs-ref
(DESIGN.md §2 ring mode; ISSUE 9).

``comm_norm(mode="ring")`` dispatches the single-kernel ring
AllReduce-RMSNorm (kernels/ring_ar_rmsnorm.py) wherever the backend can
run it, and walks a fallback ladder (ring -> fused composition ->
vanilla for ragged shards) everywhere else.  This tier pins the MODE —
not one rung — against ``kernels/ref.ring_ar_rmsnorm_ref`` and the
unfused vanilla composition, so the numerics hold identically whichever
rung fires (on jax < 0.5 CPU the interpret gate takes the composition;
on newer backends the same tests drive the real kernel).

Also here: the fault-injection half of the tier — a planted
wrong-chunk-ownership ring schedule must be caught by the numerics pin,
and a budget-overcommitting plan entry (a budget that rounds to zero
ring lanes) must be caught by scripts/check_plan.py — plus the
engine-level integration: loading the committed fused plan changes no
token and surfaces per-site ``engine/site_fused_rate`` gauges.
"""
import dataclasses
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import run_distributed
from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import fused_collectives as fc
from repro.core.splitting import MAX_RING_CHANNELS, ring_channels
from repro.distributed.context import CommCtx
from repro.kernels import ref as KREF
from repro.kernels.ref import ring_ar_rmsnorm_ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_PLAN = os.path.join(REPO, "benchmarks", "plans", "default.json")

_CHECK_PLAN = os.path.join(REPO, "scripts", "check_plan.py")
_spec = importlib.util.spec_from_file_location("check_plan", _CHECK_PLAN)
check_plan = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_plan)


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _comm_norm_tp1(mode, x, res, w, *, budget=1.0, use_pallas=True):
    """Run comm_norm on the 1-device mesh (the exact hot-path call)."""
    ctx = CommCtx(mode=mode, use_pallas=use_pallas, interpret=use_pallas,
                  comm_budget=budget)

    def f(xsh, r):
        return fc.comm_norm(xsh[0], r, w, ctx=ctx)

    g = jax.jit(jax.shard_map(f, mesh=_mesh11(),
                              in_specs=(P("model"), P("model")),
                              out_specs=(P(None), P("model")),
                              check_vma=False))
    return g(x[None], res)


def _check_ring_vs_ref_tp1(t, d, dtype, *, budget=1.0, tol=None):
    key = jax.random.PRNGKey(t * 1000 + d)
    x = jax.random.normal(key, (t, d), dtype)
    res = jax.random.normal(jax.random.PRNGKey(1), (t, d), dtype)
    w = (jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (d,))) + 0.5
         ).astype(dtype)
    o_ring, r_ring = _comm_norm_tp1("ring", x, res, w, budget=budget)
    o_van, r_van = _comm_norm_tp1("vanilla", x, res, w, use_pallas=False)
    ref_outs, ref_res = ring_ar_rmsnorm_ref([x], [res], w)
    tol = tol if tol is not None else (1e-5 if dtype == jnp.float32
                                       else 3e-2)
    for got, want in ((o_ring, ref_outs[0]), (o_ring, o_van),
                      (r_ring, ref_res[0]), (r_ring, r_van)):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


# --------------------------------------------------------------------------
# ring-mode comm_norm vs ref oracle vs vanilla composition (tp=1,
# in-process — ragged token counts are legal at tp=1 and must still pin)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d", [(16, 32), (48, 64), (33, 128), (7, 256)])
def test_comm_norm_ring_matches_ref_and_vanilla_tp1(t, d, dtype):
    _check_ring_vs_ref_tp1(t, d, dtype)


@pytest.mark.parametrize("budget", [0.125, 0.5, 1.0])
def test_comm_norm_ring_budget_does_not_change_numerics(budget):
    """The ring-lane grant is a RESOURCE knob; any budget in (0, 1] must
    produce bit-compatible results (only throughput may differ)."""
    _check_ring_vs_ref_tp1(32, 64, jnp.float32, budget=budget)


# --------------------------------------------------------------------------
# the ragged fallback edge: t_local % tp != 0 gates ring -> vanilla
# --------------------------------------------------------------------------

def test_comm_ctx_ragged_falls_back_to_vanilla():
    from repro.models.transformer import _comm_ctx
    cfg = ModelConfig(name="tiny", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32")
    pcfg = ParallelConfig()
    # divisible: the plan-forced ring mode goes through, budget and all
    ctx = _comm_ctx(pcfg, cfg, 32, 4, mode="ring", budget=0.5)
    assert ctx.mode == "ring" and ctx.comm_budget == 0.5
    # ragged (t_local % tp != 0): token-sharded layouts are impossible
    assert _comm_ctx(pcfg, cfg, 33, 4, mode="ring").mode == "vanilla"
    # degenerate (t_local < tp): same fallback
    assert _comm_ctx(pcfg, cfg, 3, 4, mode="ring").mode == "vanilla"
    # no plan override: pcfg.comm_mode rules, as before
    assert _comm_ctx(pcfg, cfg, 32, 4).mode == pcfg.comm_mode


# --------------------------------------------------------------------------
# multi-device: ring-mode comm_norm vs the ref oracle and the unfused
# vanilla composition (tp in {2, 4}, subprocess devices)
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("tp", [2, 4])
def test_comm_norm_ring_mode_multidevice(tp):
    run_distributed(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.context import CommCtx
from repro.core import fused_collectives as fc
from repro.kernels.ref import ring_ar_rmsnorm_ref
tp, T, d = {tp}, 48, 32
mesh = jax.make_mesh((1, tp), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
for dtype, tol in ((jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)):
    xs = jax.random.normal(jax.random.PRNGKey(0), (tp, T, d), dtype)
    res = jax.random.normal(jax.random.PRNGKey(3), (T, d), dtype)
    w = (jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (d,))) + 0.5
         ).astype(dtype)
    def run(mode, use_pallas):
        ctx = CommCtx(mode=mode, use_pallas=use_pallas,
                      interpret=use_pallas, comm_budget=0.5)
        sharded = mode != 'vanilla'
        def f(xsh, r):
            return fc.comm_norm(xsh[0], r if sharded else r[0], w, ctx=ctx)
        res_in = res if sharded else jnp.broadcast_to(res[None], (tp, T, d))
        g = jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=(P('model'), P('model')),
            out_specs=(P(None), P('model') if sharded else P(None)),
            check_vma=False))
        return g(xs, res_in)
    o_ring, r_ring = run('ring', True)
    o_van, r_van = run('vanilla', False)
    ref_outs, ref_res = ring_ar_rmsnorm_ref(
        [xs[i] for i in range(tp)],
        [res.reshape(tp, T // tp, d)[i] for i in range(tp)], w)
    np.testing.assert_allclose(np.asarray(o_ring, np.float32),
                               np.asarray(ref_outs[0], np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(o_ring, np.float32),
                               np.asarray(o_van, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(
        np.asarray(r_ring.reshape(T, d), np.float32),
        np.asarray(jnp.concatenate(ref_res, 0), np.float32),
        rtol=tol, atol=tol)
print('PASS')
""", n_devices=tp)


# --------------------------------------------------------------------------
# property sweep over tile shapes (hypothesis when available, the
# deterministic grid otherwise — never a skip)
# --------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @given(t=st.integers(1, 64), d=st.sampled_from((32, 64, 128, 256)),
           bf16=st.booleans())
    @settings(max_examples=25, deadline=None)
    def test_ring_comm_norm_tile_shape_sweep(t, d, bf16):
        _check_ring_vs_ref_tp1(t, d,
                               jnp.bfloat16 if bf16 else jnp.float32)
else:
    @pytest.mark.parametrize("t,d,bf16", [
        (1, 32, False), (7, 64, True), (16, 128, False), (33, 64, False),
        (56, 32, True), (64, 256, False)])
    def test_ring_comm_norm_tile_shape_sweep(t, d, bf16):
        _check_ring_vs_ref_tp1(t, d,
                               jnp.bfloat16 if bf16 else jnp.float32)


# --------------------------------------------------------------------------
# fault injection: the tier must CATCH a broken fused path, not just pass
# --------------------------------------------------------------------------

def test_numerics_pin_catches_wrong_chunk_ownership(monkeypatch):
    """Planted fault: a ring schedule whose devices norm the WRONG token
    chunk (ownership rotated by one).  Every chunk is still normed by
    exactly one device — shapes, reductions, and semaphore accounting all
    stay healthy — so only the numerics pin can catch it."""
    n, t, d = 4, 32, 64
    xs = [jax.random.normal(jax.random.PRNGKey(i), (t, d)) for i in range(n)]
    res = [jax.random.normal(jax.random.PRNGKey(10 + i), (t // n, d))
           for i in range(n)]
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(99), (d,))) + 0.5
    good, good_res = ring_ar_rmsnorm_ref(xs, res, w)

    monkeypatch.setattr(KREF, "_chunk_owner", lambda r, nd: (r + 1) % nd)
    bad, bad_res = ring_ar_rmsnorm_ref(xs, res, w)

    # the planted schedule still "works" structurally...
    assert bad[0].shape == good[0].shape
    # ...but the full normed stream disagrees with the true composition
    assert not np.allclose(np.asarray(bad[0]), np.asarray(good[0]),
                           rtol=1e-3, atol=1e-3)
    # and the residual shards each device keeps are the wrong tokens'
    assert not all(np.allclose(np.asarray(b), np.asarray(g), rtol=1e-3,
                               atol=1e-3)
                   for b, g in zip(bad_res, good_res))


def test_check_plan_rejects_budget_overcommit():
    """Planted fault: a fused plan entry whose budget rounds to ZERO ring
    lanes (an over-committed comm grant the kernel could never honor).
    ``PlanEntry.validate`` accepts any budget in (0, 1], so only the
    check_plan structural gate stands between this entry and the engine."""
    with open(DEFAULT_PLAN) as f:
        doc = json.load(f)
    assert check_plan.check_plan(doc) == []      # the committed plan is clean
    fused_idx = next(i for i, e in enumerate(doc["entries"])
                     if e["method"] in ("fused", "fused-unsplit"))
    bad = json.loads(json.dumps(doc))
    bad["entries"][fused_idx]["budget"] = 0.05   # ring_channels -> 0 lanes
    assert ring_channels(0.05) == 0
    failures = check_plan.check_plan(bad)
    assert failures and any("ring lanes" in f for f in failures)


def test_ring_channels_budget_mapping():
    """budget -> lane-count contract (the paper's 2-8 SM knob)."""
    assert ring_channels(1.0) == MAX_RING_CHANNELS
    assert ring_channels(0.5) == MAX_RING_CHANNELS // 2
    assert ring_channels(1.0 / MAX_RING_CHANNELS) == 1
    assert ring_channels(0.05) == 0      # deliberately NOT clamped: the
    #                                      plan gate must see the fault


# --------------------------------------------------------------------------
# engine integration: the committed fused plan is dispatchable end-to-end
# --------------------------------------------------------------------------

def test_engine_fused_plan_token_identity_and_fused_rate(
        tiny_engine_builder):
    """Loading the committed plan (whose tiny/tp1 entries are all
    fused/fused-unsplit) must change NO token vs the plan-free engine —
    the fallback ladder lands on numerically-identical rungs — while the
    per-site ``engine/site_fused_rate`` gauges surface that the fused
    path was selected at every decided site."""
    from repro.runtime.requests import Request

    def run(plan_path):
        eng = tiny_engine_builder(paged=True, packed=True,
                                  plan_path=plan_path)
        for i in range(3):
            # ragged prompt lengths: some forwards hit the t % tp edge
            eng.add_request(Request(rid=i, prompt=list(range(19 + 7 * i)),
                                    max_new_tokens=6))
        eng.run()
        outs = {r.rid: r.output for r in eng.sched.finished}
        return outs, eng.metrics_snapshot()

    base_outs, _ = run(None)
    plan_outs, snap = run(DEFAULT_PLAN)
    assert plan_outs == base_outs
    rates = {k: v for k, v in snap.items()
             if k.startswith("engine/site_fused_rate")}
    assert rates, f"no site_fused_rate gauges in {sorted(snap)}"
    assert all(v == 1.0 for v in rates.values()), rates
    # and without a plan there is no fused routing at all (the derived
    # rate gauges exist either way; they must read zero)
    _, base_snap = run(None)
    base_rates = {k: v for k, v in base_snap.items()
                  if k.startswith("engine/site_fused_rate")}
    assert all(v == 0.0 for v in base_rates.values()), base_rates
