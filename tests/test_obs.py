"""Observability subsystem (src/repro/obs, DESIGN.md §12).

Covers the typed metrics registry (get-or-create, kind collisions,
snapshot flattening), the trace recorder and Chrome-trace export (span
nesting, per-track virtual-clock monotonicity, the validator actually
catching broken traces), the request-lifecycle invariant (exactly one
terminal event per admitted request — including a cancel landing while
the KV is mid-migration between replicas), and the two hard §12
invariants on the randomized differential corpus: tracing on vs off is
token- and step-count-IDENTICAL, and the weave rate recomputed from the
trace's per-forward attribution records equals ``EngineStats.weave_rate``
exactly.
"""
import numpy as np
import pytest

from repro.obs import (MetricsRegistry, TERMINAL_PHASES, TraceRecorder,
                       export_chrome_trace, percentile,
                       validate_chrome_trace, weave_counts_from_trace)
from repro.runtime.requests import Request, poisson_arrivals

from test_differential import _gen_trace, _drive


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_registry_get_or_create_and_kinds():
    reg = MetricsRegistry()
    c = reg.counter("engine/steps")
    assert reg.counter("engine/steps") is c
    c.inc()
    c.inc(3)
    assert c.value == 4
    g = reg.gauge("engine/weave_rate")
    g.set(0.25)
    g.set_max(0.1)           # running max keeps the larger value
    assert g.value == 0.25
    g.set_max(0.5)
    assert g.value == 0.5
    h = reg.histogram("latency/ttft")
    for v in (3.0, 1.0, 2.0):
        h.observe(v)
    assert h.count == 3 and h.total == 6.0
    assert h.percentile(0.5) == 2.0


def test_registry_kind_collision_is_typeerror():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError, match="counter"):
        reg.gauge("x")


def test_counter_rejects_negative():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)


def test_registry_snapshot_flattening_and_labels():
    reg = MetricsRegistry()
    reg.counter("engine/steps").inc(7)
    reg.counter("engine/steps", replica="d0").inc(2)
    reg.histogram("latency/e2e").observe(4.0)
    snap = reg.snapshot()
    assert snap["engine/steps"] == 7.0
    assert snap["engine/steps{replica=d0}"] == 2.0
    assert snap["latency/e2e/count"] == 1.0
    assert snap["latency/e2e/p50"] == 4.0
    assert "latency/e2e/p90" in snap and "latency/e2e/p99" in snap


def test_percentile_matches_linear_interpolation():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0.5) == 2.5
    assert percentile(xs, 0.0) == 1.0
    assert percentile(xs, 1.0) == 4.0
    assert percentile([], 0.5) == 0.0


# --------------------------------------------------------------------------
# recorder + export + validator (hand-built traces)
# --------------------------------------------------------------------------

def _forward_args(**over):
    a = dict(kind="prefill", weave=True, reason="split", tokens=64,
             tokens_real=64, threshold=32, split=[32, 32],
             method="tokenweave", plan_id=0, bucket="64-127",
             est_compute=1.0, est_comm=0.5, est_overlapped=0.4)
    a.update(over)
    return a


def test_span_nesting_valid_trace():
    rec = TraceRecorder()
    rec.complete("eng", "step/packed", 0.0, 2.0, cat="step",
                 args={"step": 0, "forwards": 1})
    rec.complete("eng", "forward/packed", 0.0, 2.0, cat="forward",
                 args=_forward_args())
    rec.request_event(1, "queued", ts=0.0)
    rec.request_event(1, "admit", ts=0.5)
    rec.request_event(1, "finish", ts=2.0)
    assert validate_chrome_trace(export_chrome_trace(rec)) == []


def test_validator_catches_forward_escaping_its_step():
    rec = TraceRecorder()
    rec.complete("eng", "step/packed", 0.0, 1.0, cat="step")
    rec.complete("eng", "forward/packed", 0.5, 2.0, cat="forward",
                 args=_forward_args())
    fails = validate_chrome_trace(export_chrome_trace(rec))
    assert any("step" in f for f in fails)


def test_validator_catches_backwards_timestamps():
    rec = TraceRecorder()
    rec.complete("eng", "step/a", 5.0, 1.0, cat="step")
    rec.complete("eng", "step/b", 1.0, 1.0, cat="step")
    fails = validate_chrome_trace(export_chrome_trace(rec))
    assert any("backwards" in f for f in fails)


def test_validator_requires_attribution_keys():
    rec = TraceRecorder()
    a = _forward_args()
    del a["est_overlapped"]
    rec.complete("eng", "step/packed", 0.0, 1.0, cat="step")
    rec.complete("eng", "forward/packed", 0.0, 1.0, cat="forward", args=a)
    fails = validate_chrome_trace(export_chrome_trace(rec))
    assert any("est_overlapped" in f for f in fails)


def test_validator_catches_missing_terminal_for_admitted_request():
    rec = TraceRecorder()
    rec.request_event(3, "queued", ts=0.0)
    rec.request_event(3, "admit", ts=1.0)      # admitted, never finished
    fails = validate_chrome_trace(export_chrome_trace(rec))
    assert any("terminal" in f.lower() for f in fails)


def test_validator_catches_double_terminal():
    rec = TraceRecorder()
    rec.request_event(3, "queued", ts=0.0)
    rec.request_event(3, "admit", ts=1.0)
    rec.request_event(3, "finish", ts=2.0)
    rec.request_event(3, "cancel", ts=3.0)
    fails = validate_chrome_trace(export_chrome_trace(rec))
    assert any("terminal" in f.lower() for f in fails)


def test_export_merges_recorders_with_distinct_namespaces():
    a = TraceRecorder(request_ns="a/")
    b = TraceRecorder(request_ns="b/")
    for rec in (a, b):
        rec.request_event(0, "queued", ts=0.0)
        rec.request_event(0, "admit", ts=0.0)
        rec.request_event(0, "finish", ts=1.0)
    doc = export_chrome_trace([a, b])
    assert validate_chrome_trace(doc) == []
    names = {ev["args"]["name"] for ev in doc["traceEvents"]
             if ev.get("ph") == "M" and ev["name"] == "thread_name"}
    assert {"req a/0", "req b/0"} <= names


# --------------------------------------------------------------------------
# engine integration: zero-cost-off, lifecycle, weave attribution
# --------------------------------------------------------------------------

def test_tracing_is_off_by_default(tiny_engine_builder):
    eng = tiny_engine_builder(paged=True)
    assert eng.obs is None and eng._attributor is None
    eng.add_request(Request(rid=0, prompt=list(range(1, 9)),
                            max_new_tokens=3))
    eng.run()
    assert eng.stats.completed == 1      # stats work without a recorder


def test_offline_engine_trace_validates_and_attributes_every_forward(
        tiny_engine_builder):
    rec = TraceRecorder()
    eng = tiny_engine_builder(paged=True, packed=True, spec_gamma=2,
                              obs=rec)
    for i in range(3):
        eng.add_request(Request(rid=i, prompt=list(range(1, 20 + i)),
                                max_new_tokens=4))
    eng.run()
    doc = export_chrome_trace(rec)
    assert validate_chrome_trace(doc) == []
    w, n = weave_counts_from_trace(rec)
    assert n == eng.stats.forwards > 0
    assert w == eng.stats.weave_forwards
    # one step span per engine step
    steps = [ev for ev in rec.events
             if ev["kind"] == "span" and ev["cat"] == "step"]
    assert len(steps) == eng.stats.steps


def test_online_server_lifecycle_expiry_and_monotonic_clock(
        tiny_engine_builder):
    from repro.runtime.server import OnlineServer, ServerConfig, StepCost
    rec = TraceRecorder(request_ns="online/")
    eng = tiny_engine_builder(paged=True, packed=True, obs=rec)
    srv = OnlineServer(eng, ServerConfig(
        step_cost=StepCost(base=1.0, per_token=0.05),
        expire_on_deadline=True))
    rng = np.random.RandomState(4)
    reqs = [Request(rid=i,
                    prompt=list(rng.randint(0, 128, size=rng.randint(8, 30))),
                    max_new_tokens=6) for i in range(6)]
    for r in poisson_arrivals(reqs, rate=0.4, seed=9):
        r.deadline = r.arrival_time + 5.0    # tight: some expire
        srv.submit(r)
    srv.run()
    assert eng.stats.expired > 0, "deadline chosen to force expiry"
    doc = export_chrome_trace(rec)
    assert validate_chrome_trace(doc) == []
    # exactly one terminal event per request, arrival stamped at
    # arrival_time on the virtual clock
    by_rid = {}
    for ev in rec.events:
        if ev["kind"] == "request":
            by_rid.setdefault(ev["rid"], []).append(ev)
    assert len(by_rid) == 6
    for rid, evs in by_rid.items():
        terms = [e for e in evs if e["phase"] in TERMINAL_PHASES]
        assert len(terms) == 1, (rid, [e["phase"] for e in evs])
    arr = {ev["rid"]: ev["ts"] for ev in rec.events
           if ev["kind"] == "request" and ev["phase"] == "arrival"}
    for r in reqs:
        assert arr[f"online/{r.rid}"] == r.arrival_time


def test_cancel_mid_migration_emits_exactly_one_terminal(tiny_model):
    from repro.runtime.cluster import (ClusterConfig, ClusterServer,
                                       MigrationCost, Replica)
    from repro.runtime.engine import Engine
    from repro.runtime.scheduler import SchedulerConfig

    api, mesh, params = tiny_model
    rec = TraceRecorder(request_ns="cl/")

    def engine():
        return Engine(api, mesh, params,
                      SchedulerConfig(max_batch=4, chunk_tokens=48,
                                      max_len=96, prefill_bucket=16,
                                      paged=True, block_size=8),
                      obs=rec)

    reps = [Replica("p0", engine(), role="prefill"),
            Replica("d0", engine(), role="decode")]
    cs = ClusterServer(reps, ClusterConfig(
        router="round_robin",
        migration_cost=MigrationCost(base=1000.0)))
    req = Request(rid=0, prompt=list(range(1, 21)), max_new_tokens=8)
    req.arrival_time = 0.0
    cs.submit(req)
    cs.cancel(0, at=50.0)       # lands while the KV is "on the wire"
    assert cs.run() == [] and req.finish_reason == "cancelled"

    phases = [ev["phase"] for ev in rec.events if ev["kind"] == "request"]
    assert "handoff_export" in phases, "prefill side must park the handoff"
    assert phases.count("cancel") == 1
    assert sum(phases.count(p) for p in TERMINAL_PHASES) == 1
    assert validate_chrome_trace(export_chrome_trace(rec)) == []
    # replica tracks were renamed from the default
    tracks = {ev["track"] for ev in rec.events if ev["kind"] == "span"}
    assert tracks <= {"p0", "d0"}


# --------------------------------------------------------------------------
# the two hard §12 invariants, on the randomized differential corpus
# --------------------------------------------------------------------------

@pytest.mark.parametrize("trial", range(25))
def test_corpus_identity_and_trace_weave_rate(trial, tiny_engine_builder):
    """Tracing ON vs OFF must be token- and step-count-identical, and the
    weave rate recomputed from the trace's per-forward attribution spans
    must equal ``EngineStats.weave_rate`` EXACTLY — over the same 25
    seeded workloads (mixed prefill, prefix sharing, spec windows,
    mid-flight cancels) the differential harness replays."""
    rng = np.random.RandomState(1000 + trial)
    prompts, outs, gamma, cancels = _gen_trace(rng)
    kw = dict(max_batch=3, chunk_tokens=48, max_len=128, prefill_bucket=16,
              block_size=16, spec_gamma=gamma, paged=True, packed=True)

    eng_off = tiny_engine_builder(**kw)
    off = _drive(eng_off, prompts, outs, cancels)

    rec = TraceRecorder()
    eng_on = tiny_engine_builder(**kw, obs=rec)
    on = _drive(eng_on, prompts, outs, cancels)

    assert on == off, (trial, gamma, cancels)
    assert eng_on.stats.steps == eng_off.stats.steps
    assert eng_on.stats.forwards == eng_off.stats.forwards

    w, n = weave_counts_from_trace(rec)
    assert (w, n) == (eng_on.stats.weave_forwards, eng_on.stats.forwards)
    rate = w / n if n else 0.0
    assert rate == eng_on.stats.weave_rate
    # every forward carries a full attribution record (validator enforces
    # the required keys) and the whole export is schema-clean
    assert validate_chrome_trace(export_chrome_trace(rec)) == []
