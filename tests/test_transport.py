"""Wire transport (runtime/transport.py, DESIGN.md §15).

Codec round-trip bit-identity over the value types the serving layer
ships (scalars, envelopes, ragged KV payload trees in every dtype),
rejection of truncated/corrupted/version-skewed frames, the loopback
transport's accounting, and the socket framing (asyncio host + blocking
client sharing one codec).  The multi-process EngineHost/RemoteEngine
path is exercised end-to-end (with fault injection) in tests/
test_cluster.py.
"""
import itertools
import threading

import numpy as np
import pytest

from repro.runtime.requests import Request, State
from repro.runtime.transport import (DEFAULT_SPEC, LoopbackTransport,
                                     MAGIC, ReplicaGone, TransportError,
                                     WIRE_VERSION, decode_frame,
                                     encode_frame, handoff_from_wire,
                                     handoff_to_wire, request_from_wire,
                                     request_to_wire)
from repro.runtime.engine import Handoff


def _assert_same(a, b):
    """Structural equality with BIT-identical arrays."""
    assert type(a) is type(b) or (isinstance(a, list) and isinstance(b, list))
    if isinstance(a, dict):
        assert sorted(a) == sorted(b)
        for k in a:
            _assert_same(a[k], b[k])
    elif isinstance(a, np.ndarray):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()
    elif isinstance(a, list):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            _assert_same(x, y)
    else:
        assert a == b and type(a) is type(b)


# --------------------------------------------------------------------------
# deterministic round-trip grid
# --------------------------------------------------------------------------

_DTYPES = ("float32", "float16", "float64", "int8", "int32", "int64",
           "uint8", "bool")
_SHAPES = ((), (1,), (3,), (2, 5), (2, 3, 4, 2), (4, 0, 2))


def _arr(dtype, shape, seed):
    rng = np.random.RandomState(seed)
    n = int(np.prod(shape, dtype=np.int64))
    if dtype == "bool":
        flat = rng.rand(n) > 0.5
    elif np.issubdtype(np.dtype(dtype), np.floating):
        flat = rng.randn(n)
    else:
        info = np.iinfo(dtype)
        flat = rng.randint(info.min, info.max, size=n, dtype=dtype)
    return flat.astype(dtype).reshape(shape)


@pytest.mark.parametrize("dtype,shape",
                         list(itertools.product(_DTYPES, _SHAPES)))
def test_array_roundtrip_bit_identical(dtype, shape):
    arr = _arr(dtype, shape, seed=hash((dtype, shape)) % 1000)
    kind, got = decode_frame(encode_frame("blob", arr))
    assert kind == "blob"
    _assert_same(arr, got)


def test_scalar_and_container_roundtrip():
    obj = {"none": None, "t": True, "f": False, "i": -17, "big": 1 << 40,
           "d": 3.25, "s": "héllo", "b": b"\x00\xffraw", "empty": [],
           "nested": {"xs": [1, 2.5, "three", None, {"deep": [True]}]}}
    kind, got = decode_frame(encode_frame("env", obj))
    assert kind == "env"
    _assert_same(obj, got)


def test_noncontiguous_array_roundtrips():
    base = np.arange(24, dtype=np.float32).reshape(4, 6)
    view = base[::2, 1::2]                       # strided, non-contiguous
    _, got = decode_frame(encode_frame("x", view))
    _assert_same(np.ascontiguousarray(view), got)


def test_stacked_and_per_layer_payload_trees_roundtrip():
    rng = np.random.RandomState(0)
    stacked = {"k": rng.randn(2, 3, 8, 2, 16).astype(np.float32),
               "v": rng.randn(2, 3, 8, 2, 16).astype(np.float32),
               "pos": rng.randint(0, 96, size=(2, 3, 8)).astype(np.int32)}
    per_layer = {f"layer_{i}":
                 {"k": rng.randn(3, 8, 2, 16).astype(np.float16),
                  "v": rng.randn(3, 8, 2, 16).astype(np.float16),
                  "pos": rng.randint(0, 96, size=(3, 8)).astype(np.int32)}
                 for i in range(2)}
    for payload in (stacked, per_layer):
        _, got = decode_frame(encode_frame("handoff", payload))
        _assert_same(payload, got)


def test_unencodable_values_raise():
    with pytest.raises(TypeError, match="cannot encode"):
        encode_frame("x", object())
    with pytest.raises(TypeError, match="keys must be str"):
        encode_frame("x", {1: "int key"})
    with pytest.raises(ValueError, match="kind too long"):
        encode_frame("k" * 256, None)


# --------------------------------------------------------------------------
# malformed frames must raise, never mis-decode
# --------------------------------------------------------------------------

def _sample_frame():
    return encode_frame("env", {"xs": [1, 2, 3], "arr":
                                np.arange(6, dtype=np.int32)})


def test_every_truncation_raises():
    frame = _sample_frame()
    for n in range(len(frame)):
        with pytest.raises(TransportError):
            decode_frame(frame[:n])


def test_every_single_byte_corruption_raises_or_roundtrips_crc():
    # flipping any byte must be DETECTED: header fields fail their own
    # checks, body bytes fail the CRC, CRC bytes mismatch the body
    frame = bytearray(_sample_frame())
    for i in range(len(frame)):
        bad = bytearray(frame)
        bad[i] ^= 0xFF
        with pytest.raises(TransportError):
            decode_frame(bytes(bad))


def test_trailing_garbage_raises():
    with pytest.raises(TransportError, match="length mismatch"):
        decode_frame(_sample_frame() + b"x")


def test_version_skew_raises():
    import struct
    frame = bytearray(_sample_frame())
    struct.pack_into("!H", frame, 4, WIRE_VERSION + 1)
    with pytest.raises(TransportError, match="wire version"):
        decode_frame(bytes(frame))


def test_bad_magic_raises():
    frame = bytearray(_sample_frame())
    frame[:4] = b"NOPE"
    with pytest.raises(TransportError, match="magic"):
        decode_frame(bytes(frame))
    assert bytes(_sample_frame()[:4]) == MAGIC


def test_hostile_length_fields_never_overallocate():
    # a corrupted inner length field must be caught by bounds checks, not
    # trusted into a giant allocation
    frame = encode_frame("s", "abc")
    idx = frame.index(b"S") + 1                  # the string length u32
    bad = frame[:idx] + b"\x7f\xff\xff\xff" + frame[idx + 4:]
    with pytest.raises(TransportError):
        decode_frame(bad)


# --------------------------------------------------------------------------
# property test: arbitrary nested values round-trip.  With hypothesis
# installed the search is adversarial; without it a seeded deterministic
# grid over the same value space runs instead (NO skip — the skip-count
# ceiling in CI stays at the seed's capability skips).
# --------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _random_value(rng, depth=0):
    """Seeded generator over the codec's whole value space (the
    deterministic twin of the hypothesis strategy below)."""
    kinds = ["none", "bool", "int", "float", "str", "bytes", "arr"]
    if depth < 3:
        kinds += ["list", "dict", "list", "dict"]
    kind = kinds[rng.randint(len(kinds))]
    if kind == "none":
        return None
    if kind == "bool":
        return bool(rng.randint(2))
    if kind == "int":
        return int(rng.randint(-(1 << 62), 1 << 62, dtype=np.int64))
    if kind == "float":
        return float(rng.randn() * 10.0 ** rng.randint(-10, 10))
    if kind == "str":
        return "".join(chr(rng.randint(1, 0x300))
                       for _ in range(rng.randint(0, 20)))
    if kind == "bytes":
        return rng.bytes(rng.randint(0, 32))
    if kind == "arr":
        shape = tuple(rng.randint(0, 5)
                      for _ in range(rng.randint(0, 4)))
        return _arr(_DTYPES[rng.randint(len(_DTYPES))], shape,
                    seed=rng.randint(1000))
    if kind == "list":
        return [_random_value(rng, depth + 1)
                for _ in range(rng.randint(0, 5))]
    return {f"k{i}": _random_value(rng, depth + 1)
            for i in range(rng.randint(0, 5))}


def _check_roundtrip(obj, kind):
    got_kind, got = decode_frame(encode_frame(kind, obj))
    assert got_kind == kind
    _assert_same(obj, got)


if HAVE_HYPOTHESIS:
    def _values():
        scalars = st.one_of(
            st.none(), st.booleans(),
            st.integers(min_value=-(1 << 62), max_value=1 << 62),
            st.floats(allow_nan=False, width=64), st.text(max_size=20),
            st.binary(max_size=32),
            st.integers(0, 3).flatmap(lambda nd: st.tuples(
                st.sampled_from(_DTYPES),
                st.lists(st.integers(0, 4), min_size=nd, max_size=nd),
                st.integers(0, 999)).map(
                    lambda t: _arr(t[0], tuple(t[1]), t[2]))))
        return st.recursive(
            scalars,
            lambda kids: st.one_of(
                st.lists(kids, max_size=4),
                st.dictionaries(st.text(max_size=8), kids, max_size=4)),
            max_leaves=12)

    @given(obj=_values(), kind=st.text(min_size=1, max_size=32))
    @settings(max_examples=150, deadline=None)
    def test_roundtrip_property(obj, kind):
        _check_roundtrip(obj, kind)
else:
    @pytest.mark.parametrize("seed", range(60))
    def test_roundtrip_property(seed):
        rng = np.random.RandomState(seed)
        for _ in range(5):
            _check_roundtrip(_random_value(rng), f"kind{seed}")


# --------------------------------------------------------------------------
# request / handoff envelopes
# --------------------------------------------------------------------------

def test_request_envelope_roundtrips_every_field():
    req = Request(rid=42, prompt=[1, 2, 3], max_new_tokens=8)
    req.state = State.DECODE
    req.output = [9, 8]
    req.prefill_pos = 3
    req.resumed = True
    req.preemptions = 2
    req.prompt_hit_tokens = 1
    req.handoff_after_prefill = True
    req.migrations = 1
    req.requeues = 3
    req.arrival_time = 1.5
    req.deadline = 99.0
    req.admit_time = 2.0
    req.first_token_time = 4.5
    req.finish_reason = ""
    got = request_from_wire(
        decode_frame(encode_frame("req", request_to_wire(req)))[1])
    for f in ("rid", "prompt", "max_new_tokens", "state", "output",
              "prefill_pos", "resumed", "preemptions", "prompt_hit_tokens",
              "handoff_after_prefill", "migrations", "requeues",
              "arrival_time", "deadline", "admit_time", "first_token_time",
              "finish_time", "finish_reason"):
        assert getattr(got, f) == getattr(req, f), f
    assert got.slot is None                      # placement never ships


def test_handoff_envelope_preserves_identity_and_payload():
    req = Request(rid=7, prompt=[5, 6], max_new_tokens=4)
    payload = {"k": np.random.RandomState(1).randn(2, 1, 8, 2, 16)
               .astype(np.float32)}
    h = Handoff(req=req, n_tokens=3, payload=payload)
    wire = decode_frame(encode_frame("handoff", handoff_to_wire(h)))[1]
    got = handoff_from_wire(wire, req=req)
    assert got.req is req                        # loopback keeps identity
    assert got.n_tokens == 3
    _assert_same(payload, got.payload)
    fresh = handoff_from_wire(wire)              # socket path rebuilds
    assert fresh.req is not req and fresh.req.rid == 7


def test_loopback_transport_accounting():
    lo = LoopbackTransport()
    obj = {"xs": np.arange(10, dtype=np.int64)}
    got, nbytes = lo.transfer("submit", obj)
    _assert_same(obj, got)
    assert nbytes == len(encode_frame("submit", obj))
    lo.transfer("submit", obj)
    assert lo.frames == 2 and lo.bytes == 2 * nbytes


def test_default_spec_is_wire_encodable():
    _, got = decode_frame(encode_frame("spec", DEFAULT_SPEC))
    _assert_same(DEFAULT_SPEC, got)


# --------------------------------------------------------------------------
# socket framing: asyncio host side + blocking client, one codec
# --------------------------------------------------------------------------

def test_socket_channel_roundtrip_and_error_frames():
    import asyncio

    from repro.runtime.transport import (SocketChannel, read_frame_async,
                                         write_frame_async)

    ready = threading.Event()
    addr = {}

    async def _serve():
        async def handle(reader, writer):
            while True:
                try:
                    kind, obj = await read_frame_async(reader)
                except ReplicaGone:
                    break
                if kind == "boom":
                    await write_frame_async(writer, "error", "kaboom")
                    continue
                await write_frame_async(writer, f"re:{kind}", obj)
            writer.close()

        server = await asyncio.start_server(handle, "127.0.0.1", 0)
        addr["port"] = server.sockets[0].getsockname()[1]
        ready.set()
        async with server:
            await server.serve_forever()

    t = threading.Thread(target=lambda: asyncio.run(_serve()), daemon=True)
    t.start()
    assert ready.wait(10)

    chan = SocketChannel("127.0.0.1", addr["port"], timeout=10)
    payload = {"arr": np.random.RandomState(3).randn(4, 7)
               .astype(np.float32), "meta": {"rid": 1, "ok": True}}
    got = chan.request("echo", payload)
    _assert_same(payload, got)
    with pytest.raises(TransportError, match="kaboom"):
        chan.request("boom", {})
    got2 = chan.request("echo", [1, "after", None])   # channel still usable
    _assert_same([1, "after", None], got2)
    assert chan.sent_frames == 3
    chan.close()


def test_connect_to_nowhere_raises_replica_gone():
    from repro.runtime.transport import SocketChannel
    with pytest.raises(ReplicaGone):
        SocketChannel("127.0.0.1", 1, timeout=0.5)
