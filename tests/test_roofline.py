"""HLO analyzer correctness: scan-vs-unrolled FLOP equivalence (the whole
point of the call-graph walk) and collective wire-cost accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import analyze_text


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_match_unrolled():
    w = jnp.ones((64, 64))
    x = jnp.ones((8, 64))
    n = 12

    def unrolled(x, w):
        for _ in range(n):
            x = x @ w
        return x

    def scanned(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x,
                            None, length=n)[0]

    f_u = analyze_text(_compiled_text(unrolled, x, w)).flops
    f_s = analyze_text(_compiled_text(scanned, x, w)).flops
    expected = 2 * 8 * 64 * 64 * n
    assert abs(f_u - expected) / expected < 0.05, (f_u, expected)
    assert abs(f_s - expected) / expected < 0.05, (f_s, expected)


def test_nested_scan_multipliers():
    w = jnp.ones((32, 32))
    x = jnp.ones((4, 32))

    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            return jax.lax.scan(inner, c, None, length=5)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    flops = analyze_text(_compiled_text(nested, x, w)).flops
    expected = 2 * 4 * 32 * 32 * 15
    assert abs(flops - expected) / expected < 0.05, (flops, expected)


@pytest.mark.slow
def test_collective_wire_costs():
    """Per-device ring wire bytes for RS/AG/AR over an 8-way axis."""
    from conftest import run_distributed
    run_distributed("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.analysis.hlo import analyze_text
mesh = jax.make_mesh((8,), ('m',), axis_types=(jax.sharding.AxisType.Auto,))
T, D = 128, 64
def f(x):
    s = jax.lax.psum_scatter(x, 'm', scatter_dimension=0, tiled=True)
    g = jax.lax.all_gather(s, 'm', axis=0, tiled=True)
    return jax.lax.psum(g, 'm')
sm = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(None), out_specs=P(None),
                           check_vma=False))
text = sm.lower(jax.ShapeDtypeStruct((T, D), jnp.float32)).compile().as_text()
mc = analyze_text(text)
rs = mc.by_kind.get('reduce-scatter', 0)
ag = mc.by_kind.get('all-gather', 0)
ar = mc.by_kind.get('all-reduce', 0)
full = T * D * 4
# RS: (N-1)*result = 7/8*full; AG: 7/8*full; AR: 2*7/8*full
assert abs(rs - 7/8*full) < 1e-6 * full, rs
assert abs(ag - 7/8*full) < 1e-6 * full, ag
assert abs(ar - 2*7/8*full) < 1e-6 * full, ar
print('PASS')
""", n_devices=8)


def test_model_flops_estimates():
    from repro.analysis.roofline import model_flops
    from repro.configs import get_config
    cfg = get_config("deepseek-67b")
    # train: >= 6*N*D
    n_tok = 1024
    mf = model_flops(cfg, n_tok, train=True)
    assert mf >= 6 * cfg.param_count() * n_tok * 0.99
    # inference strictly less than train
    assert model_flops(cfg, n_tok, train=False) < mf
    # MoE: active < total
    moe = get_config("qwen3-moe-235b-a22b")
    assert moe.active_param_count() < 0.2 * moe.param_count()


def test_sim_orderings():
    """Simulator sanity: tokenweave <= fuseonly <= reordered ~ vanilla;
    smart split never slower than naive."""
    from repro.configs import get_config
    from repro.sim.overlap_sim import e2e_latency, layer_latency
    cfg = get_config("llama3.3-70b")
    for toks in (1024, 4096):
        v = e2e_latency(cfg, "vanilla", toks, tp=16)
        f = e2e_latency(cfg, "fuseonly", toks, tp=16)
        t = e2e_latency(cfg, "tokenweave", toks, tp=16)
        n = e2e_latency(cfg, "nocomm", toks, tp=16)
        assert t <= f <= v
        assert n <= v
    # wave quantization: smart split never slower than naive
    for toks in (768, 1280, 2304):
        sm = layer_latency(cfg, "tokenweave", toks, tp=16, smart=True)
        nv = layer_latency(cfg, "tokenweave", toks, tp=16, smart=False)
        assert sm <= nv * 1.0001, (toks, sm, nv)
